"""AOT compile path: lower every L2 entry point to HLO *text* + a manifest.

HLO text (NOT serialized HloModuleProto): jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (what the rust `xla` 0.1.6
crate links) rejects; the text parser reassigns ids and round-trips cleanly.

Outputs, per model, under <out>/<model>/:
  init.hlo.txt                 (seed:u32[]) -> (p_0..p_{P-1})
  train_step.hlo.txt           (p.., x[B,..], y[B]:i32, lr:f32[]) -> (p'.., loss)
  train_step_prox.hlo.txt      (p.., g.., x, y, lr, mu) -> (p'.., loss)
  train_step_scaffold.hlo.txt  (p.., ci.., c.., x, y, lr) -> (p'.., loss)
  grad_step.hlo.txt            (p.., x, y) -> (grads.., loss)
  eval_step.hlo.txt            (p.., x[E,..], y[E]) -> (correct, loss_sum)
  agg_d{dim}_m{m}.hlo.txt      (X[m,dim], w[m]) -> (u[dim], disc)   [L1 Pallas]
  manifest.json                layer/group/entry metadata for the rust runtime

Usage: python -m compile.aot --out ../artifacts [--models a,b] [--agg-m 4,8,16]
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.agg_discrepancy import agg_discrepancy

# Build matrix: artifact name -> (model factory kwargs).  Widths are scaled
# for the CPU testbed; see DESIGN.md §4 (substitutions).
MODEL_BUILDS = {
    "mlp": ("mlp", dict(input_dim=64, hidden=(128, 64), num_classes=10)),
    "femnist_cnn": ("femnist_cnn", dict(width=8, num_classes=62)),
    "cifar_cnn": ("cifar_cnn", dict(width=8, num_classes=10)),
    "cifar_cnn100": ("cifar_cnn", dict(width=8, num_classes=100)),
    "resnet20": ("resnet20", dict(width=8, num_classes=10)),
    "resnet20w16": ("resnet20", dict(width=16, num_classes=10)),
}

DEFAULT_AGG_M = (4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_entry(fn, args, path, verbose=True):
    t0 = time.time()
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    if verbose:
        print(f"  {os.path.basename(path):34s} {len(text):>9d} chars  {time.time() - t0:5.1f}s")


def build_model_artifacts(name, out_dir, batch, eval_batch, agg_ms, chunk=6, verbose=True):
    base, kw = MODEL_BUILDS[name]
    mdl = M.get_model(base, **kw)
    mdir = os.path.join(out_dir, name)
    os.makedirs(mdir, exist_ok=True)
    if verbose:
        print(f"[{name}] {mdl.num_params} params, {len(mdl.specs)} tensors, "
              f"{len(mdl.groups())} groups")

    pspecs = [spec(s.shape) for s in mdl.specs]
    x_t = spec((batch, *mdl.input_shape))
    y_t = spec((batch,), jnp.int32)
    x_e = spec((eval_batch, *mdl.input_shape))
    y_e = spec((eval_batch,), jnp.int32)
    f32 = spec(())

    P = len(mdl.specs)

    init = M.make_init(mdl)
    lower_entry(lambda seed: init(seed), [spec((), jnp.uint32)],
                os.path.join(mdir, "init.hlo.txt"), verbose)

    ts = M.make_train_step(mdl)
    lower_entry(lambda *a: ts(a[:P], a[P], a[P + 1], a[P + 2]),
                [*pspecs, x_t, y_t, f32],
                os.path.join(mdir, "train_step.hlo.txt"), verbose)

    tsp = M.make_train_step_prox(mdl)
    lower_entry(lambda *a: tsp(a[:P], a[P:2 * P], a[2 * P], a[2 * P + 1], a[2 * P + 2], a[2 * P + 3]),
                [*pspecs, *pspecs, x_t, y_t, f32, f32],
                os.path.join(mdir, "train_step_prox.hlo.txt"), verbose)

    tss = M.make_train_step_scaffold(mdl)
    lower_entry(lambda *a: tss(a[:P], a[P:2 * P], a[2 * P:3 * P], a[3 * P], a[3 * P + 1], a[3 * P + 2]),
                [*pspecs, *pspecs, *pspecs, x_t, y_t, f32],
                os.path.join(mdir, "train_step_scaffold.hlo.txt"), verbose)

    tc = M.make_train_chunk(mdl, chunk)
    lower_entry(lambda *a: tc(a[:P], a[P], a[P + 1], a[P + 2]),
                [*pspecs, spec((chunk, batch, *mdl.input_shape)),
                 spec((chunk, batch), jnp.int32), f32],
                os.path.join(mdir, "train_chunk.hlo.txt"), verbose)

    gs = M.make_grad_step(mdl)
    lower_entry(lambda *a: gs(a[:P], a[P], a[P + 1]),
                [*pspecs, x_t, y_t],
                os.path.join(mdir, "grad_step.hlo.txt"), verbose)

    ev = M.make_eval_step(mdl)
    lower_entry(lambda *a: ev(a[:P], a[P], a[P + 1]),
                [*pspecs, x_e, y_e],
                os.path.join(mdir, "eval_step.hlo.txt"), verbose)

    # Fused Pallas aggregation kernels: one per (distinct group dim, m).
    groups = mdl.groups()
    group_dims = sorted({sum(mdl.specs[i].dim for i in idx) for _, idx in groups})
    agg_files = {}
    for d in group_dims:
        agg_files[str(d)] = {}
        for m in agg_ms:
            fname = f"agg_d{d}_m{m}.hlo.txt"
            lower_entry(lambda X, w: agg_discrepancy(X, w),
                        [spec((m, d)), spec((m,))],
                        os.path.join(mdir, fname), verbose=False)
            agg_files[str(d)][str(m)] = fname
    if verbose:
        print(f"  agg kernels: {len(group_dims)} dims x {len(agg_ms)} m-values")

    manifest = {
        "model": name,
        "base": base,
        "batch_size": batch,
        "eval_batch_size": eval_batch,
        "input_shape": list(mdl.input_shape),
        "num_classes": mdl.num_classes,
        "num_param_tensors": P,
        "num_params": mdl.num_params,
        "params": [
            {"name": s.name, "shape": list(s.shape), "dim": s.dim, "group": s.group}
            for s in mdl.specs
        ],
        "groups": [
            {"name": g, "params": idx, "dim": sum(mdl.specs[i].dim for i in idx)}
            for g, idx in groups
        ],
        "chunk_k": chunk,
        "entries": {
            "init": "init.hlo.txt",
            "train_step": "train_step.hlo.txt",
            "train_chunk": "train_chunk.hlo.txt",
            "train_step_prox": "train_step_prox.hlo.txt",
            "train_step_scaffold": "train_step_scaffold.hlo.txt",
            "grad_step": "grad_step.hlo.txt",
            "eval_step": "eval_step.hlo.txt",
        },
        "agg": {"m_values": list(agg_ms), "by_dim": agg_files},
    }
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODEL_BUILDS))
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=256)
    ap.add_argument("--agg-m", default=",".join(str(m) for m in DEFAULT_AGG_M))
    ap.add_argument("--chunk", type=int, default=6)
    args = ap.parse_args()

    models = [m for m in args.models.split(",") if m]
    agg_ms = [int(v) for v in args.agg_m.split(",") if v]
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    names = []
    for name in models:
        if name not in MODEL_BUILDS:
            print(f"unknown model {name!r}; have {sorted(MODEL_BUILDS)}", file=sys.stderr)
            return 1
        build_model_artifacts(name, args.out, args.batch, args.eval_batch, agg_ms, args.chunk)
        names.append(name)
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"models": names, "batch_size": args.batch,
                   "eval_batch_size": args.eval_batch}, f, indent=1)
    print(f"artifacts complete in {time.time() - t0:.1f}s -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
