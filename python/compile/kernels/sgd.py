"""L1 Pallas kernel: fused SGD parameter update.

Applied to every parameter tensor of every client at every local step — the
highest-frequency elementwise op in the system.  The kernel tiles the
flattened parameter through VMEM in BLOCK elements and fuses the scale and
subtract (p - lr*g) in a single pass, so each parameter is read once and
written once (vs. read-twice/write-once if the scale materializes lr*g).

interpret=True: CPU PJRT cannot execute Mosaic custom-calls; the kernel
lowers to plain HLO and fuses there.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 32768


def _sgd_kernel(lr_ref, p_ref, g_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_update_flat(param, grad, lr, block=DEFAULT_BLOCK):
    """p - lr*g over a flat f32[d] tensor via the tiled Pallas kernel."""
    (d,) = param.shape
    block = min(block, _next_multiple(d, 128))
    d_pad = _next_multiple(d, block)
    if d_pad != d:
        param = jnp.pad(param, (0, d_pad - d))
        grad = jnp.pad(grad, (0, d_pad - d))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(d_pad // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        interpret=True,
    )(lr.reshape(1).astype(jnp.float32), param.astype(jnp.float32), grad.astype(jnp.float32))
    return out[:d]


def sgd_update(param, grad, lr):
    """Shape-preserving SGD update on an arbitrary-rank tensor."""
    flat = sgd_update_flat(param.reshape(-1), grad.reshape(-1), lr)
    return flat.reshape(param.shape)


def sgd_update_tree(params, grads, lr):
    """Fused SGD update over a whole parameter list via ONE Pallas call.

    Concatenates all tensors into a single flat vector, runs the tiled
    kernel once, and splits back.  One kernel invocation per training step
    (instead of one per tensor) keeps the lowered HLO small and lets XLA
    fuse the gather/scatter copies — critical for deep models like ResNet20
    where per-tensor kernel ceremony dominated the step time.
    """
    sizes = [int(p.size) for p in params]
    pflat = jnp.concatenate([p.reshape(-1) for p in params])
    gflat = jnp.concatenate([g.reshape(-1) for g in grads])
    new_flat = sgd_update_flat(pflat, gflat, lr)
    offsets = []
    off = 0
    for s in sizes:
        offsets.append((off, off + s))
        off += s
    return [
        new_flat[a:b].reshape(p.shape) for (a, b), p in zip(offsets, params)
    ]


def _next_multiple(x, base):
    return ((x + base - 1) // base) * base
