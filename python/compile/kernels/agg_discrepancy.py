"""L1 Pallas kernel: fused weighted aggregation + layer discrepancy.

This is FedLAMA's server-side hot spot.  Every time layer l reaches its
aggregation point (k mod tau_l == 0) the server must compute

    u_l    = sum_i p_i x_l^i                      (weighted average)
    disc_l = sum_i p_i ||u_l - x_l^i||^2          (Eq. 2 numerator)

A naive implementation makes two passes over the [m, d] stack of client
parameters (one for the average, one for the distance), i.e. 2*m*d floats of
HBM traffic.  The fused kernel streams each [m, BLOCK_D] tile through VMEM
once, producing both the averaged block and the block-partial discrepancy,
halving memory traffic.  On TPU the weighted average is expressed as a
(1, m) x (m, BLOCK_D) matmul so it maps onto the MXU; the distance reduction
runs on the VPU over the same resident tile.

VMEM footprint per tile: (m + 2) * BLOCK_D * 4 bytes (+ m weights), so e.g.
m=128, BLOCK_D=2048 -> ~1 MiB, comfortably under the ~16 MiB budget, with
headroom for double buffering.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so lowering stays in plain HLO (see DESIGN.md
Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 2048


def _agg_disc_kernel(p_ref, x_ref, u_ref, dpart_ref):
    """One [m, BLOCK_D] tile: fused weighted mean + partial discrepancy.

    p_ref:     f32[m, 1]        client weights (replicated per tile)
    x_ref:     f32[m, BLOCK_D]  stacked client params for this tile
    u_ref:     f32[BLOCK_D]     output: aggregated block
    dpart_ref: f32[1]           output: this tile's discrepancy contribution
    """
    x = x_ref[...]
    p = p_ref[...]  # [m, 1]
    # Weighted average as (1, m) @ (m, BLOCK_D) — MXU-shaped on TPU.
    u = jnp.dot(p.T, x, preferred_element_type=jnp.float32)  # [1, BLOCK_D]
    u_ref[...] = u[0]
    # Distance reduction reuses the tile already resident in VMEM.
    diff = x - u  # broadcast [m, BLOCK_D]
    dpart_ref[...] = jnp.sum(p[:, 0] * jnp.sum(diff * diff, axis=1))[None]


@functools.partial(jax.jit, static_argnames=("block_d",))
def agg_discrepancy(stacked, weights, block_d=DEFAULT_BLOCK_D):
    """Fused aggregation + discrepancy over f32[m, d] client stacks.

    Returns (u: f32[d], disc: f32[]).  Matches ref.ref_agg_discrepancy.
    Pads d up to a multiple of block_d; zero padding is exact (padded
    columns aggregate to zero and contribute zero discrepancy).
    """
    m, d = stacked.shape
    block_d = min(block_d, _next_multiple(d, 128))
    d_pad = _next_multiple(d, block_d)
    if d_pad != d:
        stacked = jnp.pad(stacked, ((0, 0), (0, d_pad - d)))
    grid = d_pad // block_d
    p2 = weights.astype(jnp.float32).reshape(m, 1)

    u, dpart = pl.pallas_call(
        _agg_disc_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_pad,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=True,
    )(p2, stacked.astype(jnp.float32))
    return u[:d], jnp.sum(dpart)


def _next_multiple(x, base):
    return ((x + base - 1) // base) * base
