"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float32 tolerance (pytest + hypothesis enforce it).
"""

import jax.numpy as jnp


def ref_agg_discrepancy(stacked, weights):
    """Weighted model aggregation + unit model discrepancy (paper Eq. 2 numerator).

    Args:
      stacked: f32[m, d] — one flattened layer from each of the m clients.
      weights: f32[m]    — aggregation weights p_i (sum to 1 over active
        clients; inactive clients contribute weight 0).

    Returns:
      (u, disc): u = sum_i p_i x_i  (f32[d]) and
      disc = sum_i p_i * ||u - x_i||^2  (f32 scalar).
    """
    u = jnp.einsum("m,md->d", weights, stacked)
    diff = stacked - u[None, :]
    disc = jnp.sum(weights * jnp.sum(diff * diff, axis=1))
    return u, disc


def ref_sgd(param, grad, lr):
    """Plain SGD update: p <- p - lr * g (elementwise, any shape)."""
    return param - lr * grad


def ref_weighted_average(stacked, weights):
    """Aggregation only (no discrepancy)."""
    return jnp.einsum("m,md->d", weights, stacked)
