"""L2: the paper's models and federated train/eval steps in pure JAX.

Everything here is build-time only: `aot.py` lowers the entry points to HLO
text and the rust coordinator executes them via PJRT.  Parameters are an
explicit *list* of tensors (no pytree nesting) so the rust side can address
each FedLAMA aggregation unit ("layer") positionally, exactly as listed in
the manifest.

Models (paper §6):
  mlp          — quickstart model.
  femnist_cnn  — the LEAF/Caldas FEMNIST CNN (2 conv + 2 fc), width-scalable.
  cifar_cnn    — VGG-style CNN, the scaled stand-in for WideResNet28-10.
  resnet20     — faithful ResNet20 topology (He et al.), norm-free residual
                 blocks with trainable scale/bias (see DESIGN.md §4).

Entry points lowered per model:
  init(seed)                                  -> params
  train_step(params.., x, y, lr)              -> params'.., loss
  train_step_prox(params.., glob.., x, y, lr, mu) -> params'.., loss  (FedProx)
  train_step_scaffold(params.., ci.., c.., x, y, lr) -> params'.., loss (SCAFFOLD)
  eval_step(params.., x, y)                   -> correct, loss_sum
  grad_step(params.., x, y)                   -> grads.., loss (FedNova & tests)

The SGD update inside train_step goes through the L1 Pallas kernel
(kernels.sgd) so the kernel lowers into the same HLO module.
"""

import dataclasses
import math
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.sgd import sgd_update, sgd_update_tree


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter tensor: FedLAMA schedules aggregation per `group`."""

    name: str  # e.g. "stage2.block1.conv1.w"
    shape: Tuple[int, ...]
    group: str  # aggregation unit ("layer" in the paper's sense)
    init: str  # "he", "glorot", "zeros", "ones", "small"

    @property
    def dim(self) -> int:
        return int(math.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    input_shape: Tuple[int, ...]  # per-example, e.g. (32, 32, 3)
    num_classes: int
    specs: Tuple[ParamSpec, ...]
    apply: Callable  # (params: List[Array], x: Array[B,...]) -> logits[B, C]

    @property
    def num_params(self) -> int:
        return sum(s.dim for s in self.specs)

    def groups(self):
        """Ordered aggregation units: [(group_name, [param indices])]."""
        out, index = [], {}
        for i, s in enumerate(self.specs):
            if s.group not in index:
                index[s.group] = len(out)
                out.append((s.group, []))
            out[index[s.group]][1].append(i)
        return out


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_param(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, jnp.float32)
    if spec.init == "ones":
        return jnp.ones(spec.shape, jnp.float32)
    if spec.init == "small":
        # Residual-branch output scale: start near zero so each block is
        # near-identity at init (fixup-style, replaces BatchNorm's effect).
        return jnp.full(spec.shape, 0.1, jnp.float32)
    if spec.init == "glorot":
        fan_in, fan_out = _fans(spec.shape)
        lim = math.sqrt(6.0 / (fan_in + fan_out))
        return jax.random.uniform(key, spec.shape, jnp.float32, -lim, lim)
    # He normal (default for conv/dense + relu)
    fan_in, _ = _fans(spec.shape)
    std = math.sqrt(2.0 / max(fan_in, 1))
    return std * jax.random.normal(key, spec.shape, jnp.float32)


def _fans(shape):
    if len(shape) == 1:
        return shape[0], shape[0]
    if len(shape) == 2:
        return shape[0], shape[1]
    if len(shape) == 4:  # HWIO conv
        rf = shape[0] * shape[1]
        return rf * shape[2], rf * shape[3]
    n = int(math.prod(shape))
    return n, n


def init_params(model: ModelDef, seed):
    """Deterministic init from a traced uint32 seed (AOT `init` entry)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(model.specs))
    return [init_param(k, s) for k, s in zip(keys, model.specs)]


# ---------------------------------------------------------------------------
# Primitive layers (pure functions over the params list)
# ---------------------------------------------------------------------------


def conv2d(x, w, b=None, stride=1):
    """NHWC x HWIO -> NHWC, SAME padding."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return y


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


def scale_bias(x, s, b):
    """Channelwise affine (the norm-free stand-in for BatchNorm)."""
    return x * s + b


# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------


def make_mlp(input_dim=64, hidden=(128, 64), num_classes=10, name="mlp"):
    specs: List[ParamSpec] = []
    dims = [input_dim, *hidden, num_classes]
    for i in range(len(dims) - 1):
        g = f"fc{i + 1}"
        specs.append(ParamSpec(f"{g}.w", (dims[i], dims[i + 1]), g, "he"))
        specs.append(ParamSpec(f"{g}.b", (dims[i + 1],), g, "zeros"))

    nlayers = len(dims) - 1

    def apply(params, x):
        h = x.reshape(x.shape[0], -1)
        for i in range(nlayers):
            h = dense(h, params[2 * i], params[2 * i + 1])
            if i < nlayers - 1:
                h = jax.nn.relu(h)
        return h

    return ModelDef(name, (input_dim,), num_classes, tuple(specs), apply)


def make_femnist_cnn(width=16, num_classes=62, image=28, name="femnist_cnn"):
    """LEAF FEMNIST CNN (Caldas et al. 2018), width-scalable.

    conv5x5(1->w) relu pool2 | conv5x5(w->2w) relu pool2 | fc(->8w) relu | fc.
    """
    w1, w2, fc = width, 2 * width, 8 * width
    flat = (image // 4) * (image // 4) * w2
    specs = (
        ParamSpec("conv1.w", (5, 5, 1, w1), "conv1", "he"),
        ParamSpec("conv1.b", (w1,), "conv1", "zeros"),
        ParamSpec("conv2.w", (5, 5, w1, w2), "conv2", "he"),
        ParamSpec("conv2.b", (w2,), "conv2", "zeros"),
        ParamSpec("fc1.w", (flat, fc), "fc1", "he"),
        ParamSpec("fc1.b", (fc,), "fc1", "zeros"),
        ParamSpec("fc2.w", (fc, num_classes), "fc2", "he"),
        ParamSpec("fc2.b", (num_classes,), "fc2", "zeros"),
    )

    def apply(params, x):
        h = jax.nn.relu(conv2d(x, params[0], params[1]))
        h = maxpool2(h)
        h = jax.nn.relu(conv2d(h, params[2], params[3]))
        h = maxpool2(h)
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(dense(h, params[4], params[5]))
        return dense(h, params[6], params[7])

    return ModelDef(name, (image, image, 1), num_classes, specs, apply)


def make_cifar_cnn(width=16, num_classes=10, image=32, name="cifar_cnn"):
    """VGG-style CNN: 3 conv-conv-pool stages + 2 fc.

    Stand-in for WideResNet28-10: preserves the property the paper's
    Figures 2/3 rely on — the output-side layers hold most parameters.
    """
    w = width
    chans = [(3, w), (w, w), (w, 2 * w), (2 * w, 2 * w), (2 * w, 4 * w), (4 * w, 4 * w)]
    specs: List[ParamSpec] = []
    for i, (ci, co) in enumerate(chans):
        g = f"conv{i + 1}"
        specs.append(ParamSpec(f"{g}.w", (3, 3, ci, co), g, "he"))
        specs.append(ParamSpec(f"{g}.b", (co,), g, "zeros"))
    flat = (image // 8) * (image // 8) * 4 * w
    specs.append(ParamSpec("fc1.w", (flat, 8 * w), "fc1", "he"))
    specs.append(ParamSpec("fc1.b", (8 * w,), "fc1", "zeros"))
    specs.append(ParamSpec("fc2.w", (8 * w, num_classes), "fc2", "he"))
    specs.append(ParamSpec("fc2.b", (num_classes,), "fc2", "zeros"))

    def apply(params, x):
        h = x
        for stage in range(3):
            for j in range(2):
                i = stage * 2 + j
                h = jax.nn.relu(conv2d(h, params[2 * i], params[2 * i + 1]))
            h = maxpool2(h)
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(dense(h, params[12], params[13]))
        return dense(h, params[14], params[15])

    return ModelDef(name, (image, image, 3), num_classes, tuple(specs), apply)


def make_resnet20(width=16, num_classes=10, image=32, name="resnet20"):
    """ResNet20 (He et al. 2016): stem + 3 stages x 3 blocks x 2 convs + fc.

    BatchNorm is replaced by trainable channelwise scale/bias with a
    small-initialized scale on the residual branch output (fixup-style), so
    every parameter is a plain tensor the aggregation scheme can average
    (see DESIGN.md §4 substitutions).
    """
    w = width
    specs: List[ParamSpec] = []

    def add_conv(g, k, ci, co, bias=True):
        specs.append(ParamSpec(f"{g}.w", (k, k, ci, co), g, "he"))
        if bias:
            # Downsample shortcuts are bias-free: an unused parameter would
            # be DCE'd out of the eval/grad HLO signatures by XLA and break
            # the positional calling convention.
            specs.append(ParamSpec(f"{g}.b", (co,), g, "zeros"))

    def add_sb(g, c, small=False):
        specs.append(ParamSpec(f"{g}.s", (c,), g, "small" if small else "ones"))
        specs.append(ParamSpec(f"{g}.bb", (c,), g, "zeros"))

    add_conv("stem", 3, 3, w)
    stage_ch = [w, 2 * w, 4 * w]
    cin = w
    for s, ch in enumerate(stage_ch):
        for b in range(3):
            g = f"s{s + 1}b{b + 1}"
            add_conv(f"{g}.conv1", 3, cin if b == 0 else ch, ch)
            add_sb(f"{g}.sb1", ch)
            add_conv(f"{g}.conv2", 3, ch, ch)
            add_sb(f"{g}.sb2", ch, small=True)
            if b == 0 and cin != ch:
                add_conv(f"{g}.down", 1, cin, ch, bias=False)
        cin = ch
    specs.append(ParamSpec("fc.w", (4 * w, num_classes), "fc", "he"))
    specs.append(ParamSpec("fc.b", (num_classes,), "fc", "zeros"))

    index = {s.name: i for i, s in enumerate(specs)}

    def p(params, name):
        return params[index[name]]

    def apply(params, x):
        h = jax.nn.relu(conv2d(x, p(params, "stem.w"), p(params, "stem.b")))
        cin_l = w
        for s, ch in enumerate(stage_ch):
            for b in range(3):
                g = f"s{s + 1}b{b + 1}"
                stride = 2 if (b == 0 and s > 0) else 1
                y = conv2d(h, p(params, f"{g}.conv1.w"), p(params, f"{g}.conv1.b"), stride)
                y = jax.nn.relu(scale_bias(y, p(params, f"{g}.sb1.s"), p(params, f"{g}.sb1.bb")))
                y = conv2d(y, p(params, f"{g}.conv2.w"), p(params, f"{g}.conv2.b"))
                y = scale_bias(y, p(params, f"{g}.sb2.s"), p(params, f"{g}.sb2.bb"))
                if b == 0 and cin_l != ch:
                    h = conv2d(h, p(params, f"{g}.down.w"), None, stride)
                h = jax.nn.relu(h + y)
            cin_l = ch
        h = avgpool_global(h)
        return dense(h, p(params, "fc.w"), p(params, "fc.b"))

    return ModelDef(name, (image, image, 3), num_classes, tuple(specs), apply)


MODELS = {
    "mlp": make_mlp,
    "femnist_cnn": make_femnist_cnn,
    "cifar_cnn": make_cifar_cnn,
    "resnet20": make_resnet20,
}


def get_model(name: str, **kw) -> ModelDef:
    if name not in MODELS:
        raise KeyError(f"unknown model {name!r}; have {sorted(MODELS)}")
    return MODELS[name](**kw, name=name)


# ---------------------------------------------------------------------------
# Losses + entry points
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def make_train_step(model: ModelDef):
    """(params.., x, y, lr) -> (params'.., loss). One local SGD step."""

    def loss_fn(params, x, y):
        return cross_entropy(model.apply(params, x), y)

    def train_step(params: Sequence, x, y, lr):
        loss, grads = jax.value_and_grad(loss_fn)(list(params), x, y)
        new = sgd_update_tree(list(params), grads, lr)
        return (*new, loss)

    return train_step


def make_train_step_prox(model: ModelDef):
    """FedProx: local loss + (mu/2) * ||params - global||^2."""

    def loss_fn(params, glob, x, y, mu):
        base = cross_entropy(model.apply(params, x), y)
        prox = sum(jnp.sum((p - g) ** 2) for p, g in zip(params, glob))
        return base + 0.5 * mu * prox

    def train_step(params: Sequence, glob: Sequence, x, y, lr, mu):
        loss, grads = jax.value_and_grad(loss_fn)(list(params), list(glob), x, y, mu)
        new = sgd_update_tree(list(params), grads, lr)
        return (*new, loss)

    return train_step


def make_train_step_scaffold(model: ModelDef):
    """SCAFFOLD local step: p <- p - lr * (g - c_i + c)."""

    def loss_fn(params, x, y):
        return cross_entropy(model.apply(params, x), y)

    def train_step(params: Sequence, ci: Sequence, c: Sequence, x, y, lr):
        loss, grads = jax.value_and_grad(loss_fn)(list(params), x, y)
        corrected = [g - a + b for g, a, b in zip(grads, ci, c)]
        new = sgd_update_tree(list(params), corrected, lr)
        return (*new, loss)

    return train_step


def make_train_chunk(model: ModelDef, k: int):
    """(params.., xs[K,B,..], ys[K,B], lr) -> (params'.., losses[K]).

    K local SGD steps fused into one executable, amortizing the rust<->PJRT
    literal boundary over K steps (the L3 hot-path optimization; DESIGN.md
    §7).  The loop is UNROLLED rather than lax.scan: xla_extension 0.5.1's
    CPU backend executes while-loop bodies ~18x slower than straight-line
    code (measured in EXPERIMENTS.md §Perf), so scan would defeat the
    purpose of chunking.
    """
    step = make_train_step(model)

    def chunk(params: Sequence, xs, ys, lr):
        carry = list(params)
        losses = []
        for s in range(k):
            out = step(carry, xs[s], ys[s], lr)
            carry = list(out[:-1])
            losses.append(out[-1])
        return (*carry, jnp.stack(losses))

    return chunk


def make_grad_step(model: ModelDef):
    """(params.., x, y) -> (grads.., loss) — used by FedNova and tests."""

    def loss_fn(params, x, y):
        return cross_entropy(model.apply(params, x), y)

    def grad_step(params: Sequence, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(list(params), x, y)
        return (*grads, loss)

    return grad_step


def make_eval_step(model: ModelDef):
    """(params.., x, y) -> (correct_count, loss_sum) over one batch."""

    def eval_step(params: Sequence, x, y):
        logits = model.apply(list(params), x)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == y).astype(jnp.float32))
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        return correct, jnp.sum(nll)

    return eval_step


def make_init(model: ModelDef):
    """(seed: u32) -> params.."""

    def init(seed):
        return tuple(init_params(model, seed))

    return init
