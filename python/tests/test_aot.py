"""AOT pipeline tests: manifest consistency + HLO text emission."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_model_builds_cover_experiments():
    for name in ["mlp", "femnist_cnn", "cifar_cnn", "cifar_cnn100", "resnet20"]:
        assert name in aot.MODEL_BUILDS


def test_to_hlo_text_emits_parsable_module():
    import jax

    def fn(a, b):
        return (a @ b,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # parameters appear with f32[4,4] shapes
    assert "f32[4,4]" in text


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build_model_artifacts(
        "mlp", out, batch=8, eval_batch=16, agg_ms=[2, 3], chunk=2, verbose=False
    )
    return out, manifest


def test_manifest_round_trips(built):
    out, manifest = built
    path = os.path.join(out, "mlp", "manifest.json")
    with open(path) as f:
        loaded = json.load(f)
    assert loaded == manifest


def test_manifest_consistency(built):
    _, m = built
    mdl = M.get_model(aot.MODEL_BUILDS["mlp"][0], **aot.MODEL_BUILDS["mlp"][1])
    assert m["num_params"] == mdl.num_params
    assert m["num_param_tensors"] == len(mdl.specs)
    assert m["batch_size"] == 8
    assert m["eval_batch_size"] == 16
    assert m["chunk_k"] == 2
    # group dims sum to total
    assert sum(g["dim"] for g in m["groups"]) == m["num_params"]
    # every group's params indices are valid and disjoint
    seen = set()
    for g in m["groups"]:
        for i in g["params"]:
            assert 0 <= i < len(m["params"])
            assert i not in seen
            seen.add(i)
    assert len(seen) == len(m["params"])


def test_all_entry_files_exist_and_are_hlo(built):
    out, m = built
    for entry, fname in m["entries"].items():
        path = os.path.join(out, "mlp", fname)
        assert os.path.exists(path), entry
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), f"{entry} is not HLO text"


def test_agg_kernels_exist_per_dim_and_m(built):
    out, m = built
    dims = {str(g["dim"]) for g in m["groups"]}
    assert set(m["agg"]["by_dim"].keys()) == dims
    for d, by_m in m["agg"]["by_dim"].items():
        assert set(by_m.keys()) == {"2", "3"}
        for f in by_m.values():
            assert os.path.exists(os.path.join(out, "mlp", f))
