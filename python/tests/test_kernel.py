"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes/dtypes/client counts; assert_allclose against
kernels/ref.py everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.agg_discrepancy import agg_discrepancy, DEFAULT_BLOCK_D
from compile.kernels.ref import ref_agg_discrepancy, ref_sgd, ref_weighted_average
from compile.kernels.sgd import sgd_update, sgd_update_flat, sgd_update_tree


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(jax.random.PRNGKey(key), shape)).astype(dtype)


# ---------------------------------------------------------------------------
# agg_discrepancy
# ---------------------------------------------------------------------------


class TestAggDiscrepancy:
    def check(self, m, d, key=0, block_d=DEFAULT_BLOCK_D):
        X = rand(key, (m, d))
        w = jnp.abs(rand(key + 1, (m,))) + 0.01
        w = w / w.sum()
        u, disc = agg_discrepancy(X, w, block_d=block_d)
        u_ref, disc_ref = ref_agg_discrepancy(X, w)
        np.testing.assert_allclose(u, u_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(disc, disc_ref, rtol=1e-4, atol=1e-5)

    def test_basic(self):
        self.check(4, 1000)

    def test_single_client(self):
        X = rand(3, (1, 257))
        u, disc = agg_discrepancy(X, jnp.ones((1,)))
        np.testing.assert_allclose(u, X[0], rtol=1e-6)
        assert float(disc) < 1e-8

    def test_unpadded_exact_multiple(self):
        self.check(2, 2 * DEFAULT_BLOCK_D)

    def test_ragged_padding(self):
        self.check(3, DEFAULT_BLOCK_D + 17)

    def test_tiny_dim(self):
        self.check(8, 3)

    def test_identical_clients_zero_discrepancy(self):
        x = rand(5, (1, 400))
        X = jnp.tile(x, (6, 1))
        w = jnp.full((6,), 1.0 / 6.0)
        u, disc = agg_discrepancy(X, w)
        np.testing.assert_allclose(u, x[0], rtol=1e-5, atol=1e-6)
        assert float(disc) < 1e-6

    def test_zero_weight_rows_ignored(self):
        X = rand(6, (3, 128))
        X = X.at[2].set(1e6)  # junk row
        w = jnp.array([0.5, 0.5, 0.0])
        u, disc = agg_discrepancy(X, w)
        u_ref, disc_ref = ref_agg_discrepancy(X[:2], jnp.array([0.5, 0.5]))
        np.testing.assert_allclose(u, u_ref, rtol=1e-5)
        np.testing.assert_allclose(disc, disc_ref, rtol=1e-4)

    def test_weighted_average_matches(self):
        X = rand(7, (4, 300))
        w = jnp.array([0.1, 0.2, 0.3, 0.4])
        u, _ = agg_discrepancy(X, w)
        np.testing.assert_allclose(u, ref_weighted_average(X, w), rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 12),
        d=st.integers(1, 3000),
        block=st.sampled_from([128, 256, 1024, 2048]),
        key=st.integers(0, 10_000),
    )
    def test_hypothesis_shapes(self, m, d, block, key):
        self.check(m, d, key=key, block_d=block)

    @settings(max_examples=8, deadline=None)
    @given(m=st.integers(2, 6), d=st.integers(10, 500), key=st.integers(0, 100))
    def test_hypothesis_bf16_inputs_upcast(self, m, d, key):
        # bf16 client tensors are accepted and accumulated in f32
        X = rand(key, (m, d), jnp.bfloat16)
        w = jnp.full((m,), 1.0 / m)
        u, disc = agg_discrepancy(X, w)
        u_ref, disc_ref = ref_agg_discrepancy(X.astype(jnp.float32), w)
        np.testing.assert_allclose(u, u_ref, rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(disc, disc_ref, rtol=5e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# sgd
# ---------------------------------------------------------------------------


class TestSgd:
    def test_flat_matches_ref(self):
        p = rand(0, (5000,))
        g = rand(1, (5000,))
        out = sgd_update_flat(p, g, jnp.float32(0.3))
        np.testing.assert_allclose(out, ref_sgd(p, g, 0.3), rtol=1e-5, atol=1e-6)

    def test_shaped(self):
        p = rand(2, (3, 4, 5))
        g = rand(3, (3, 4, 5))
        out = sgd_update(p, g, jnp.float32(0.01))
        np.testing.assert_allclose(out, ref_sgd(p, g, 0.01), rtol=1e-5, atol=1e-6)
        assert out.shape == p.shape

    def test_zero_lr_is_identity(self):
        p = rand(4, (130,))
        out = sgd_update_flat(p, rand(5, (130,)), jnp.float32(0.0))
        np.testing.assert_allclose(out, p, rtol=0, atol=0)

    def test_tree_update_matches_per_tensor(self):
        shapes = [(3, 3, 2, 4), (4,), (10, 7), (1,), (128,)]
        params = [rand(10 + i, s) for i, s in enumerate(shapes)]
        grads = [rand(20 + i, s) for i, s in enumerate(shapes)]
        lr = jnp.float32(0.05)
        tree = sgd_update_tree(params, grads, lr)
        for t, p, g in zip(tree, params, grads):
            np.testing.assert_allclose(t, ref_sgd(p, g, 0.05), rtol=1e-5, atol=1e-6)
            assert t.shape == p.shape

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 100_000),
        lr=st.floats(0.0, 2.0, allow_nan=False),
        key=st.integers(0, 1000),
    )
    def test_hypothesis_sizes(self, n, lr, key):
        p = rand(key, (n,))
        g = rand(key + 1, (n,))
        out = sgd_update_flat(p, g, jnp.float32(lr))
        np.testing.assert_allclose(out, ref_sgd(p, g, np.float32(lr)), rtol=1e-5, atol=1e-6)

    def test_inside_jit(self):
        p = rand(6, (64,))
        g = rand(7, (64,))

        @jax.jit
        def f(p, g, lr):
            return sgd_update_flat(p, g, lr)

        np.testing.assert_allclose(f(p, g, jnp.float32(0.1)), ref_sgd(p, g, 0.1), rtol=1e-5, atol=1e-6)
