"""L2 model tests: shapes, gradients, training dynamics, entry points."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

MODELS = [
    ("mlp", dict(input_dim=32, hidden=(32, 16), num_classes=5)),
    ("femnist_cnn", dict(width=4, num_classes=62)),
    ("cifar_cnn", dict(width=4, num_classes=10)),
    ("resnet20", dict(width=4, num_classes=10)),
]


def make(name, kw):
    return M.get_model(name, **kw)


def batch_for(mdl, b=4, key=0):
    x = jax.random.normal(jax.random.PRNGKey(key), (b, *mdl.input_shape))
    y = jnp.arange(b, dtype=jnp.int32) % mdl.num_classes
    return x, y


@pytest.mark.parametrize("name,kw", MODELS)
class TestModelZoo:
    def test_specs_consistent(self, name, kw):
        mdl = make(name, kw)
        assert mdl.num_params == sum(int(np.prod(s.shape)) for s in mdl.specs)
        names = [s.name for s in mdl.specs]
        assert len(names) == len(set(names)), "duplicate param names"
        groups = mdl.groups()
        covered = sorted(i for _, idx in groups for i in idx)
        assert covered == list(range(len(mdl.specs))), "groups must cover all params"

    def test_init_shapes_and_determinism(self, name, kw):
        mdl = make(name, kw)
        p1 = M.init_params(mdl, jnp.uint32(7))
        p2 = M.init_params(mdl, jnp.uint32(7))
        p3 = M.init_params(mdl, jnp.uint32(8))
        for a, b, s in zip(p1, p2, mdl.specs):
            assert a.shape == s.shape
            np.testing.assert_array_equal(a, b)
        assert any(not np.array_equal(a, c) for a, c in zip(p1, p3))

    def test_forward_shape(self, name, kw):
        mdl = make(name, kw)
        params = M.init_params(mdl, jnp.uint32(0))
        x, _ = batch_for(mdl)
        logits = mdl.apply(params, x)
        assert logits.shape == (4, mdl.num_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_gradients_flow_to_every_param(self, name, kw):
        mdl = make(name, kw)
        params = M.init_params(mdl, jnp.uint32(1))
        x, y = batch_for(mdl)

        def loss(params):
            return M.cross_entropy(mdl.apply(params, x), y)

        grads = jax.grad(loss)(params)
        for g, s in zip(grads, mdl.specs):
            assert bool(jnp.all(jnp.isfinite(g))), s.name
            # every tensor must receive gradient signal somewhere
            assert float(jnp.max(jnp.abs(g))) > 0.0, f"dead parameter {s.name}"

    def test_train_step_reduces_fixed_batch_loss(self, name, kw):
        mdl = make(name, kw)
        params = list(M.init_params(mdl, jnp.uint32(2)))
        x, y = batch_for(mdl, b=8)
        step = make_jitted_step(mdl)
        first = None
        for _ in range(10):
            out = step(params, x, y, jnp.float32(0.05))
            params = list(out[:-1])
            if first is None:
                first = float(out[-1])
        last = float(out[-1])
        assert last < first, f"{name}: {first} -> {last}"

    def test_eval_step_counts(self, name, kw):
        mdl = make(name, kw)
        params = M.init_params(mdl, jnp.uint32(3))
        x, y = batch_for(mdl, b=8)
        correct, loss_sum = M.make_eval_step(mdl)(params, x, y)
        assert 0.0 <= float(correct) <= 8.0
        assert float(loss_sum) > 0.0


def make_jitted_step(mdl):
    raw = M.make_train_step(mdl)
    return jax.jit(lambda params, x, y, lr: raw(params, x, y, lr))


class TestEntryPoints:
    def setup_method(self):
        self.mdl = make("mlp", dict(input_dim=16, hidden=(16,), num_classes=4))
        self.params = list(M.init_params(self.mdl, jnp.uint32(0)))
        self.x, self.y = batch_for(self.mdl, b=4)

    def test_prox_penalizes_distance(self):
        step = M.make_train_step_prox(self.mdl)
        glob = [p + 1.0 for p in self.params]
        out_mu0 = step(self.params, glob, self.x, self.y, jnp.float32(0.0), jnp.float32(0.0))
        out_mu1 = step(self.params, glob, self.x, self.y, jnp.float32(0.0), jnp.float32(1.0))
        # with mu>0 the loss includes the prox term: P params off by 1 each
        extra = 0.5 * sum(float(jnp.sum((p - g) ** 2)) for p, g in zip(self.params, glob))
        assert float(out_mu1[-1]) == pytest.approx(float(out_mu0[-1]) + extra, rel=1e-4)

    def test_scaffold_correction_shifts_update(self):
        step = M.make_train_step_scaffold(self.mdl)
        zeros = [jnp.zeros_like(p) for p in self.params]
        ones = [jnp.ones_like(p) * 0.1 for p in self.params]
        lr = jnp.float32(0.1)
        base = step(self.params, zeros, zeros, self.x, self.y, lr)
        # c_i = c -> identical to plain sgd
        same = step(self.params, ones, ones, self.x, self.y, lr)
        for a, b in zip(base[:-1], same[:-1]):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
        # c != c_i shifts every parameter by lr*(c - c_i) = lr*0.1
        shifted = step(self.params, zeros, ones, self.x, self.y, lr)
        for a, b in zip(base[:-1], shifted[:-1]):
            np.testing.assert_allclose(b, a - 0.01, rtol=1e-4, atol=1e-6)

    def test_grad_step_matches_autodiff(self):
        gs = M.make_grad_step(self.mdl)
        out = gs(self.params, self.x, self.y)
        grads, loss = out[:-1], out[-1]

        def loss_fn(params):
            return M.cross_entropy(self.mdl.apply(params, self.x), self.y)

        want = jax.grad(loss_fn)(self.params)
        assert float(loss) == pytest.approx(float(loss_fn(self.params)), rel=1e-5)
        for g, w in zip(grads, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)

    def test_train_chunk_matches_sequential_steps(self):
        k = 3
        chunk = M.make_train_chunk(self.mdl, k)
        step = M.make_train_step(self.mdl)
        xs = jax.random.normal(jax.random.PRNGKey(9), (k, 4, *self.mdl.input_shape))
        ys = jnp.tile(self.y, (k, 1))
        lr = jnp.float32(0.05)
        out = chunk(self.params, xs, ys, lr)
        chunk_params, losses = list(out[:-1]), out[-1]
        assert losses.shape == (k,)
        params = self.params
        for s in range(k):
            o = step(params, xs[s], ys[s], lr)
            params = list(o[:-1])
            np.testing.assert_allclose(float(o[-1]), float(losses[s]), rtol=1e-5)
        for a, b in zip(chunk_params, params):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_cross_entropy_uniform_logits(self):
        logits = jnp.zeros((4, 10))
        y = jnp.zeros((4,), jnp.int32)
        assert float(M.cross_entropy(logits, y)) == pytest.approx(np.log(10.0), rel=1e-5)


class TestResnetStructure:
    def test_layer_count_matches_paper(self):
        mdl = make("resnet20", dict(width=8, num_classes=10))
        conv_weights = [s for s in mdl.specs if len(s.shape) == 4]
        fc = [s for s in mdl.specs if s.name.startswith("fc.")]
        # 20 weight layers: stem + 18 block convs + fc; +2 downsample 1x1
        assert len(conv_weights) == 1 + 18 + 2
        assert len(fc) == 2
        # downsample shortcuts are bias-free (would be DCE'd from eval HLO)
        assert not any(s.name.endswith("down.b") for s in mdl.specs)

    def test_output_side_layers_dominate_size(self):
        # the property Figures 2/3 rely on: later groups hold most params
        mdl = make("resnet20", dict(width=8, num_classes=10))
        groups = mdl.groups()
        dims = [sum(mdl.specs[i].dim for i in idx) for _, idx in groups]
        first_half = sum(dims[: len(dims) / 2 if False else len(dims) // 2])
        second_half = sum(dims[len(dims) // 2 :])
        assert second_half > 2 * first_half
