#!/usr/bin/env python3
"""Gate a fresh `fedlama bench` artifact against the committed baseline.

Used by the nightly-bench workflow: the full (non --quick) bench runs on
the scheduled runner and this script fails the job if any section
regressed more than the tolerance (default 20%) versus the committed
BENCH_kernels.json.

The baseline starts life as an unmeasured skeleton (measured: false,
null metrics).  Anything unmeasured is *skipped, loudly*: a null on
either side, a whole unmeasured baseline, or an entry the other artifact
does not carry gates nothing — but each skip is printed so a silently
shrinking gate is visible in the job log.  The fresh artifact itself
must be measured; an unmeasured nightly run is a broken run.

Metric direction is inferred from the field name: *ns_per_iter / *_ns /
*_secs / *_ms are times (lower is better), *_per_s / *gflops /
*speedup* are rates (higher is better).  Deterministic fields (bytes,
frame counts, dispatch names) are never gated — they are correctness
surface, not performance.
"""

import argparse
import json
import sys

LOWER_IS_BETTER = ("ns_per_iter", "_ns", "_secs", "_ms", "peak_rss_bytes")
HIGHER_IS_BETTER = ("_per_s", "gflops", "speedup_vs_scalar")


def direction(field):
    for suffix in LOWER_IS_BETTER:
        if field.endswith(suffix):
            return "lower"
    for suffix in HIGHER_IS_BETTER:
        if field.endswith(suffix):
            return "higher"
    return None


def identity(entry):
    """An entry's identity is its string-valued fields (kernel, shape,
    model, path, ...) — stable across reruns, unlike the metrics."""
    return tuple(sorted((k, v) for k, v in entry.items() if isinstance(v, str)))


def entries_of(doc, section):
    val = doc.get(section)
    if val is None:
        return []
    if isinstance(val, dict):  # the pool section is one flat object
        return [val] if val else []
    return val


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_kernels.json")
    ap.add_argument("fresh", help="artifact from this nightly run")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.20,
        help="allowed fractional regression per metric (default 0.20)",
    )
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    for name, doc in ((args.baseline, base), (args.fresh, fresh)):
        if doc.get("schema") != 1:
            sys.exit(f"{name}: unknown schema {doc.get('schema')!r}")
    if fresh.get("measured") is not True:
        sys.exit(f"{args.fresh}: nightly artifact is not measured — broken bench run")
    if base.get("measured") is not True:
        print(
            f"SKIP all: {args.baseline} is an unmeasured skeleton — regenerate it "
            "with `cargo run --release -- bench` and commit the diff to arm this gate"
        )
        return

    regressions = []
    compared = skipped = 0
    for section in ("kernels", "ops", "end_to_end", "pool", "transport"):
        base_by_id = {identity(e): e for e in entries_of(base, section)}
        fresh_by_id = {identity(e): e for e in entries_of(fresh, section)}
        for ident, be in base_by_id.items():
            label = f"{section}[{', '.join(v for _, v in ident)}]" if ident else section
            fe = fresh_by_id.get(ident)
            if fe is None:
                print(f"SKIP {label}: entry absent from fresh artifact")
                skipped += 1
                continue
            for field, bv in be.items():
                sense = direction(field)
                if sense is None:
                    continue
                fv = fe.get(field)
                if bv is None or fv is None:
                    print(f"SKIP {label}.{field}: unmeasured (null)")
                    skipped += 1
                    continue
                if sense == "lower":
                    worse = fv > bv * (1.0 + args.tolerance)
                    change = (fv - bv) / bv
                else:
                    worse = fv < bv * (1.0 - args.tolerance)
                    change = (bv - fv) / bv
                compared += 1
                if worse:
                    regressions.append(
                        f"{label}.{field}: {bv} -> {fv} "
                        f"({change:+.1%} worse, tolerance {args.tolerance:.0%})"
                    )

    print(f"compared {compared} metrics, skipped {skipped} unmeasured/missing")
    if regressions:
        print(f"\n{len(regressions)} regression(s) beyond tolerance:")
        for r in regressions:
            print(f"  REGRESSION {r}")
        sys.exit(1)
    if compared == 0:
        print("note: nothing was comparable — the gate is currently a no-op")


if __name__ == "__main__":
    main()
