#!/usr/bin/env python3
"""Assert the Byzantine-robustness story of an adversarial chaos run.

Used by the chaos-smoke CI job's adversarial leg.  Takes three fedlama
run reports produced from the same base flags:

  clean   — no attacker, plain mean (the reference trajectory)
  robust  — attacker active (--chaos signflip:1) but screened out by a
            robust aggregator (--aggregator trimmed:1)
  mean    — the same attacker with the plain mean fold (unprotected)

and checks the three claims the robustness PR makes:

  1. containment: the robust run's final accuracy lands within
     --acc-tolerance of the clean run (the screen rejects the forged
     updates, so the attacker contributes nothing but a smaller
     renormalized quorum);
  2. attribution: every rejected update in the robust report is charged
     to the attacking shard (chaos turns the *lowest* N shards
     adversarial, so shard 0 here), and honest shards are never charged;
  3. contrast: the unprotected mean run is strictly worse than the
     robust run on both final loss and final accuracy — if the attack
     doesn't hurt the mean, the leg is vacuous and should fail loudly.
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("clean", help="attack-free reference report")
    ap.add_argument("robust", help="attacked run with a robust aggregator")
    ap.add_argument("mean", help="attacked run with the plain mean fold")
    ap.add_argument(
        "--acc-tolerance",
        type=float,
        default=0.10,
        help="max final-accuracy shortfall of the robust run vs clean",
    )
    args = ap.parse_args()

    clean, robust, mean = load(args.clean), load(args.robust), load(args.mean)

    # 1. containment
    gap = clean["final_acc"] - robust["final_acc"]
    if gap > args.acc_tolerance:
        sys.exit(
            f"robust run lost {gap:.4f} accuracy vs clean "
            f"({robust['final_acc']:.4f} vs {clean['final_acc']:.4f}), "
            f"tolerance {args.acc_tolerance}"
        )

    # 2. attribution
    parts = robust["per_participant"]
    attacker = parts[0]
    if attacker["shard"] != 0:
        sys.exit(f"expected shard 0 first in per_participant, got {attacker}")
    if attacker["rejected_updates"] == 0:
        sys.exit(f"attacking shard was never rejected: {parts}")
    honest_rejects = [p for p in parts[1:] if p["rejected_updates"] > 0]
    if honest_rejects:
        sys.exit(f"honest shards charged with rejections: {honest_rejects}")

    # 3. contrast — the attack must actually bite without the screen
    if mean["final_loss"] <= robust["final_loss"]:
        sys.exit(
            f"unprotected mean did not diverge: loss {mean['final_loss']:.6f} "
            f"<= robust {robust['final_loss']:.6f} (vacuous attack?)"
        )
    if mean["final_acc"] >= robust["final_acc"]:
        sys.exit(
            f"unprotected mean did not lose accuracy: {mean['final_acc']:.4f} "
            f">= robust {robust['final_acc']:.4f} (vacuous attack?)"
        )

    print(
        f"robust ok: clean acc {clean['final_acc']:.4f}, "
        f"robust-under-attack acc {robust['final_acc']:.4f} "
        f"(gap {gap:+.4f} <= {args.acc_tolerance}), "
        f"attacker shard 0 rejected {attacker['rejected_updates']}x, "
        f"unprotected mean collapsed to acc {mean['final_acc']:.4f} / "
        f"loss {mean['final_loss']:.6f}"
    )


if __name__ == "__main__":
    main()
