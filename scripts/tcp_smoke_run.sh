#!/usr/bin/env bash
# Run one fedlama TCP federation on localhost: a `serve` coordinator plus
# N `join` participants, waiting for every process to exit cleanly.
#
# Usage: tcp_smoke_run.sh PORT PARTICIPANTS OUT_JSON [extra train flags...]
#
# The run flags come from $SMOKE_FLAGS (the single copy lives in the env
# block of .github/workflows/ci.yml, whose in-proc and --workers reference
# runs expand the same variable before diffing OUT_JSON against theirs
# with scripts/assert_identical_metrics.py); the fallback below mirrors it
# for local use outside CI.  Extra flags go to `serve` only — participants
# receive the run config over the wire.
#
# Chaos knob: CHAOS_KILL_ONE_AFTER=SECS sends SIGKILL to the last joiner
# that many seconds into the run.  Its non-zero exit is then expected and
# tolerated; pass `--quorum Q < N` in the extra flags so the serve side
# survives the departure.
set -euo pipefail

port=$1
n=$2
out=$3
shift 3
bin=./target/release/fedlama

flags=${SMOKE_FLAGS:-"--dataset toy --clients 8 --samples 128 --policy fedlama \
  --tau 6 --phi 2 --iters 96 --eval-every 2 --lr 0.05 --seed 7"}

# shellcheck disable=SC2086  # $flags is a flag list, word-splitting intended
"$bin" serve --bind "127.0.0.1:$port" --expect "$n" $flags \
  --join-timeout 120 --out "$out" "$@" &
serve=$!

pids=()
# serve failing (bind clash, join-window expiry) exits the script via
# set -e: reap the joiners so they don't keep retrying into the CI log
trap 'kill "$serve" "${pids[@]:-}" 2>/dev/null || true' EXIT
for _ in $(seq "$n"); do
  "$bin" join --connect "127.0.0.1:$port" --retry-secs 60 &
  pids+=("$!")
done

victim=""
if [[ -n "${CHAOS_KILL_ONE_AFTER:-}" ]]; then
  victim=${pids[$((n - 1))]}
  (
    sleep "$CHAOS_KILL_ONE_AFTER"
    echo "[chaos] SIGKILL joiner pid $victim" >&2
    kill -9 "$victim" 2>/dev/null || true
  ) &
fi

wait "$serve"
for p in "${pids[@]}"; do
  if [[ "$p" == "$victim" ]]; then
    # the SIGKILLed joiner exits 137 by design
    wait "$p" || true
  else
    wait "$p"
  fi
done
