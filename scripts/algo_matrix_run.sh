#!/usr/bin/env bash
# Cross-algorithm transport matrix: every local optimizer (sgd, fedprox,
# scaffold, fednova) under every layer-wise policy (fedlama,
# divergence-feedback, personalized), each run three ways — in-proc,
# sharded over --workers 2, and as a localhost TCP federation — with the
# three JSON reports diffed bit-for-bit by
# scripts/assert_identical_metrics.py.  This is the gate behind the
# claim that the whole algorithm zoo is transport-complete: server-side
# reductions (SCAFFOLD control folds, FedNova normalization, lambda
# updates) ride wire messages, never in-proc shortcuts.
#
# Usage: algo_matrix_run.sh PORT_BASE OUT_DIR
#
# Run flags come from $MATRIX_FLAGS (the single copy lives in the env of
# the ci.yml algo-matrix job; the fallback below mirrors it for local
# use).  Each TCP combo gets its own port (PORT_BASE + combo index) so a
# lingering socket from one combo can never bite the next.
set -euo pipefail

port_base=$1
out_dir=$2
bin=./target/release/fedlama

flags=${MATRIX_FLAGS:-"--dataset toy --clients 6 --samples 64 --partition dirichlet \
  --alpha 0.3 --tau 6 --phi 2 --iters 48 --eval-every 2 --lr 0.05 --seed 7"}

mkdir -p "$out_dir"

# Per-combo extra flags.  scaffold/fednova take the per-step local path
# (the fused chunk entry has no hook for control-variate correction);
# fednova adds heterogeneous local budgets since normalized averaging is
# exactly the mechanism that must survive them.
extra_for() {
  local algo=$1 policy=$2 extra=""
  case "$algo" in
    fedprox) extra+=" --mu 0.01" ;;
    scaffold) extra+=" --no-chunk" ;;
    fednova) extra+=" --no-chunk --hetero" ;;
  esac
  case "$policy" in
    divergence-feedback) extra+=" --threshold 0.05" ;;
    personalized) extra+=" --mix-eta 0.25" ;;
  esac
  echo "$extra"
}

i=0
for algo in sgd fedprox scaffold fednova; do
  for policy in fedlama divergence-feedback personalized; do
    extra=$(extra_for "$algo" "$policy")
    tag="${algo}_${policy}"
    echo "=== ${tag} ==="
    # shellcheck disable=SC2086  # $flags/$extra are flag lists, splitting intended
    "$bin" train $flags --algo "$algo" --policy "$policy" $extra \
      --out "$out_dir/${tag}_inproc.json"
    # shellcheck disable=SC2086
    "$bin" train $flags --algo "$algo" --policy "$policy" $extra --workers 2 \
      --out "$out_dir/${tag}_workers2.json"
    # shellcheck disable=SC2086
    SMOKE_FLAGS="$flags" scripts/tcp_smoke_run.sh "$((port_base + i))" 2 \
      "$out_dir/${tag}_tcp2.json" --algo "$algo" --policy "$policy" $extra
    # in-proc vs workers: per_participant is shape-mismatched by design
    # (1 shard vs 2); totals are pinned by the test suite
    python3 scripts/assert_identical_metrics.py \
      "$out_dir/${tag}_inproc.json" "$out_dir/${tag}_workers2.json" \
      --ignore per_participant
    # workers vs TCP share the shard count: exact tables must match
    python3 scripts/assert_identical_metrics.py \
      "$out_dir/${tag}_workers2.json" "$out_dir/${tag}_tcp2.json"
    i=$((i + 1))
  done
done

# The extreme-non-IID partitions must rebuild identically on worker
# shards (partitions derive from the seed, never travel the wire).
for part in single-class power-law; do
  echo "=== partition ${part} rebuilds identically across transports ==="
  # shellcheck disable=SC2086
  "$bin" train $flags --partition "$part" --policy fedlama \
    --out "$out_dir/part_${part}_inproc.json"
  # shellcheck disable=SC2086
  "$bin" train $flags --partition "$part" --policy fedlama --workers 2 \
    --out "$out_dir/part_${part}_workers2.json"
  python3 scripts/assert_identical_metrics.py \
    "$out_dir/part_${part}_inproc.json" "$out_dir/part_${part}_workers2.json" \
    --ignore per_participant
done

# Acceptance leg: on a pathological non-IID shard, divergence-feedback
# must land strictly below plain FedLAMA on measured bytes *and* the
# Eq.9 ledger.  The generous threshold makes every observed group skip:
# this gates the machinery (skips really leave the wire and the ledger
# agrees), not the policy-quality question, which belongs to reports.
echo "=== divergence-feedback cuts uplink on single-class shards ==="
# shellcheck disable=SC2086
"$bin" train $flags --partition single-class --policy fedlama \
  --out "$out_dir/uplink_plain.json"
# shellcheck disable=SC2086
"$bin" train $flags --partition single-class --policy divergence-feedback \
  --threshold 1e9 --out "$out_dir/uplink_divfb.json"
python3 scripts/assert_uplink_reduction.py \
  "$out_dir/uplink_plain.json" "$out_dir/uplink_divfb.json"

echo "algo matrix ok: 12 combos x 3 transports, 2 partition rebuilds, 1 uplink gate"
