#!/usr/bin/env python3
"""Assert two fedlama run-metrics JSON files are bit-identical.

Used by the multiprocess-smoke and tcp-smoke CI jobs (one shared script
instead of per-job heredocs).  Compares every transport-invariant key;
wall-clock and throughput fields are never compared (they depend on the
machine, not the math).

--ignore KEY[,KEY...] skips keys whose *shape* legitimately differs
between the two runs.  The only expected use is `per_participant` when
the shard counts differ: an in-proc run folds all traffic into one shard,
while an N-worker/N-participant run has N slots.  Runs with equal shard
counts (e.g. stdio `--workers 3` vs a 3-participant TCP run) must match
on per_participant too, so do not ignore it there.
"""

import argparse
import json
import sys

# Transport-invariant keys of the fedlama run report, in emit order.
KEYS = [
    "tag",
    "final_acc",
    "final_loss",
    "total_comm_cost",
    "total_syncs",
    "total_bytes",
    "per_group",
    "per_participant",
    "per_client",
    "curve",
]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("a", help="first run report (reference)")
    ap.add_argument("b", help="second run report")
    ap.add_argument(
        "--ignore",
        default="",
        metavar="KEY[,KEY...]",
        help="keys to skip (only for shape-mismatched comparisons)",
    )
    args = ap.parse_args()

    ignore = {k for k in args.ignore.split(",") if k}
    unknown = ignore - set(KEYS)
    if unknown:
        sys.exit(f"--ignore names unknown keys: {sorted(unknown)} (known: {KEYS})")

    with open(args.a) as f:
        a = json.load(f)
    with open(args.b) as f:
        b = json.load(f)

    checked = []
    for key in KEYS:
        if key in ignore:
            continue
        for name, doc in ((args.a, a), (args.b, b)):
            if key not in doc:
                sys.exit(f"{name}: missing key {key!r}")
        if a[key] != b[key]:
            sys.exit(f"MISMATCH {key}:\n  {args.a}: {a[key]!r}\n  {args.b}: {b[key]!r}")
        checked.append(key)

    print(f"{args.a} == {args.b} on: {', '.join(checked)}")


if __name__ == "__main__":
    main()
