#!/usr/bin/env python3
"""Assert a measured `fedlama bench --scale` artifact holds the client
registry's scalability claims.

Used by the scale-smoke CI job on the `--quick` (10k registered / 100
sampled) artifact.  Checks:

  - the doc is measured and carries a `scale` section,
  - the roster/sampling shape matches what the job requested,
  - sampling made progress (positive rounds/s) and actually wrote
    per-client state through the spill-to-disk store,
  - the resident set is O(sampled): touched clients are bounded by
    sampled x rounds, never by the registered roster,
  - the O(sampled) memory claim: the coordinator's peak RSS (VmHWM)
    sits inside the artifact's reported bound — a flat harness
    allowance plus a per-touched-entry budget, independent of
    `registered`.
"""

import argparse
import json
import sys


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact", help="bench artifact JSON (from --scale)")
    ap.add_argument("--registered", type=int, default=0, help="expected roster size")
    ap.add_argument("--sampled", type=int, default=0, help="expected clients per round")
    args = ap.parse_args()

    with open(args.artifact) as f:
        doc = json.load(f)

    if doc.get("measured") is not True:
        fail("artifact is not measured (is this the committed skeleton?)")
    s = doc.get("scale")
    if not isinstance(s, dict):
        fail("no scale section in the artifact (was bench run with --scale?)")

    for key in (
        "registered",
        "sampled",
        "rounds",
        "rounds_per_sec",
        "touched_clients",
        "spilled_controls",
        "spill_log_bytes",
        "peak_rss_bytes",
        "rss_bound_bytes",
    ):
        v = s.get(key)
        if not isinstance(v, (int, float)) or v <= 0:
            fail(f"scale.{key} = {v!r} (want a positive number)")

    if args.registered and s["registered"] != args.registered:
        fail(f"scale.registered = {s['registered']}, job requested {args.registered}")
    if args.sampled and s["sampled"] != args.sampled:
        fail(f"scale.sampled = {s['sampled']}, job requested {args.sampled}")

    touched, sampled, rounds = s["touched_clients"], s["sampled"], s["rounds"]
    if not sampled <= touched <= sampled * rounds:
        fail(
            f"touched_clients {touched} outside [{sampled}, {sampled * rounds}] "
            "— the resident set must be O(sampled x rounds), not O(registered)"
        )

    if s.get("rss_within_bound") is not True:
        fail(
            f"peak RSS {s['peak_rss_bytes']} B exceeds the O(sampled) bound "
            f"{s['rss_bound_bytes']} B — coordinator memory scales with the roster?"
        )
    if not s["peak_rss_bytes"] <= s["rss_bound_bytes"]:
        fail("rss_within_bound is true but the numbers disagree")

    print(
        f"OK scale: {int(s['registered'])} registered / {int(sampled)} sampled "
        f"x {int(rounds)} rounds at {s['rounds_per_sec']:.1f} rounds/s; "
        f"peak RSS {int(s['peak_rss_bytes'])} B <= bound {int(s['rss_bound_bytes'])} B, "
        f"{int(touched)} touched, spill log {int(s['spill_log_bytes'])} B"
    )


if __name__ == "__main__":
    main()
