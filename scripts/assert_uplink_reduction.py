#!/usr/bin/env python3
"""Assert a divergence-feedback run actually cut uplink traffic.

Compares two fedlama run reports over the same scenario: `plain` (the
FedLAMA schedule, every due group uplinks at its sync point) and
`skipping` (divergence-feedback, under-threshold groups keep training
and skip the uplink).  The skipping run must come in strictly below the
plain run on *both* the measured wire bytes and the Eq.9 communication
cost — if only one of the two drops, the ledger and the transport
disagree about what was actually sent, which is exactly the bug this
gate exists to catch.
"""

import argparse
import json
import sys


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("plain", help="report of the plain FedLAMA run")
    ap.add_argument("skipping", help="report of the divergence-feedback run")
    args = ap.parse_args()

    with open(args.plain) as f:
        plain = json.load(f)
    with open(args.skipping) as f:
        skip = json.load(f)

    failed = False
    for key in ("total_bytes", "total_comm_cost"):
        for name, doc in ((args.plain, plain), (args.skipping, skip)):
            if key not in doc:
                sys.exit(f"{name}: missing key {key!r}")
        p, s = plain[key], skip[key]
        if s < p:
            print(f"{key}: {s} < {p} ({(1 - s / p):.1%} saved)")
        else:
            print(f"FAIL {key}: skipping run must be strictly cheaper: {s} !< {p}")
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
