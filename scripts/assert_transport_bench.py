#!/usr/bin/env python3
"""Assert a measured `fedlama bench` artifact's transport section holds
the streamed-framing claims.

Used by the bench-smoke CI job on the `--quick` artifact.  Checks:

  - the doc is measured (not the committed skeleton),
  - the transport section covers both bench models (mlp, resnet20) on
    both wire paths (monolithic, streamed),
  - every throughput / size metric is a positive number,
  - the tentpole claim: for each model, the streamed path's peak staging
    bytes undercut the monolithic path's (peak staging is bounded by the
    largest *layer* frame, not the largest whole message — for resnet20
    that is the difference between one conv layer and the full model).
"""

import json
import sys

MODELS = ("mlp", "resnet20")
PATHS = ("monolithic", "streamed")
METRICS = (
    "frames",
    "bytes",
    "peak_staging_bytes",
    "encode_mb_per_s",
    "decode_mb_per_s",
    "encode_frames_per_s",
    "decode_frames_per_s",
)


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} BENCH_artifact.json")
    with open(sys.argv[1]) as f:
        doc = json.load(f)

    if doc.get("measured") is not True:
        fail("artifact is not measured (is this the committed skeleton?)")

    entries = doc.get("transport")
    if not isinstance(entries, list):
        fail("no transport section in the artifact")

    by_key = {}
    for e in entries:
        by_key[(e.get("model"), e.get("path"))] = e

    for model in MODELS:
        for path in PATHS:
            e = by_key.get((model, path))
            if e is None:
                fail(f"transport entry missing for model={model} path={path}")
            for m in METRICS:
                v = e.get(m)
                if not isinstance(v, (int, float)) or v <= 0:
                    fail(f"{model}/{path}: {m} = {v!r} (want a positive number)")

    for model in MODELS:
        streamed = by_key[(model, "streamed")]["peak_staging_bytes"]
        mono = by_key[(model, "monolithic")]["peak_staging_bytes"]
        if not streamed < mono:
            fail(
                f"{model}: streamed peak staging {streamed} B is not below "
                f"the monolithic baseline {mono} B"
            )
        print(
            f"OK {model}: streamed peak staging {int(streamed)} B < "
            f"monolithic {int(mono)} B ({mono / streamed:.1f}x smaller)"
        )

    print("transport bench assertions passed")


if __name__ == "__main__":
    main()
