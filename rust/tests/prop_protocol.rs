//! Property tests for the federation-protocol wire codec (`protocol::wire`
//! + `protocol::messages`), under the in-house `util::prop` harness:
//!
//!   - encode -> decode identity for every message kind, including
//!     `LayerUpdate` payloads in dense, q-bit, and top-k encodings;
//!   - truncated frames are rejected at every probed cut;
//!   - corrupted frames are rejected (magic/version/length guarded by the
//!     header checks, the body by CRC-32 — which catches *every* burst
//!     error shorter than 32 bits, so a single flipped byte can never
//!     slip through);
//!   - the lossy payload re-encodings reproduce the compressor's output
//!     bit-for-bit and preserve its nominal (ledger) byte accounting.

use fedlama::aggregation::Policy;
use fedlama::comm::{Compressor, Quantizer, Spec, TopK};
use fedlama::config::{Algorithm, PartitionKind, RunConfig};
use fedlama::data::DatasetKind;
use fedlama::protocol::messages::{encode_tensor, update_stream_seed};
use fedlama::protocol::{
    Abort, AlgoState, BlockDone, Configure, ControlUpdate, Heartbeat, Hello, LayerUpdate, Message,
    Payload, RoundAssignment, SyncDecision,
};
use fedlama::util::prop::{forall, Strategy};
use fedlama::util::rng::Rng;

fn rand_f32s(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.below(max_len);
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn rand_payload(rng: &mut Rng) -> Payload {
    let mut data = rand_f32s(rng, 160);
    match rng.below(3) {
        0 => Payload::Dense(data),
        1 => {
            let bits = 1 + rng.below(16) as u32;
            let mut q = Quantizer::new(bits, rng.next_u64());
            q.compress(&mut data);
            Payload::qbits_from(&data, bits, q.chunk)
        }
        _ => {
            let mut t = TopK::new(0.01 + rng.range_f64(0.0, 0.99));
            let nominal = t.compress(&mut data);
            Payload::topk_from(&data, nominal)
        }
    }
}

fn rand_cfg(rng: &mut Rng) -> RunConfig {
    let dataset = match rng.below(4) {
        0 => DatasetKind::Toy,
        1 => DatasetKind::Cifar10,
        2 => DatasetKind::Cifar100,
        _ => DatasetKind::Femnist,
    };
    let algorithm = match rng.below(4) {
        0 => Algorithm::Sgd,
        1 => Algorithm::Prox { mu: rng.f32() },
        2 => Algorithm::Scaffold,
        _ => Algorithm::Nova,
    };
    let policy = match rng.below(4) {
        0 => Policy::fedavg(1 + rng.below(12)),
        1 => Policy::FedLama {
            tau: 1 + rng.below(12),
            phi: 1 + rng.below(4),
            accelerate: rng.below(2) == 0,
        },
        2 => Policy::divergence_feedback(
            1 + rng.below(12),
            1 + rng.below(4),
            rng.range_f64(0.0, 1.0),
        ),
        _ => Policy::personalized(1 + rng.below(12), rng.range_f64(0.01, 1.0)),
    };
    let partition = match rng.below(5) {
        0 => PartitionKind::Iid,
        1 => PartitionKind::Dirichlet { alpha: rng.range_f64(0.01, 5.0) },
        2 => PartitionKind::Writers,
        3 => PartitionKind::SingleClass,
        _ => PartitionKind::PowerLaw { exponent: rng.range_f64(0.5, 3.0) },
    };
    let compressor = ["dense", "q4", "q8", "top10"][rng.below(4)].to_string();
    RunConfig {
        model: ["mlp", "femnist_cnn", "resnet20"][rng.below(3)].to_string(),
        dataset,
        algorithm,
        policy,
        partition,
        n_clients: 1 + rng.below(64),
        active_ratio: rng.range_f64(0.05, 1.0),
        samples: 1 + rng.below(1024),
        lr: rng.f32() + 0.001,
        warmup_rounds: rng.below(8),
        iterations: 1 + rng.below(2048),
        seed: rng.next_u64(),
        threads: rng.below(16),
        use_chunk: rng.below(2) == 0,
        hetero_local_steps: rng.below(2) == 0,
        compressor,
        ..RunConfig::default()
    }
}

fn rand_ids(rng: &mut Rng, max: usize) -> Vec<usize> {
    (0..rng.below(max)).map(|_| rng.below(1024)).collect()
}

/// Uniform generator over every message kind.
struct MsgStrategy;

impl Strategy for MsgStrategy {
    type Value = Message;
    fn generate(&self, rng: &mut Rng) -> Message {
        match rng.below(11) {
            0 => Message::Hello(Hello {
                version: rng.below(255) as u8,
                worker_id: rng.below(64),
                shard_len: rng.below(1024),
            }),
            1 => Message::Configure(Configure {
                worker_id: rng.below(8),
                n_workers: 1 + rng.below(8),
                shard: rand_ids(rng, 32),
                cfg: rand_cfg(rng),
            }),
            2 => Message::Heartbeat(Heartbeat { nonce: rng.next_u64() }),
            3 => Message::Assignment(RoundAssignment {
                k: rng.below(100_000),
                round: rng.below(1000),
                gap: 1 + rng.below(24),
                lr: rng.f32(),
                new_round: rng.below(2) == 0,
                active: rand_ids(rng, 32),
                due_groups: rand_ids(rng, 16),
            }),
            4 => Message::Update(LayerUpdate {
                k: rng.below(100_000),
                group: rng.below(64),
                client: rng.below(1024),
                tensors: (0..1 + rng.below(3)).map(|_| rand_payload(rng)).collect(),
            }),
            5 => Message::Done(BlockDone {
                worker_id: rng.below(8),
                k: rng.below(100_000),
                losses: (0..rng.below(16))
                    .map(|_| {
                        let loss =
                            if rng.below(8) == 0 { f64::NAN } else { rng.range_f64(-10.0, 10.0) };
                        (rng.below(1024), loss)
                    })
                    .collect(),
                compute_secs: rng.range_f64(0.0, 1e6),
            }),
            6 => Message::Decision(SyncDecision {
                k: rng.below(100_000),
                group: rng.below(64),
                new_interval: 1 + rng.below(64),
                new_params: (0..1 + rng.below(3)).map(|_| rand_f32s(rng, 120)).collect(),
                mix: (0..rng.below(8)).map(|_| (rng.below(1024), rng.f32())).collect(),
            }),
            7 => Message::Abort(Abort {
                worker_id: rng.below(64),
                reason: "x".repeat(rng.below(96)),
            }),
            8 => Message::Algo(AlgoState {
                k: rng.below(100_000),
                client: rng.below(1024),
                steps: rng.next_u64() % 10_000,
                tensors: (0..1 + rng.below(3)).map(|_| rand_f32s(rng, 120)).collect(),
            }),
            9 => Message::Control(ControlUpdate {
                k: rng.below(100_000),
                tensors: (0..1 + rng.below(3)).map(|_| rand_f32s(rng, 120)).collect(),
            }),
            _ => Message::Shutdown,
        }
    }
}

/// Structural equality that treats NaN == NaN (losses may legitimately be
/// NaN; `PartialEq` on f64 would reject the round-trip).
fn msg_eq(a: &Message, b: &Message) -> bool {
    match (a, b) {
        (Message::Done(x), Message::Done(y)) => {
            x.worker_id == y.worker_id
                && x.k == y.k
                && x.compute_secs.to_bits() == y.compute_secs.to_bits()
                && x.losses.len() == y.losses.len()
                && x.losses
                    .iter()
                    .zip(&y.losses)
                    .all(|((ca, la), (cb, lb))| ca == cb && la.to_bits() == lb.to_bits())
        }
        _ => a == b,
    }
}

#[test]
fn every_message_kind_round_trips() {
    forall(0xC0DEC, 300, &MsgStrategy, |msg| {
        let frame = msg.to_frame().map_err(|e| format!("encode failed: {e:#}"))?;
        let (decoded, used) =
            Message::decode(&frame).map_err(|e| format!("decode failed: {e:#}"))?;
        if used != frame.len() {
            return Err(format!("consumed {used} of {} bytes", frame.len()));
        }
        if !msg_eq(&decoded, msg) {
            return Err(format!("round-trip mismatch: {decoded:?}"));
        }
        Ok(())
    });
}

#[test]
fn truncated_frames_are_rejected() {
    forall(0x7A11, 150, &MsgStrategy, |msg| {
        let frame = msg.to_frame().map_err(|e| format!("encode failed: {e:#}"))?;
        // probe the header, the body boundary, and interior cuts
        let cuts =
            [0, 1, 4, 7, 8, frame.len() / 3, frame.len() / 2, frame.len() - 1];
        for &cut in cuts.iter().filter(|&&c| c < frame.len()) {
            if Message::decode(&frame[..cut]).is_ok() {
                return Err(format!("accepted a frame truncated to {cut} bytes"));
            }
        }
        Ok(())
    });
}

#[test]
fn corrupted_frames_are_rejected() {
    forall(0xBAD_F00D, 150, &MsgStrategy, |msg| {
        let frame = msg.to_frame().map_err(|e| format!("encode failed: {e:#}"))?;
        // magic, version: header validation must fire
        for i in [0usize, 1, 2] {
            let mut bad = frame.clone();
            bad[i] ^= 0x5A;
            if Message::decode(&bad).is_ok() {
                return Err(format!("accepted corrupt header byte {i}"));
            }
        }
        // length field: setting a high bit always overshoots the buffer
        let mut bad = frame.clone();
        bad[7] ^= 0x01; // += 2^24 bytes
        if Message::decode(&bad).is_ok() {
            return Err("accepted corrupt length field".into());
        }
        // body + trailing crc: every single-byte flip is a burst < 32 bits,
        // which CRC-32 is guaranteed to catch
        let body_len = frame.len() - 12;
        let probes = [0usize, body_len / 2, body_len.saturating_sub(1), body_len, body_len + 3];
        for &off in probes.iter().filter(|&&o| o < body_len + 4) {
            let mut bad = frame.clone();
            bad[8 + off] ^= 0x10;
            if Message::decode(&bad).is_ok() {
                return Err(format!("accepted corrupt body byte {off}"));
            }
        }
        // kind byte is outside the crc: a flip must at minimum never decode
        // back to the original message
        let mut bad = frame.clone();
        bad[3] ^= 0x01;
        if let Ok((m, _)) = Message::decode(&bad) {
            if msg_eq(&m, msg) {
                return Err("kind flip decoded to the original message".into());
            }
        }
        Ok(())
    });
}

/// Strategy for payload-encoding inputs: (spec, values, stream seed).
struct TensorStrategy;

impl Strategy for TensorStrategy {
    type Value = (String, Vec<f32>, u64);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let spec = match rng.below(6) {
            0 => "dense".to_string(),
            1 => "q1".to_string(),
            2 => "q4".to_string(),
            3 => "q8".to_string(),
            4 => "q16".to_string(),
            _ => format!("top{}", 1 + rng.below(100)),
        };
        // lengths straddling the quantizer chunk size (1024)
        let n = 1 + rng.below(2500);
        let mut vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        // sprinkle exact zeros and sign edge cases
        for v in vals.iter_mut() {
            match rng.below(16) {
                0 => *v = 0.0,
                1 => *v = -0.0,
                _ => {}
            }
        }
        (spec, vals, rng.next_u64())
    }
}

#[test]
fn payload_encodings_reproduce_the_compressor_bit_for_bit() {
    forall(0x9E7, 120, &TensorStrategy, |(spec_s, vals, seed)| {
        let spec = Spec::parse(spec_s).ok_or(format!("bad spec {spec_s}"))?;
        // reference: what the compressor alone would produce
        let mut reference = vals.clone();
        let nominal = spec.build(*seed).compress(&mut reference);
        // protocol path: compress + wire-encode + frame + decode
        let mut buf = vals.clone();
        let payload = encode_tensor(spec, *seed, &mut buf);
        if payload.nominal_bytes() != nominal {
            return Err(format!(
                "{spec_s}: nominal {} != compressor {nominal}",
                payload.nominal_bytes()
            ));
        }
        let msg = Message::Update(LayerUpdate { k: 6, group: 0, client: 1, tensors: vec![payload] });
        let frame = msg.to_frame().map_err(|e| format!("{e:#}"))?;
        let (decoded, _) = Message::decode(&frame).map_err(|e| format!("{e:#}"))?;
        let Message::Update(u) = decoded else { return Err("wrong kind".into()) };
        let out = u.tensors[0].decode().map_err(|e| format!("{e:#}"))?;
        if out.len() != reference.len() {
            return Err(format!("{spec_s}: length {} != {}", out.len(), reference.len()));
        }
        for (i, (a, b)) in reference.iter().zip(&out).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("{spec_s}: bit mismatch at {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn update_stream_seeds_are_message_unique_not_order_dependent() {
    // the same (seed, k, group, client) always yields the same stream, so
    // compression is independent of which worker sends the update...
    assert_eq!(update_stream_seed(7, 12, 3, 5), update_stream_seed(7, 12, 3, 5));
    // ...and distinct messages get distinct streams
    let mut seen = std::collections::BTreeSet::new();
    for k in (6..=60).step_by(6) {
        for g in 0..8 {
            for c in 0..16 {
                seen.insert(update_stream_seed(7, k, g, c));
            }
        }
    }
    assert_eq!(seen.len(), 10 * 8 * 16);
}
