//! Bit-identity oracle tests for the SIMD quantize / dequantize /
//! aggregation primitives added alongside the matmul ladder.
//!
//! The contract: every dispatch path (AVX2 / SSE2 / scalar) produces
//! **bitwise identical** results for `abs_div_mul`, `div_mul`, the
//! `Quantizer` compress pipeline (including its RNG draw order), QBits
//! payload decode, and the aggregation weighted-sum — on every length,
//! including remainder lanes.  Determinism of the federation across
//! transports and `--workers N` rests on these holding exactly.

use fedlama::aggregation::aggregate_native_with;
use fedlama::comm::compression::{Compressor, Quantizer};
use fedlama::protocol::Payload;
use fedlama::runtime::simd::{self, Isa};
use fedlama::util::prop::{forall, Pair, UsizeIn};
use fedlama::util::rng::Rng;

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 1.5)).collect()
}

#[test]
fn abs_div_mul_paths_are_bit_identical_across_remainders() {
    for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 100, 1023] {
        let src = randvec(n, n as u64);
        let mut want = vec![0.0f32; n];
        simd::abs_div_mul(Isa::Scalar, &mut want, &src, 1.7, 255.0);
        for isa in simd::supported_isas() {
            let mut got = vec![-9.0f32; n]; // stale values must be overwritten
            simd::abs_div_mul(isa, &mut got, &src, 1.7, 255.0);
            assert_eq!(got, want, "abs_div_mul diverged on {} at n={n}", isa.name());
        }
    }
}

#[test]
fn div_mul_paths_are_bit_identical_across_remainders() {
    for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 100, 1023] {
        let base = randvec(n, 1000 + n as u64);
        let mut want = base.clone();
        simd::div_mul(Isa::Scalar, &mut want, 255.0, 0.83);
        for isa in simd::supported_isas() {
            let mut got = base.clone();
            simd::div_mul(isa, &mut got, 255.0, 0.83);
            assert_eq!(got, want, "div_mul diverged on {} at n={n}", isa.name());
        }
    }
}

/// The full compress pipeline is bit-identical across paths: same lossy
/// values AND the same RNG stream consumption (same seed -> same draws on
/// every path, with zero-max chunks drawing nothing).
#[test]
fn quantizer_compress_is_bit_identical_across_paths() {
    let lens = Pair(UsizeIn { lo: 1, hi: 2600 }, UsizeIn { lo: 1, hi: 12 });
    forall(17, 40, &lens, |&(n, bits)| {
        let mut data = randvec(n, (n * 31 + bits) as u64);
        // zero out a whole chunk when long enough: the skip path must
        // consume no RNG draws on any dispatch path
        if n > 2048 {
            data[1024..2048].fill(0.0);
        }
        let mut want = data.clone();
        let bytes_want = Quantizer::with_isa(bits as u32, 99, Isa::Scalar).compress(&mut want);
        for isa in simd::supported_isas() {
            let mut got = data.clone();
            let bytes = Quantizer::with_isa(bits as u32, 99, isa).compress(&mut got);
            if got != want || bytes != bytes_want {
                return Err(format!(
                    "compress diverged on {} (n={n}, bits={bits})",
                    isa.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn qbits_decode_is_bit_identical_across_paths() {
    for n in [1usize, 7, 64, 1023, 1024, 1025, 3000] {
        let mut lossy = randvec(n, 7 + n as u64);
        Quantizer::with_isa(8, 5, Isa::Scalar).compress(&mut lossy);
        let p = Payload::qbits_from(&lossy, 8, 1024);
        let want = p.decode_with_isa(Isa::Scalar).unwrap();
        // decode reconstructs the compressor's lossy values exactly...
        assert_eq!(want, lossy, "decode must reproduce the lossy values at n={n}");
        // ...on every dispatch path
        for isa in simd::supported_isas() {
            let got = p.decode_with_isa(isa).unwrap();
            assert_eq!(got, want, "QBits decode diverged on {} at n={n}", isa.name());
        }
    }
}

#[test]
fn aggregation_weighted_sum_is_bit_identical_across_paths() {
    let shapes = Pair(UsizeIn { lo: 1, hi: 9 }, UsizeIn { lo: 1, hi: 130 });
    forall(23, 40, &shapes, |&(m, d)| {
        let rows_data: Vec<Vec<f32>> =
            (0..m).map(|i| randvec(d, (m * 1000 + d * 10 + i) as u64)).collect();
        let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new((m + d) as u64);
        let mut w: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
        if m > 2 {
            w[1] = 0.0; // the zero-weight skip must match on every path
        }
        let mut u_want = vec![0.0f32; d];
        let disc_want = aggregate_native_with(Isa::Scalar, &rows, &w, &mut u_want);
        for isa in simd::supported_isas() {
            let mut u = vec![7.0f32; d];
            let disc = aggregate_native_with(isa, &rows, &w, &mut u);
            if u != u_want {
                return Err(format!("aggregate u diverged on {} (m={m}, d={d})", isa.name()));
            }
            // the f64 discrepancy pass runs on identical u, rows, weights
            // -> identical bits
            if disc.to_bits() != disc_want.to_bits() {
                return Err(format!("discrepancy diverged on {} (m={m}, d={d})", isa.name()));
            }
        }
        Ok(())
    });
}
