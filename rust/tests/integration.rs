//! Integration tests over the hermetic native backend: these run on every
//! `cargo test` with zero external artifacts, exercising the full stack —
//! backend compute, the coordinator loop, FedLAMA scheduling, comm
//! accounting, compression, and the baselines.
//!
//! The PJRT/artifact variants of the backend-equivalence tests live at the
//! bottom behind `#[cfg(feature = "pjrt")]` and still skip when no AOT
//! artifacts are present (run `make artifacts` with a real xla crate).

use fedlama::aggregation::Policy;
use fedlama::config::{Algorithm, PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::runtime::{ComputeBackend, NativeBackend};
use fedlama::util::rng::Rng;

fn toy_cfg() -> RunConfig {
    RunConfig {
        dataset: DatasetKind::Toy,
        n_clients: 4,
        samples: 256,
        lr: 0.05,
        warmup_rounds: 2,
        iterations: 96,
        policy: Policy::fedavg(6),
        eval_every_rounds: 4,
        eval_examples: 256,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn backend_loads_and_inits_deterministically() {
    let rt = NativeBackend::for_dataset(DatasetKind::Toy);
    assert_eq!(rt.manifest().model, "native-mlp");
    let p1 = rt.init_params(3).unwrap();
    let p2 = rt.init_params(3).unwrap();
    assert_eq!(p1.len(), rt.manifest().num_tensors());
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(a.data, b.data, "same seed -> same init");
    }
    let p3 = rt.init_params(4).unwrap();
    assert!(p1.iter().zip(&p3).any(|(a, b)| a.data != b.data), "different seed -> different init");
    // shapes match the manifest
    for (t, info) in p1.iter().zip(&rt.manifest().params) {
        assert_eq!(t.shape, info.shape, "{}", info.name);
        assert_eq!(t.len(), info.dim);
    }
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let rt = NativeBackend::for_dataset(DatasetKind::Toy);
    let mut params = rt.init_params(0).unwrap();
    let b = rt.manifest().batch_size;
    let d: usize = rt.manifest().input_shape.iter().product();
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..b).map(|i| (i % rt.manifest().num_classes) as i32).collect();
    let first = rt.train_step(&mut params, &x, &y, 0.1).unwrap();
    let mut last = first;
    for _ in 0..30 {
        last = rt.train_step(&mut params, &x, &y, 0.1).unwrap();
    }
    assert!(last < 0.5 * first, "loss should collapse on a fixed batch: {first} -> {last}");
}

#[test]
fn train_chunk_matches_single_steps() {
    let rt = NativeBackend::for_dataset(DatasetKind::Toy);
    let k = rt.chunk_k();
    assert!(k > 1, "expected a chunked configuration");
    let b = rt.manifest().batch_size;
    let d: usize = rt.manifest().input_shape.iter().product();
    let mut rng = Rng::new(6);
    let xs: Vec<f32> = (0..k * b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ys: Vec<i32> = (0..k * b).map(|i| (i % rt.manifest().num_classes) as i32).collect();

    let mut p_chunk = rt.init_params(1).unwrap();
    let losses = rt.train_chunk(&mut p_chunk, &xs, &ys, 0.05).unwrap();
    assert_eq!(losses.len(), k);

    let mut p_step = rt.init_params(1).unwrap();
    let mut step_losses = Vec::new();
    for s in 0..k {
        let x = &xs[s * b * d..(s + 1) * b * d];
        let y = &ys[s * b..(s + 1) * b];
        step_losses.push(rt.train_step(&mut p_step, x, y, 0.05).unwrap());
    }
    // chunking is defined as K single steps: bit-identical, not just close
    assert_eq!(losses, step_losses);
    for (a, b) in p_chunk.iter().zip(&p_step) {
        assert_eq!(a.data, b.data, "chunked and stepped params diverged");
    }
}

#[test]
fn fedavg_run_learns_and_accounts_comm() {
    let mut coord = Coordinator::new(toy_cfg()).unwrap();
    let metrics = coord.run().unwrap();
    // the toy task is easy: accuracy far above chance (10%)
    assert!(metrics.final_acc > 0.5, "final acc {}", metrics.final_acc);
    // loss decreased over training
    let first = metrics.curve.first().unwrap().train_loss;
    let last = metrics.curve.last().unwrap().train_loss;
    assert!(last < first, "loss {first} -> {last}");
    // comm accounting: K/interval syncs of the whole model
    let expected_syncs = (96 / 6) * coord.manifest().groups.len() as u64;
    assert_eq!(metrics.total_syncs, expected_syncs);
    let expected_cost: u64 = (96 / 6) * coord.manifest().num_params as u64;
    assert_eq!(metrics.total_comm_cost, expected_cost);
}

#[test]
fn fedlama_phi1_is_bit_identical_to_fedavg() {
    let mut avg = Coordinator::new(toy_cfg()).unwrap();
    let m_avg = avg.run().unwrap();
    let cfg = RunConfig { policy: Policy::fedlama(6, 1), ..toy_cfg() };
    let mut lama = Coordinator::new(cfg).unwrap();
    let m_lama = lama.run().unwrap();
    assert_eq!(m_avg.total_comm_cost, m_lama.total_comm_cost);
    for (a, b) in avg.global().iter().zip(lama.global()) {
        assert_eq!(a.data, b.data, "phi=1 must reproduce FedAvg exactly");
    }
    assert_eq!(m_avg.final_acc, m_lama.final_acc);
}

#[test]
fn fedlama_reduces_comm_vs_fedavg_base_interval() {
    let base = toy_cfg();
    let mut avg = Coordinator::new(base.clone()).unwrap();
    let m_avg = avg.run().unwrap();
    let cfg = RunConfig { policy: Policy::fedlama(6, 4), ..base };
    let mut lama = Coordinator::new(cfg).unwrap();
    let m_lama = lama.run().unwrap();
    assert!(
        m_lama.total_comm_cost < m_avg.total_comm_cost,
        "fedlama {} !< fedavg {}",
        m_lama.total_comm_cost,
        m_avg.total_comm_cost
    );
    // and still at least one adjustment happened
    assert!(!lama.schedule().adjustments.is_empty());
    // full sync still guaranteed at round boundaries: every group synced
    assert!(m_lama.per_group.iter().all(|(_, _, syncs, _)| *syncs >= (96 / 24) as u64));
    // FedLAMA should stay comparable on accuracy (generous floor)
    assert!(m_lama.final_acc > 0.4, "fedlama acc {}", m_lama.final_acc);
}

#[test]
fn partial_participation_runs_and_resamples() {
    let cfg = RunConfig {
        n_clients: 8,
        active_ratio: 0.25,
        partition: PartitionKind::Dirichlet { alpha: 0.5 },
        samples: 64,
        policy: Policy::fedlama(6, 2),
        iterations: 96,
        ..toy_cfg()
    };
    let mut coord = Coordinator::new(cfg).unwrap();
    let metrics = coord.run().unwrap();
    // 2 active clients per round
    assert_eq!(coord.sampler().n_active, 2);
    assert!(metrics.final_acc > 0.15, "partial-participation run collapsed");
}

#[test]
fn baselines_run_and_learn() {
    for algo in [
        Algorithm::Prox { mu: 0.01 },
        Algorithm::Scaffold,
        Algorithm::Nova,
    ] {
        let cfg = RunConfig {
            algorithm: algo,
            policy: Policy::fedavg(6),
            iterations: 48,
            hetero_local_steps: algo == Algorithm::Nova,
            partition: PartitionKind::Dirichlet { alpha: 0.3 },
            samples: 64,
            use_chunk: false,
            ..toy_cfg()
        };
        let mut coord = Coordinator::new(cfg).unwrap();
        let metrics = coord.run().unwrap();
        let first = metrics.curve.first().unwrap().train_loss;
        assert!(
            metrics.final_loss < first,
            "{} did not reduce loss: {first} -> {}",
            algo.name(),
            metrics.final_loss
        );
    }
}

#[test]
fn compression_composes_with_fedlama() {
    let base = RunConfig {
        policy: Policy::fedlama(6, 2),
        iterations: 96,
        eval_every_rounds: 0,
        ..toy_cfg()
    };
    let mut dense = Coordinator::new(base.clone()).unwrap();
    let m_dense = dense.run().unwrap();
    let cfg = RunConfig { compressor: "q8".into(), ..base.clone() };
    let mut q8 = Coordinator::new(cfg).unwrap();
    let m_q8 = q8.run().unwrap();
    // Eq.9 cost (parameter count) is schedule-determined, identical
    assert_eq!(m_dense.total_comm_cost, m_q8.total_comm_cost);
    // wire bytes shrink with 8-bit quantization (uplink ~4x smaller)
    assert!(
        (m_q8.total_bytes as f64) < 0.8 * m_dense.total_bytes as f64,
        "q8 bytes {} !<< dense bytes {}",
        m_q8.total_bytes,
        m_dense.total_bytes
    );
    // and the model still learns through the lossy channel
    assert!(m_q8.final_acc > 0.4, "q8 acc {}", m_q8.final_acc);

    // top-10% sparsification: even fewer bytes, still trains
    let cfg = RunConfig { compressor: "top10".into(), ..base };
    let mut topk = Coordinator::new(cfg).unwrap();
    let m_topk = topk.run().unwrap();
    assert!((m_topk.total_bytes as f64) < 0.7 * m_dense.total_bytes as f64);
    assert!(m_topk.final_loss.is_finite());
}

#[test]
fn accelerate_variant_runs_and_syncs_more() {
    let base = toy_cfg();
    let lama = RunConfig { policy: Policy::fedlama(6, 2), ..base.clone() };
    let acc = RunConfig {
        policy: Policy::FedLama { tau: 6, phi: 2, accelerate: true },
        ..base
    };
    let mut a = Coordinator::new(lama).unwrap();
    let m_lama = a.run().unwrap();
    let mut b = Coordinator::new(acc).unwrap();
    let m_acc = b.run().unwrap();
    // both keep the full-sync guarantee and produce finite results
    assert!(m_acc.final_loss.is_finite() && m_lama.final_loss.is_finite());
    assert!(m_acc.total_comm_cost <= m_lama.total_comm_cost * 2);
}

#[test]
fn grad_step_is_consistent_with_train_step() {
    let rt = NativeBackend::for_dataset(DatasetKind::Toy);
    let b = rt.manifest().batch_size;
    let d: usize = rt.manifest().input_shape.iter().product();
    let mut rng = Rng::new(12);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..b).map(|i| (i % rt.manifest().num_classes) as i32).collect();
    let p0 = rt.init_params(2).unwrap();
    let (grads, gloss) = rt.grad_step(&p0, &x, &y).unwrap();
    let mut p1 = p0.clone();
    let tloss = rt.train_step(&mut p1, &x, &y, 0.2).unwrap();
    assert_eq!(gloss, tloss);
    for ((new, old), g) in p1.iter().zip(&p0).zip(&grads) {
        for ((&pn, &po), &gv) in new.data.iter().zip(&old.data).zip(&g.data) {
            assert_eq!(pn, po - 0.2 * gv);
        }
    }
}

#[test]
fn native_engine_rejects_forced_xla_agg() {
    use fedlama::aggregation::AggBackend;
    let cfg = RunConfig { backend: AggBackend::Xla, ..toy_cfg() };
    assert!(cfg.validate().is_err(), "native engine must reject backend=xla at validation");
}

// ---------------------------------------------------------------------------
// PJRT/artifact variants: compiled only with `--features pjrt`, and skipped
// at runtime unless `make artifacts` has produced AOT HLO files.
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use fedlama::aggregation::{aggregate_native, AggBackend};
    use fedlama::config::EngineKind;
    use fedlama::runtime::ModelRuntime;
    use std::path::{Path, PathBuf};

    fn artifacts(model: &str) -> Option<PathBuf> {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(model);
        if p.join("manifest.json").exists() {
            Some(p)
        } else {
            eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", p.display());
            None
        }
    }

    #[test]
    fn pallas_agg_kernel_matches_native() {
        let Some(dir) = artifacts("mlp") else { return };
        let rt = ModelRuntime::load(&dir).unwrap();
        let mut rng = Rng::new(8);
        for (&dim, by_m) in rt.manifest.agg_by_dim.clone().iter() {
            for (&m, _) in by_m {
                let Some(exe) = rt.agg_kernel(dim, m) else {
                    panic!("manifest lists agg kernel for dim={dim} m={m} but load failed")
                };
                let stack: Vec<f32> = (0..m * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let mut w: Vec<f32> = (0..m).map(|_| rng.f32() + 0.05).collect();
                let s: f32 = w.iter().sum();
                w.iter_mut().for_each(|v| *v /= s);
                let (u_xla, disc_xla) = rt.run_agg(&exe, &stack, &w, dim).unwrap();
                let rows: Vec<&[f32]> = (0..m).map(|i| &stack[i * dim..(i + 1) * dim]).collect();
                let mut u_nat = vec![0.0f32; dim];
                let disc_nat = aggregate_native(&rows, &w, &mut u_nat);
                let max_diff = u_xla
                    .iter()
                    .zip(&u_nat)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_diff < 1e-4, "agg u mismatch dim={dim} m={m}: {max_diff}");
                let rel = ((disc_xla as f64 - disc_nat) / disc_nat.max(1e-9)).abs();
                assert!(rel < 1e-3, "disc mismatch dim={dim} m={m}: {disc_xla} vs {disc_nat}");
            }
        }
    }

    #[test]
    fn xla_and_native_agg_backends_agree_end_to_end() {
        let Some(dir) = artifacts("mlp") else { return };
        let base = RunConfig {
            engine: EngineKind::Pjrt,
            model_dir: dir,
            backend: AggBackend::Native,
            iterations: 24,
            eval_every_rounds: 0,
            ..toy_cfg()
        };
        let mut nat = Coordinator::new(base.clone()).unwrap();
        let m_nat = nat.run().unwrap();
        let cfg = RunConfig { backend: AggBackend::Xla, ..base };
        let mut xla = Coordinator::new(cfg).unwrap();
        let m_xla = xla.run().unwrap();
        assert_eq!(m_nat.total_comm_cost, m_xla.total_comm_cost);
        for (a, b) in nat.global().iter().zip(xla.global()) {
            let max_diff =
                a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(max_diff < 1e-3, "backend divergence {max_diff}");
        }
    }
}
