//! Property tests for `comm::compression` under `util::prop::forall`:
//! wire-size upper bounds, decode idempotence, error bounds, and the dense
//! round-trip exactness.

use fedlama::comm::{Compressor, Dense, Quantizer, TopK};
use fedlama::util::prop::{forall, Strategy, VecF64};
use fedlama::util::rng::Rng;

/// Random f32 vectors, non-degenerate (no zeros, so top-k tie-breaking and
/// quantizer scales stay well-defined the way real updates are).
struct F32Vec {
    min_len: usize,
    max_len: usize,
}

impl Strategy for F32Vec {
    type Value = Vec<f64>;
    fn generate(&self, rng: &mut Rng) -> Vec<f64> {
        let inner = VecF64 { min_len: self.min_len, max_len: self.max_len, lo: -8.0, hi: 8.0 };
        inner
            .generate(rng)
            .into_iter()
            .map(|v| if v.abs() < 1e-3 { v + 0.01 } else { v })
            .collect()
    }
    fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
        if v.len() > self.min_len {
            vec![v[..v.len() - 1].to_vec(), v[..self.min_len.max(v.len() / 2)].to_vec()]
        } else {
            Vec::new()
        }
    }
}

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

#[test]
fn dense_round_trip_is_exact() {
    forall(101, 200, &F32Vec { min_len: 1, max_len: 256 }, |v| {
        let mut data = to_f32(v);
        let orig = data.clone();
        let bytes = Dense.compress(&mut data);
        if data != orig {
            return Err("dense changed values".into());
        }
        if bytes != 4 * data.len() {
            return Err(format!("dense bytes {bytes} != {}", 4 * data.len()));
        }
        Ok(())
    });
}

#[test]
fn quantizer_wire_size_upper_bound() {
    for bits in [1u32, 4, 8, 16] {
        forall(200 + bits as u64, 100, &F32Vec { min_len: 1, max_len: 4096 }, |v| {
            let mut q = Quantizer::new(bits, 7);
            let mut data = to_f32(v);
            let n = data.len();
            let bytes = q.compress(&mut data);
            if bytes != q.encoded_bytes(n) {
                return Err(format!("bytes {bytes} != encoded_bytes {}", q.encoded_bytes(n)));
            }
            // payload: bits/8 per value rounded up; scales: one f32 per 1024
            let bound = (n * bits as usize).div_ceil(8) + n.div_ceil(1024) * 4;
            if bytes > bound {
                return Err(format!("q{bits}: {bytes} bytes > bound {bound} for n={n}"));
            }
            // dense is never beaten by 16-bit+scales on tiny inputs, but 8
            // bits or fewer must strictly shrink anything >= one chunk
            if bits <= 8 && n >= 1024 && bytes >= 4 * n {
                return Err(format!("q{bits} did not compress: {bytes} >= {}", 4 * n));
            }
            Ok(())
        });
    }
}

#[test]
fn quantizer_error_bounded_by_one_level() {
    for bits in [2u32, 4, 8] {
        forall(300 + bits as u64, 100, &F32Vec { min_len: 1, max_len: 600 }, |v| {
            let mut q = Quantizer::new(bits, 11);
            let orig = to_f32(v);
            let mut data = orig.clone();
            q.compress(&mut data);
            let levels = ((1u32 << bits) - 1) as f32;
            for chunk_start in (0..orig.len()).step_by(1024) {
                let end = (chunk_start + 1024).min(orig.len());
                let max =
                    orig[chunk_start..end].iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let tol = max / levels + 1e-5;
                for i in chunk_start..end {
                    let err = (orig[i] - data[i]).abs();
                    if err > tol {
                        return Err(format!(
                            "q{bits}: |{} - {}| = {err} > one level {tol}",
                            orig[i], data[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}

#[test]
fn quantizer_decode_is_idempotent_up_to_one_level() {
    // Re-encoding a decoded vector lands on the same grid: values stay
    // within one quantization level (exact equality can be broken only by
    // f32 rounding at grid boundaries + stochastic rounding).
    forall(401, 150, &F32Vec { min_len: 1, max_len: 512 }, |v| {
        let mut q = Quantizer::new(8, 13);
        let mut first = to_f32(v);
        q.compress(&mut first);
        let mut second = first.clone();
        let b1 = q.compress(&mut second);
        if b1 != q.encoded_bytes(first.len()) {
            return Err("second pass changed wire size".into());
        }
        let levels = 255.0f32;
        for chunk_start in (0..first.len()).step_by(1024) {
            let end = (chunk_start + 1024).min(first.len());
            let max = first[chunk_start..end].iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let tol = max / levels * 1.01 + 1e-5;
            for i in chunk_start..end {
                if (first[i] - second[i]).abs() > tol {
                    return Err(format!(
                        "re-encode moved {} -> {} (> one level {tol})",
                        first[i], second[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn topk_wire_size_upper_bound_and_support() {
    for &ratio in &[0.01f64, 0.1, 0.25] {
        forall((ratio * 1000.0) as u64 + 500, 100, &F32Vec { min_len: 2, max_len: 800 }, |v| {
            let mut t = TopK::new(ratio);
            let mut data = to_f32(v);
            let n = data.len();
            let orig = data.clone();
            let bytes = t.compress(&mut data);
            let k = t.kept(n);
            // 4B value + 4B index per kept entry, never more than dense
            if bytes > k * 8 {
                return Err(format!("top{ratio}: {bytes} > {} for n={n}", k * 8));
            }
            if bytes > 8 * n {
                return Err("worse than dense+indices".into());
            }
            let nonzero = data.iter().filter(|&&x| x != 0.0).count();
            if nonzero > k {
                return Err(format!("kept {nonzero} > k={k}"));
            }
            // kept values are unchanged originals
            for (a, b) in data.iter().zip(&orig) {
                if *a != 0.0 && a != b {
                    return Err("kept value was altered".into());
                }
            }
            Ok(())
        });
    }
}

#[test]
fn topk_decode_is_exactly_idempotent() {
    forall(601, 150, &F32Vec { min_len: 4, max_len: 800 }, |v| {
        let mut t = TopK::new(0.1);
        let mut first = to_f32(v);
        let b1 = t.compress(&mut first);
        let mut second = first.clone();
        let b2 = t.compress(&mut second);
        if first != second {
            return Err("top-k re-encode changed the vector".into());
        }
        if b2 > b1 {
            return Err(format!("re-encode grew: {b1} -> {b2}"));
        }
        Ok(())
    });
}

#[test]
fn compressor_parse_round_trips_names() {
    for spec in ["dense", "q4", "q8", "q16", "top1", "top10", "top100"] {
        let c = fedlama::comm::parse_compressor(spec, 1)
            .unwrap_or_else(|| panic!("spec {spec} should parse"));
        let mut v = vec![1.0f32, -2.0, 3.0, -4.0];
        let bytes = {
            let mut c = c;
            c.compress(&mut v)
        };
        assert!(bytes > 0, "{spec}: zero wire size");
    }
    assert!(fedlama::comm::parse_compressor("q0", 1).is_none());
    assert!(fedlama::comm::parse_compressor("top0", 1).is_none());
    assert!(fedlama::comm::parse_compressor("gzip", 1).is_none());
}
