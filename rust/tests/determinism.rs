//! Cluster determinism: `threads = N` must be **bit-identical** to
//! `threads = 1` — same global parameters, same losses, same metrics —
//! because each client's RNG stream, parameter state, and f32 accumulation
//! order are independent of worker scheduling, and aggregation always runs
//! on the coordinator thread in a fixed order.

use fedlama::aggregation::Policy;
use fedlama::config::{Algorithm, PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::RunMetrics;

fn base_cfg() -> RunConfig {
    RunConfig {
        dataset: DatasetKind::Toy,
        n_clients: 8,
        active_ratio: 1.0,
        partition: PartitionKind::Dirichlet { alpha: 0.3 },
        samples: 64,
        lr: 0.05,
        warmup_rounds: 2,
        iterations: 96,
        policy: Policy::fedlama(6, 2),
        eval_every_rounds: 4,
        eval_examples: 256,
        seed: 17,
        ..Default::default()
    }
}

/// Everything except wall-clock fields must match exactly.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.tag, b.tag, "{what}: tag");
    assert_eq!(a.curve, b.curve, "{what}: learning curve");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final_loss");
    assert_eq!(a.total_comm_cost, b.total_comm_cost, "{what}: comm cost");
    assert_eq!(a.total_syncs, b.total_syncs, "{what}: syncs");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: bytes");
    assert_eq!(a.per_group, b.per_group, "{what}: per-group ledger");
}

fn run_with_threads(cfg: &RunConfig, threads: usize) -> (Coordinator, RunMetrics) {
    let cfg = RunConfig { threads, ..cfg.clone() };
    let mut coord = Coordinator::new(cfg).unwrap();
    let metrics = coord.run().unwrap();
    (coord, metrics)
}

fn assert_threads_bit_identical(cfg: RunConfig, threads: usize, what: &str) {
    let (serial, m1) = run_with_threads(&cfg, 1);
    let (parallel, mn) = run_with_threads(&cfg, threads);
    assert_metrics_identical(&m1, &mn, what);
    for (gt, (a, b)) in serial.global().iter().zip(parallel.global()).enumerate() {
        assert_eq!(a.data, b.data, "{what}: global tensor {gt} diverged at threads={threads}");
    }
    for (a, b) in serial.clients().iter().zip(parallel.clients()) {
        assert_eq!(a.steps_in_round, b.steps_in_round, "{what}: client step counts");
        for (ta, tb) in a.params.iter().zip(&b.params) {
            assert_eq!(ta.data, tb.data, "{what}: client {} params diverged", a.id);
        }
    }
}

#[test]
fn threads8_bit_identical_sgd_fedlama() {
    assert_threads_bit_identical(base_cfg(), 8, "sgd/fedlama(6,2)");
}

#[test]
fn threads8_bit_identical_scaffold() {
    let cfg = RunConfig {
        algorithm: Algorithm::Scaffold,
        policy: Policy::fedavg(6),
        iterations: 48,
        use_chunk: false,
        ..base_cfg()
    };
    assert_threads_bit_identical(cfg, 8, "scaffold/fedavg(6)");
}

#[test]
fn odd_thread_counts_and_partial_participation_are_identical() {
    // 3 workers over 4 active clients exercises uneven chunking; partial
    // participation exercises the moved-out/restored client bookkeeping.
    let cfg = RunConfig {
        active_ratio: 0.5,
        policy: Policy::fedlama(6, 2),
        iterations: 48,
        ..base_cfg()
    };
    assert_threads_bit_identical(cfg, 3, "sgd/partial-participation");
    // threads beyond the active-client count clamp without changing results
    let cfg = RunConfig { active_ratio: 0.5, iterations: 24, ..base_cfg() };
    assert_threads_bit_identical(cfg, 64, "sgd/threads>clients");
}

#[test]
fn auto_threads_is_identical_too() {
    // threads = 0 resolves to available_parallelism - 2; whatever that is
    // on the host, results must not change.
    let cfg = RunConfig { iterations: 48, ..base_cfg() };
    assert_threads_bit_identical(cfg, 0, "sgd/auto-threads");
}
