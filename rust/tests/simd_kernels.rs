//! Bit-identity oracle tests for the `runtime::simd` matmul paths.
//!
//! The contract under test: every dispatch path (AVX2 / SSE2 / scalar)
//! produces **bitwise identical** results for `matmul_acc`,
//! `matmul_at_acc`, and `matmul_bt` on every shape — including remainder
//! lanes (`n % lane_width != 0`), single-row batches (`m = 1`), k
//! spanning multiple KC tiles, and mixed sparse/dense rows.  These run in
//! CI twice: with default flags and with `RUSTFLAGS=-Ctarget-cpu=native`.

use fedlama::runtime::ops::matmul::{matmul_acc_with, matmul_at_acc_with, matmul_bt_with};
use fedlama::runtime::simd::{self, Isa};
use fedlama::util::prop::{forall, Pair, UsizeIn};
use fedlama::util::rng::Rng;

/// Deterministic inputs for a shape: ~25% of a's entries are zeroed so
/// both the sparse-skip and the dense fast path get exercised.
fn inputs(m: usize, k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for v in a.iter_mut() {
        if rng.below(4) == 0 {
            *v = 0.0;
        }
    }
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let dy: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let c0: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    (a, b, dy, c0)
}

/// Compare every supported path against the scalar reference, bitwise.
fn check_shape(m: usize, k: usize, n: usize, seed: u64) -> Result<(), String> {
    let (a, b, dy, c0) = inputs(m, k, n, seed);

    let mut c_want = c0.clone();
    matmul_acc_with(Isa::Scalar, &a, &b, &mut c_want, m, k, n);
    let mut gw_want = vec![0.0f32; k * n];
    matmul_at_acc_with(Isa::Scalar, &a, &dy, &mut gw_want, m, k, n);
    let mut dx_want = vec![0.0f32; m * k];
    matmul_bt_with(Isa::Scalar, &dy, &b, &mut dx_want, m, n, k);

    for isa in simd::supported_isas() {
        let mut c = c0.clone();
        matmul_acc_with(isa, &a, &b, &mut c, m, k, n);
        if c != c_want {
            return Err(format!("matmul_acc diverged on {} (m={m} k={k} n={n})", isa.name()));
        }
        let mut gw = vec![0.0f32; k * n];
        matmul_at_acc_with(isa, &a, &dy, &mut gw, m, k, n);
        if gw != gw_want {
            return Err(format!("matmul_at_acc diverged on {} (m={m} k={k} n={n})", isa.name()));
        }
        // stale dx contents must be fully overwritten on every path
        let mut dx = vec![-7.5f32; m * k];
        matmul_bt_with(isa, &dy, &b, &mut dx, m, n, k);
        if dx != dx_want {
            return Err(format!("matmul_bt diverged on {} (m={m} k={k} n={n})", isa.name()));
        }
    }
    Ok(())
}

#[test]
fn random_shapes_are_bit_identical_across_paths() {
    // n up to 19 covers every AVX2/SSE2 remainder class; k up to 70
    // covers every bt panel remainder; m = 1 occurs with p ~ 1/6.
    let mk = Pair(UsizeIn { lo: 1, hi: 6 }, UsizeIn { lo: 1, hi: 70 });
    let shapes = Pair(mk, UsizeIn { lo: 1, hi: 19 });
    forall(42, 60, &shapes, |&((m, k), n)| check_shape(m, k, n, (m * 1000 + k * 10 + n) as u64));
}

#[test]
fn kc_tile_spanning_and_edge_shapes() {
    // (m, k, n): k = 513/600 spans 2-3 KC=256 tiles; m = 1 single-row;
    // n = 1/3/5 below and between lane widths; n = 8/16 exact lanes.
    for &(m, k, n) in &[
        (1usize, 513usize, 9usize),
        (1, 600, 3),
        (2, 600, 5),
        (3, 256, 8),
        (4, 257, 16),
        (5, 512, 1),
        (1, 1, 1),
        (8, 32, 64),
    ] {
        check_shape(m, k, n, 7 + k as u64).unwrap();
    }
}

#[test]
fn all_zero_and_all_dense_rows_are_bit_identical() {
    // fully dense a (no skip anywhere) and fully zero a (skip everything)
    let (m, k, n) = (3, 300, 10);
    let mut rng = Rng::new(5);
    let dense: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.5, 1.0) + 2.0).collect();
    let zeros = vec![0.0f32; m * k];
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for a in [&dense, &zeros] {
        let c0: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut want = c0.clone();
        matmul_acc_with(Isa::Scalar, a, &b, &mut want, m, k, n);
        for isa in simd::supported_isas() {
            let mut c = c0.clone();
            matmul_acc_with(isa, a, &b, &mut c, m, k, n);
            assert_eq!(c, want, "diverged on {}", isa.name());
        }
    }
    // the all-zero input leaves c untouched (the value-preserving skip)
    let c0: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut c = c0.clone();
    matmul_acc_with(fedlama::runtime::simd::active_isa(), &zeros, &b, &mut c, m, k, n);
    assert_eq!(c, c0);
}

#[test]
fn dispatch_reports_a_supported_isa() {
    let isa = simd::active_isa();
    assert!(simd::supported_isas().contains(&isa));
    // On x86-64, SSE2 is architecturally guaranteed: the ladder must
    // never fall through to scalar unless forced via FEDLAMA_SIMD.
    #[cfg(target_arch = "x86_64")]
    if std::env::var("FEDLAMA_SIMD").is_err() {
        assert_ne!(isa, Isa::Scalar, "x86-64 must dispatch a wide path");
    }
}
