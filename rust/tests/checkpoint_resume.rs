//! Checkpoint/resume: a run interrupted at a round boundary and resumed
//! from its `--checkpoint-dir` snapshot must be **bit-identical** to the
//! run that was never interrupted — same curve, same global tensors, same
//! Eq.9 ledger — because the snapshot restores the core state machine
//! (schedule, ledger, sampler rng, registry) exactly and every
//! participant fast-forwards its client rng streams past the committed
//! blocks.  Exercised in-proc and over the `--workers N` stdio transport
//! (the TCP path shares the worker-side code via the Configure frame).

use std::path::PathBuf;

use fedlama::aggregation::Policy;
use fedlama::config::{Algorithm, PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::RunMetrics;

fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedlama_ckpt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn base_cfg() -> RunConfig {
    RunConfig {
        dataset: DatasetKind::Toy,
        n_clients: 6,
        active_ratio: 0.5,
        partition: PartitionKind::Dirichlet { alpha: 0.3 },
        samples: 48,
        lr: 0.05,
        warmup_rounds: 1,
        iterations: 24,
        policy: Policy::fedlama(2, 2),
        eval_every_rounds: 2,
        eval_examples: 128,
        seed: 23,
        ..Default::default()
    }
}

fn run_cfg(cfg: RunConfig) -> (Coordinator, RunMetrics) {
    let mut coord = Coordinator::new(cfg).unwrap();
    let m = coord.run().unwrap();
    (coord, m)
}

/// Everything wall-clock-independent must match exactly.
fn assert_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.curve, b.curve, "{what}: learning curve");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final_loss");
    assert_eq!(a.total_comm_cost, b.total_comm_cost, "{what}: comm cost");
    assert_eq!(a.total_syncs, b.total_syncs, "{what}: syncs");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: bytes");
    assert_eq!(a.per_group, b.per_group, "{what}: per-group ledger");
    assert_eq!(a.per_client, b.per_client, "{what}: per-client ledger");
}

fn assert_resume_bit_identical(cfg: RunConfig, halt_after: usize, what: &str) {
    let dir = ckpt_dir(what);

    // the uninterrupted reference (no checkpointing in sight)
    let (ref_coord, ref_m) = run_cfg(cfg.clone());

    // interrupted run: checkpoint every round, stop after `halt_after`
    let (_, halted) = run_cfg(RunConfig {
        checkpoint_dir: Some(dir.clone()),
        halt_after_rounds: halt_after,
        ..cfg.clone()
    });
    assert!(
        halted.curve.len() < ref_m.curve.len(),
        "{what}: the interrupted run must actually stop early"
    );
    assert!(fedlama::registry::checkpoint::exists(&dir), "{what}: no snapshot written");

    // resumed run: picks up from the snapshot and finishes the schedule
    let (res_coord, res_m) = run_cfg(RunConfig {
        checkpoint_dir: Some(dir.clone()),
        resume: true,
        ..cfg
    });
    assert_identical(&ref_m, &res_m, what);
    for (gt, (a, b)) in ref_coord.global().iter().zip(res_coord.global()).enumerate() {
        assert_eq!(a.data, b.data, "{what}: global tensor {gt} diverged after resume");
    }
    // the resumed process only timed the rounds it actually ran
    assert!(
        res_m.round_wall_secs.len() < ref_m.round_wall_secs.len(),
        "{what}: resume re-ran rounds it should have skipped"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: in-proc resume after 2 of 6 rounds, with client sampling
/// active (the sampler rng snapshot and the participant's active-set
/// replay both matter here).
#[test]
fn resume_is_bit_identical_in_proc() {
    assert_resume_bit_identical(base_cfg(), 2, "inproc");
}

/// Resume composes with heterogeneous local budgets and FedProx: the
/// fast-forward replay must reproduce each client's per-round step budget
/// to consume exactly the right number of data draws.
#[test]
fn resume_is_bit_identical_under_hetero_fedprox() {
    let cfg = RunConfig {
        algorithm: Algorithm::Prox { mu: 0.02 },
        hetero_local_steps: true,
        ..base_cfg()
    };
    assert_resume_bit_identical(cfg, 3, "hetero");
}

/// Resume over the multi-process transport: `resume_blocks` rides the
/// Configure frame, so every worker subprocess fast-forwards its shard's
/// client rngs exactly as the in-proc participant does.
#[test]
fn resume_is_bit_identical_with_workers() {
    let cfg = RunConfig { workers: 2, ..base_cfg() };
    assert_resume_bit_identical(cfg, 2, "workers");
}

/// Divergence-feedback's skip decision depends on discrepancies observed
/// in earlier rounds; the snapshot carries the observation flags and last
/// measured values, so a resumed run skips exactly the groups the
/// uninterrupted run skips — byte totals included.
#[test]
fn resume_is_bit_identical_under_divergence_feedback() {
    let cfg = RunConfig {
        policy: Policy::divergence_feedback(2, 2, 0.05),
        ..base_cfg()
    };
    assert_resume_bit_identical(cfg, 2, "divfb");
}

/// SCAFFOLD is the hard case for resume: the server control s_t and every
/// client's control variate c_i must come back out of the snapshot (the
/// registry spills them; the coordinator re-broadcasts both as catch-up
/// `ControlUpdate`/`AlgoState` frames) or the resumed run drifts silently.
#[test]
fn resume_restores_scaffold_control_variates() {
    let cfg = RunConfig {
        algorithm: Algorithm::Scaffold,
        use_chunk: false,
        ..base_cfg()
    };
    assert_resume_bit_identical(cfg, 2, "scaffold");
}

/// FedNova's normalized fold is recomputed from wire state each round, so
/// resume only needs the core snapshot — but the heterogeneous step
/// budgets make the participant fast-forward replay earn its keep.
#[test]
fn resume_is_bit_identical_under_fednova() {
    let cfg = RunConfig {
        algorithm: Algorithm::Nova,
        hetero_local_steps: true,
        use_chunk: false,
        ..base_cfg()
    };
    assert_resume_bit_identical(cfg, 3, "fednova");
}

/// The personalized policy keeps blended client replicas on participants —
/// state the snapshot cannot capture — so `--resume` refuses it loudly
/// instead of restarting every client from the restored global.
#[test]
fn personalized_resume_is_refused_loudly() {
    let cfg = RunConfig {
        policy: Policy::personalized(2, 0.25),
        checkpoint_dir: Some(ckpt_dir("personalized-refuse")),
        resume: true,
        ..base_cfg()
    };
    let err = cfg.validate().unwrap_err();
    assert!(format!("{err:#}").contains("personalized"), "{err:#}");
}

/// A snapshot only resumes the configuration that wrote it; drift is
/// refused loudly instead of silently diverging.
#[test]
fn resume_refuses_config_drift_and_missing_snapshots() {
    let dir = ckpt_dir("drift");
    let cfg = RunConfig {
        checkpoint_dir: Some(dir.clone()),
        halt_after_rounds: 1,
        ..base_cfg()
    };
    // resume before any snapshot exists: loud error, not a fresh run
    let err = Coordinator::new(RunConfig { resume: true, ..cfg.clone() })
        .err()
        .map(|e| format!("{e:#}"))
        .expect("resume without a snapshot must fail");
    assert!(err.contains("reading checkpoint"), "{err}");

    let (_, _) = run_cfg(cfg.clone());

    // same dir, different seed -> different config fingerprint
    let err = Coordinator::new(RunConfig { resume: true, seed: 99, ..cfg.clone() })
        .err()
        .map(|e| format!("{e:#}"))
        .expect("a drifted config must not resume");
    assert!(err.contains("different run configuration"), "{err}");

    // a worker-count change alters the ledger shape and is refused too
    let err = Coordinator::new(RunConfig { resume: true, workers: 2, ..cfg })
        .err()
        .map(|e| format!("{e:#}"))
        .expect("a worker-count change must not resume");
    assert!(err.contains("--workers"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The registry travels inside the snapshot: participation recorded
/// before the interruption survives into the resumed run's ledger.
#[test]
fn registry_state_survives_resume() {
    let dir = ckpt_dir("registry");
    let cfg = RunConfig { checkpoint_dir: Some(dir.clone()), ..base_cfg() };

    let (_, halted) = run_cfg(RunConfig { halt_after_rounds: 2, ..cfg.clone() });
    let pre: u64 = halted.per_client.iter().map(|(_, c)| c.updates).sum();
    assert!(pre > 0, "halted run recorded no participation");

    let (_, resumed) = run_cfg(RunConfig { resume: true, ..cfg });
    let post: u64 = resumed.per_client.iter().map(|(_, c)| c.updates).sum();
    assert!(
        post > pre,
        "resumed ledger must extend the snapshot's counters ({post} !> {pre})"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
