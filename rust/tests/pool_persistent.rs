//! Integration tests for the persistent worker pool (`util::pool`).
//!
//! The contract: `par_map{,_mut}` over the pool matches the serial
//! (`threads = 1`) path exactly — same outputs in the same order, same
//! item mutations — and the pool is actually persistent: repeated calls
//! reuse parked workers instead of spawning threads per call.
//!
//! Everything lives in one `#[test]` on purpose: the spawn-count
//! assertions read process-global pool state, which concurrent tests
//! would race on.

use fedlama::util::pool;

#[test]
fn pool_matches_serial_and_survives_repeated_calls() {
    // A spread of chunk widths first — this also grows the pool to its
    // high-water mark so the reuse assertion below is race-free.
    for threads in [2usize, 3, 8, 16] {
        let out = pool::par_map(57, threads, |i| i as u64 * i as u64 + 1);
        let want: Vec<u64> = (0..57).map(|i| i as u64 * i as u64 + 1).collect();
        assert_eq!(out, want, "threads={threads}");
    }
    let spawned_after_warmup = pool::workers_spawned_total();
    assert!(spawned_after_warmup >= 1, "parallel calls must have started the pool");

    // 100 reuse calls: serial vs pooled must agree bit-for-bit.
    for call in 0..100u64 {
        let n = 1 + (call as usize * 7) % 41; // vary sizes incl. n < threads
        let mk = || -> Vec<u64> { (0..n as u64).map(|i| i * 3 + call).collect() };

        let mut serial_items = mk();
        let serial_out = pool::par_map_mut(&mut serial_items, 1, |i, v| {
            *v = v.wrapping_mul(2) + 1;
            *v ^ i as u64
        });

        let mut pooled_items = mk();
        let pooled_out = pool::par_map_mut(&mut pooled_items, 4, |i, v| {
            *v = v.wrapping_mul(2) + 1;
            *v ^ i as u64
        });

        assert_eq!(pooled_out, serial_out, "outputs diverged at call {call}");
        assert_eq!(pooled_items, serial_items, "mutations diverged at call {call}");
    }

    // Persistence: the 100 threads=4 calls above ride the workers the
    // warmup already spawned (chunk 0 runs on the caller thread).
    assert_eq!(
        pool::workers_spawned_total(),
        spawned_after_warmup,
        "steady-state calls must not spawn new workers"
    );
    assert!(pool::pool_size() >= 1);

    // Clean shutdown parks everything; the next call respawns.
    pool::shutdown();
    assert_eq!(pool::pool_size(), 0);
    let out = pool::par_map(8, 2, |i| i + 10);
    assert_eq!(out, (10..18).collect::<Vec<_>>());
    assert!(pool::workers_spawned_total() > spawned_after_warmup, "respawn after shutdown");
}
