//! Byzantine-robust aggregation across transports, and the deterministic
//! fault-injection harness — the PR's acceptance bar:
//!
//!   - a robust `--aggregator` spec (screens + fold) must be
//!     **bit-identical** across in-proc, stdio `--workers N`, and TCP
//!     runs, because the fold orders rows by the survivor list and
//!     breaks ties by client id, never by arrival order;
//!   - a seeded `--chaos` payload attack is keyed by (seed, k, group,
//!     client), so two transports with the **same shard count** produce
//!     identical adversarial runs — including which updates the robust
//!     fold rejects and which shard the ledger charges them to;
//!   - wire-level faults (stall, corrupt-frame) live in the TCP write
//!     path only: stall never changes numerics, corrupt-frame departs
//!     exactly the attacked shard.
//!
//! Payload attacks key on *shard* id and the in-proc run is one shard, so
//! chaos comparisons here always pit equal shard counts against each
//! other (`--workers 3` vs a 3-participant TCP run); only chaos-free
//! robust runs are compared against the in-proc reference.

use std::thread;
use std::time::Duration;

use fedlama::aggregation::Policy;
use fedlama::config::RunConfig;
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::RunMetrics;
use fedlama::protocol::tcp::{self, JoinOpts, TcpOpts, TcpServer};

/// Point worker spawns at the fedlama binary (set once; tests share the
/// process environment).
fn use_test_binary() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| std::env::set_var("FEDLAMA_WORKER_EXE", env!("CARGO_BIN_EXE_fedlama")));
}

fn base_cfg() -> RunConfig {
    RunConfig {
        dataset: DatasetKind::Toy,
        n_clients: 6,
        samples: 64,
        lr: 0.05,
        warmup_rounds: 2,
        iterations: 24,
        policy: Policy::fedlama(6, 2),
        eval_every_rounds: 2,
        eval_examples: 256,
        seed: 23,
        ..Default::default()
    }
}

fn fast_opts() -> TcpOpts {
    TcpOpts {
        join_timeout: Duration::from_secs(60),
        io_timeout: Duration::from_secs(60),
        heartbeat_every: Duration::from_millis(50),
    }
}

fn join_opts() -> JoinOpts {
    JoinOpts {
        connect_retry: Duration::from_secs(10),
        io_timeout: Duration::from_secs(60),
        depart_after_blocks: None,
    }
}

/// Run `cfg` over localhost TCP with `n` participant threads.  Joiners
/// return `Result` so chaos tests can assert on deliberate failures.
fn run_tcp(cfg: &RunConfig, n: usize) -> (Coordinator, RunMetrics, Vec<anyhow::Result<usize>>) {
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..n)
        .map(|_| {
            let a = addr.clone();
            thread::spawn(move || tcp::join(&a, &join_opts()))
        })
        .collect();
    let cfg = RunConfig { workers: n, ..cfg.clone() };
    let mut coord = Coordinator::new(cfg).unwrap();
    let mut transport = server.accept_participants(&coord.cfg, n, &fast_opts()).unwrap();
    let metrics = coord.run_with_transport(&mut transport).unwrap();
    let outcomes: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    (coord, metrics, outcomes)
}

/// TCP run where every joiner must survive to Shutdown.
fn run_tcp_clean(cfg: &RunConfig, n: usize) -> (Coordinator, RunMetrics) {
    let (coord, metrics, outcomes) = run_tcp(cfg, n);
    let mut shards: Vec<usize> = outcomes.into_iter().map(|r| r.unwrap()).collect();
    shards.sort_unstable();
    assert_eq!(shards, (0..n).collect::<Vec<_>>(), "every shard served exactly once");
    (coord, metrics)
}

fn run_with_workers(cfg: &RunConfig, workers: usize) -> (Coordinator, RunMetrics) {
    if workers > 0 {
        use_test_binary();
    }
    let cfg = RunConfig { workers, ..cfg.clone() };
    let mut coord = Coordinator::new(cfg).unwrap();
    let metrics = coord.run().unwrap();
    (coord, metrics)
}

/// Everything except wall-clock (and the shard-count-dependent
/// per-participant table) must match exactly.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.tag, b.tag, "{what}: tag");
    assert_eq!(a.curve, b.curve, "{what}: learning curve");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final_loss");
    assert_eq!(a.total_comm_cost, b.total_comm_cost, "{what}: Eq.9 comm cost");
    assert_eq!(a.total_syncs, b.total_syncs, "{what}: syncs");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: bytes");
    assert_eq!(a.per_group, b.per_group, "{what}: per-group ledger");
}

fn assert_globals_identical(a: &Coordinator, b: &Coordinator, what: &str) {
    for (gt, (x, y)) in a.global().iter().zip(b.global()).enumerate() {
        assert_eq!(x.data, y.data, "{what}: global tensor {gt} diverged");
    }
}

#[test]
fn trimmed_fold_bit_identical_across_all_transports() {
    let cfg = RunConfig { aggregator: "trimmed:1".into(), ..base_cfg() };
    let (inproc, m0) = run_with_workers(&cfg, 0);
    let (multi, mw) = run_with_workers(&cfg, 2);
    let (over_tcp, mt) = run_tcp_clean(&cfg, 3);
    assert_metrics_identical(&m0, &mw, "trimmed:1 inproc vs workers=2");
    assert_metrics_identical(&m0, &mt, "trimmed:1 inproc vs tcp=3");
    assert_globals_identical(&inproc, &multi, "trimmed:1 workers=2");
    assert_globals_identical(&inproc, &over_tcp, "trimmed:1 tcp=3");
    // no attacker: the honest-majority fold still trims, but trims the
    // same rows everywhere
    let rej0: u64 = m0.per_participant.iter().map(|p| p.rejected_updates).sum();
    let rejt: u64 = mt.per_participant.iter().map(|p| p.rejected_updates).sum();
    assert_eq!(rej0, rejt, "trim charges are shard-count invariant in total");
}

#[test]
fn screened_median_bit_identical_inproc_vs_tcp() {
    // screens compose in front of a non-mean fold; both halves must obey
    // the same ordering contract
    let cfg = RunConfig { aggregator: "normclip:2+median".into(), ..base_cfg() };
    let (inproc, m0) = run_with_workers(&cfg, 0);
    let (over_tcp, mt) = run_tcp_clean(&cfg, 2);
    assert_metrics_identical(&m0, &mt, "normclip:2+median inproc vs tcp=2");
    assert_globals_identical(&inproc, &over_tcp, "normclip:2+median tcp=2");
}

#[test]
fn payload_attack_is_transport_invariant_at_equal_shard_counts() {
    // shard 0 (clients 0 and 3 of 6) sign-flips every uplink; trimmed:2
    // screens both forged rows out.  The stdio and TCP runs have the same
    // shard count, so the whole adversarial run — including the rejection
    // ledger — must match bit for bit.
    let cfg = RunConfig {
        aggregator: "trimmed:2".into(),
        chaos: "signflip:1".into(),
        ..base_cfg()
    };
    let (multi, mw) = run_with_workers(&cfg, 3);
    let (over_tcp, mt) = run_tcp_clean(&cfg, 3);
    assert_metrics_identical(&mw, &mt, "signflip:1+trimmed:2 workers=3 vs tcp=3");
    assert_globals_identical(&multi, &over_tcp, "signflip:1+trimmed:2 tcp=3");
    assert_eq!(
        mw.per_participant, mt.per_participant,
        "per-shard tables (incl. rejections) match across transports"
    );
    // attribution: every rejection lands on the attacking shard
    assert!(mt.per_participant[0].rejected_updates > 0, "attacker shard charged");
    for p in &mt.per_participant[1..] {
        assert_eq!(p.rejected_updates, 0, "honest shard {} never rejected", p.shard);
    }
}

#[test]
fn stall_wire_fault_is_numerics_inert() {
    // stall trickles shard 0's assignment frames through the TCP write
    // path; it may slow the run but must never change a single bit
    let clean = base_cfg();
    let stalled = RunConfig { chaos: "stall:1".into(), ..clean.clone() };
    let (a, ma) = run_tcp_clean(&clean, 2);
    let (b, mb) = run_tcp_clean(&stalled, 2);
    assert_metrics_identical(&ma, &mb, "stall:1 vs clean over tcp=2");
    assert_globals_identical(&a, &b, "stall:1 tcp=2");
}

#[test]
fn corrupt_frame_departs_exactly_the_attacked_shard() {
    // one flipped bit in shard 0's round-1 assignment frame: the peer's
    // CRC check rejects it, the connection drops, and the quorum engine
    // finishes the run on the surviving shard
    let cfg = RunConfig {
        quorum: 1,
        chaos: "corrupt-frame:1".into(),
        ..base_cfg()
    };
    let (_, m, outcomes) = run_tcp(&cfg, 2);
    let survivors: Vec<usize> = outcomes.into_iter().filter_map(|r| r.ok()).collect();
    assert_eq!(survivors, vec![1], "shard 1 survives to Shutdown; shard 0's join errors");
    assert_eq!(m.per_participant[0].departures, 1, "attacked shard departs once");
    assert!(m.per_participant[0].missed_blocks >= 1, "attacked shard misses blocks");
    assert_eq!(m.per_participant[1].departures, 0, "surviving shard never departs");
    assert!(m.final_loss.is_finite(), "run completes under quorum=1");
}
