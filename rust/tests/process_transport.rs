//! Multi-process transport determinism: a training run sharded across N
//! `fedlama worker` subprocesses must be **bit-identical** to the in-proc
//! single-process run — same final accuracy, same loss curve, same Eq. 9
//! ledger totals — because every numeric stream is keyed by *what* is
//! computed (client id, message identity), never by *where*:
//!
//!   - client RNGs derive from the global client id,
//!   - workers rebuild the data partition and model init from the seed,
//!   - the coordinator core orders every cross-client reduction by the
//!     active list, and
//!   - compression streams derive from (seed, k, group, client).
//!
//! These tests spawn real subprocesses of the `fedlama` binary (cargo
//! exposes its path to integration tests via `CARGO_BIN_EXE_fedlama`).

use fedlama::aggregation::Policy;
use fedlama::config::{Algorithm, PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::RunMetrics;

/// Point worker spawns at the fedlama binary (the test harness itself is
/// not the CLI, so `current_exe` would be wrong here).  Set exactly once:
/// tests run on parallel threads and the environment is process-global.
fn use_test_binary() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| std::env::set_var("FEDLAMA_WORKER_EXE", env!("CARGO_BIN_EXE_fedlama")));
}

fn base_cfg() -> RunConfig {
    RunConfig {
        dataset: DatasetKind::Toy,
        n_clients: 6,
        samples: 64,
        lr: 0.05,
        warmup_rounds: 2,
        iterations: 48,
        policy: Policy::fedlama(6, 2),
        eval_every_rounds: 2,
        eval_examples: 256,
        seed: 23,
        ..Default::default()
    }
}

fn run_with_workers(cfg: &RunConfig, workers: usize) -> (Coordinator, RunMetrics) {
    let cfg = RunConfig { workers, ..cfg.clone() };
    let mut coord = Coordinator::new(cfg).unwrap();
    let metrics = coord.run().unwrap();
    (coord, metrics)
}

/// Everything except wall-clock fields must match exactly.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.tag, b.tag, "{what}: tag");
    assert_eq!(a.curve, b.curve, "{what}: learning curve");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final_loss");
    assert_eq!(a.total_comm_cost, b.total_comm_cost, "{what}: Eq.9 comm cost");
    assert_eq!(a.total_syncs, b.total_syncs, "{what}: syncs");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: bytes");
    assert_eq!(a.per_group, b.per_group, "{what}: per-group ledger");
}

fn assert_workers_bit_identical(cfg: RunConfig, workers: usize, what: &str) {
    use_test_binary();
    let (inproc, m0) = run_with_workers(&cfg, 0);
    let (multi, mn) = run_with_workers(&cfg, workers);
    assert_metrics_identical(&m0, &mn, what);
    for (gt, (a, b)) in inproc.global().iter().zip(multi.global()).enumerate() {
        assert_eq!(
            a.data, b.data,
            "{what}: global tensor {gt} diverged with {workers} workers"
        );
    }
    // the per-participant ledger has one slot per shard; its totals are
    // invariant to the shard count (the fold just partitions the traffic)
    assert_eq!(m0.per_participant.len(), 1, "{what}: in-proc is one shard");
    assert_eq!(mn.per_participant.len(), workers, "{what}: one slot per worker");
    let p0 = &m0.per_participant[0];
    let un: u64 = mn.per_participant.iter().map(|p| p.updates).sum();
    let upn: u64 = mn.per_participant.iter().map(|p| p.uplink_bytes).sum();
    let downn: u64 = mn.per_participant.iter().map(|p| p.downlink_bytes).sum();
    assert_eq!(un, p0.updates, "{what}: per-participant update total");
    assert_eq!(upn, p0.uplink_bytes, "{what}: per-participant uplink total");
    assert_eq!(downn, p0.downlink_bytes, "{what}: per-participant downlink total");
}

#[test]
fn two_workers_bit_identical_fedlama() {
    assert_workers_bit_identical(base_cfg(), 2, "sgd/fedlama(6,2)/workers=2");
}

#[test]
fn three_workers_partial_participation_bit_identical() {
    // 3 shards over 6 clients with only half active per round exercises
    // shard/active intersection bookkeeping; worker count need not divide
    // anything.
    let cfg = RunConfig {
        active_ratio: 0.5,
        partition: PartitionKind::Dirichlet { alpha: 0.3 },
        ..base_cfg()
    };
    assert_workers_bit_identical(cfg, 3, "sgd/partial/workers=3");
    // more workers than clients: surplus workers own empty shards
    let cfg = RunConfig { n_clients: 3, iterations: 24, ..base_cfg() };
    assert_workers_bit_identical(cfg, 5, "sgd/workers>clients");
}

#[test]
fn compressed_uplink_is_transport_invariant() {
    // q-bit quantization draws from a stochastic-rounding RNG; streams are
    // keyed per (seed, k, group, client), so the multi-process run must
    // reproduce the in-proc lossy values bit-for-bit.
    let cfg = RunConfig { compressor: "q8".into(), ..base_cfg() };
    assert_workers_bit_identical(cfg, 2, "q8/workers=2");
    let cfg = RunConfig { compressor: "top10".into(), ..base_cfg() };
    assert_workers_bit_identical(cfg, 2, "top10/workers=2");
}

#[test]
fn fedprox_hetero_bit_identical() {
    let cfg = RunConfig {
        algorithm: Algorithm::Prox { mu: 0.01 },
        policy: Policy::fedavg(6),
        hetero_local_steps: true,
        partition: PartitionKind::Dirichlet { alpha: 0.3 },
        iterations: 24,
        ..base_cfg()
    };
    assert_workers_bit_identical(cfg, 2, "fedprox/hetero/workers=2");
}

#[test]
fn worker_threads_compose_with_process_sharding() {
    // threads > 1 inside each worker process must stay bit-identical too
    // (the per-client fan-out is order-preserving at both levels).
    use_test_binary();
    let cfg = base_cfg();
    let (_, reference) = run_with_workers(&cfg, 0);
    let threaded = RunConfig { threads: 4, ..cfg };
    let (_, m) = run_with_workers(&threaded, 2);
    assert_metrics_identical(&reference, &m, "workers=2 x threads=4");
}

#[test]
fn scaffold_bit_identical_across_workers() {
    // SCAFFOLD's control variates ride the wire as AlgoState/ControlUpdate
    // frames and the server fold runs on the coordinator in active order,
    // so the multiprocess run must match in-proc bit-for-bit.
    let cfg = RunConfig {
        algorithm: Algorithm::Scaffold,
        policy: Policy::fedavg(6),
        iterations: 24,
        use_chunk: false,
        ..base_cfg()
    };
    assert_workers_bit_identical(cfg, 2, "scaffold/workers=2");
}

#[test]
fn fednova_bit_identical_across_workers() {
    // FedNova ships each client's raw round delta + step count; the
    // normalized fold happens coordinator-side, so sharding cannot change
    // the numerics — even with heterogeneous local step budgets.
    let cfg = RunConfig {
        algorithm: Algorithm::Nova,
        policy: Policy::fedavg(6),
        hetero_local_steps: true,
        iterations: 24,
        use_chunk: false,
        ..base_cfg()
    };
    assert_workers_bit_identical(cfg, 2, "fednova/hetero/workers=2");
}

#[test]
fn divergence_feedback_bit_identical_and_cheaper_uplink() {
    // the uplink-skip decision is coordinator state (observed
    // discrepancies live in the schedule), so the same groups skip on
    // every transport; a generous threshold must actually cut bytes
    let base = RunConfig {
        partition: PartitionKind::Dirichlet { alpha: 0.1 },
        ..base_cfg()
    };
    let plain = RunConfig { policy: Policy::fedlama(6, 2), ..base.clone() };
    let skipping = RunConfig {
        policy: Policy::divergence_feedback(6, 2, f64::MAX),
        ..base.clone()
    };
    assert_workers_bit_identical(skipping.clone(), 2, "divfb/workers=2");
    let (_, m_plain) = run_with_workers(&plain, 0);
    let (_, m_skip) = run_with_workers(&skipping, 0);
    assert!(
        m_skip.total_bytes < m_plain.total_bytes,
        "an always-skip threshold must reduce uplink bytes: {} !< {}",
        m_skip.total_bytes,
        m_plain.total_bytes
    );
    assert!(
        m_skip.total_comm_cost < m_plain.total_comm_cost,
        "and the Eq.9 ledger must agree: {} !< {}",
        m_skip.total_comm_cost,
        m_plain.total_comm_cost
    );
}

#[test]
fn divergence_feedback_threshold_zero_matches_fedlama_end_to_end() {
    // threshold 0 means no observed discrepancy can fall below it, so no
    // group ever skips: the whole run — curve, globals, ledger — must be
    // byte-identical to plain fedlama (only the report tag differs)
    let base = RunConfig {
        partition: PartitionKind::Dirichlet { alpha: 0.1 },
        ..base_cfg()
    };
    let plain = RunConfig { policy: Policy::fedlama(6, 2), ..base.clone() };
    let zeroed = RunConfig { policy: Policy::divergence_feedback(6, 2, 0.0), ..base };
    let (c_plain, m_plain) = run_with_workers(&plain, 0);
    let (c_zero, m_zero) = run_with_workers(&zeroed, 0);
    assert_eq!(m_plain.curve, m_zero.curve, "threshold=0: learning curve");
    assert_eq!(m_plain.final_acc, m_zero.final_acc, "threshold=0: final_acc");
    assert_eq!(m_plain.final_loss, m_zero.final_loss, "threshold=0: final_loss");
    assert_eq!(m_plain.total_comm_cost, m_zero.total_comm_cost, "threshold=0: Eq.9 cost");
    assert_eq!(m_plain.total_syncs, m_zero.total_syncs, "threshold=0: syncs");
    assert_eq!(m_plain.total_bytes, m_zero.total_bytes, "threshold=0: bytes");
    assert_eq!(m_plain.per_group, m_zero.per_group, "threshold=0: per-group ledger");
    for (gt, (a, b)) in c_plain.global().iter().zip(c_zero.global()).enumerate() {
        assert_eq!(a.data, b.data, "threshold=0: global tensor {gt} diverged");
    }
}

#[test]
fn personalized_bit_identical_across_workers() {
    // per-client lambda updates fold on the coordinator (registry-backed)
    // and ride SyncDecision.mix; participants only apply their own weight
    let cfg = RunConfig {
        policy: Policy::personalized(6, 0.25),
        partition: PartitionKind::Dirichlet { alpha: 0.3 },
        iterations: 24,
        ..base_cfg()
    };
    assert_workers_bit_identical(cfg, 2, "personalized/workers=2");
}
