//! TCP transport: loopback handshake, failure surfacing, and the
//! acceptance bar — an N-participant TCP run over localhost must be
//! **bit-identical** to the in-proc run (and therefore to the stdio
//! `--workers N` run, which `tests/process_transport.rs` pins to the same
//! reference), including compressed uplinks.
//!
//! Participants here run as in-process threads calling
//! `protocol::tcp::join` — the exact code path `fedlama join` executes —
//! so the suite needs no subprocesses and no free fixed ports (everything
//! binds 127.0.0.1:0).

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread;
use std::time::Duration;

use fedlama::aggregation::Policy;
use fedlama::config::RunConfig;
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::RunMetrics;
use fedlama::protocol::tcp::{self, JoinOpts, TcpOpts, TcpServer};
use fedlama::protocol::wire::StreamDecoder;
use fedlama::protocol::{Heartbeat, Hello, Message, WIRE_VERSION};

fn base_cfg() -> RunConfig {
    RunConfig {
        dataset: DatasetKind::Toy,
        n_clients: 6,
        samples: 64,
        lr: 0.05,
        warmup_rounds: 2,
        iterations: 24,
        policy: Policy::fedlama(6, 2),
        eval_every_rounds: 2,
        eval_examples: 256,
        seed: 23,
        ..Default::default()
    }
}

fn fast_opts() -> TcpOpts {
    TcpOpts {
        join_timeout: Duration::from_secs(60),
        io_timeout: Duration::from_secs(60),
        heartbeat_every: Duration::from_millis(50),
    }
}

fn join_opts() -> JoinOpts {
    JoinOpts {
        connect_retry: Duration::from_secs(10),
        io_timeout: Duration::from_secs(60),
        depart_after_blocks: None,
    }
}

/// `tcp::join` from a thread after `delay`, optionally departing cleanly
/// after `depart_after` served blocks; returns the shard served.
fn spawn_join(
    addr: String,
    delay: Duration,
    depart_after: Option<usize>,
) -> thread::JoinHandle<usize> {
    thread::spawn(move || {
        thread::sleep(delay);
        let opts = JoinOpts { depart_after_blocks: depart_after, ..join_opts() };
        tcp::join(&addr, &opts).unwrap()
    })
}

/// Run `cfg` over a real localhost TCP federation with `n` participant
/// threads; returns the coordinator (for global-tensor access) + metrics.
fn run_tcp(cfg: &RunConfig, n: usize) -> (Coordinator, RunMetrics) {
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..n)
        .map(|_| {
            let a = addr.clone();
            thread::spawn(move || tcp::join(&a, &join_opts()).unwrap())
        })
        .collect();
    let cfg = RunConfig { workers: n, ..cfg.clone() };
    let mut coord = Coordinator::new(cfg).unwrap();
    let mut transport = server.accept_participants(&coord.cfg, n, &fast_opts()).unwrap();
    let metrics = coord.run_with_transport(&mut transport).unwrap();
    let mut shards: Vec<usize> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    shards.sort_unstable();
    assert_eq!(shards, (0..n).collect::<Vec<_>>(), "every shard served exactly once");
    (coord, metrics)
}

fn run_inproc(cfg: &RunConfig) -> (Coordinator, RunMetrics) {
    let cfg = RunConfig { workers: 0, ..cfg.clone() };
    let mut coord = Coordinator::new(cfg).unwrap();
    let metrics = coord.run().unwrap();
    (coord, metrics)
}

/// Everything except wall-clock (and the shard-count-dependent
/// per-participant table) must match exactly.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.tag, b.tag, "{what}: tag");
    assert_eq!(a.curve, b.curve, "{what}: learning curve");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.final_loss, b.final_loss, "{what}: final_loss");
    assert_eq!(a.total_comm_cost, b.total_comm_cost, "{what}: Eq.9 comm cost");
    assert_eq!(a.total_syncs, b.total_syncs, "{what}: syncs");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: bytes");
    assert_eq!(a.per_group, b.per_group, "{what}: per-group ledger");
}

/// A hand-rolled protocol peer: completes the join handshake, echoes
/// heartbeats, and either exits cleanly on Shutdown or drops the
/// connection on the first RoundAssignment.  Returns its assigned shard.
fn raw_peer(addr: SocketAddr, drop_on_assignment: bool) -> thread::JoinHandle<usize> {
    thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let hello = |id: usize, len: usize| {
            Message::Hello(Hello { version: WIRE_VERSION, worker_id: id, shard_len: len })
        };
        hello(0, 0).write_to(&mut s).unwrap();
        let conf = match Message::read_from(&mut s).unwrap() {
            Message::Configure(c) => c,
            other => panic!("expected Configure, got {}", other.kind_name()),
        };
        hello(conf.worker_id, conf.shard.len()).write_to(&mut s).unwrap();
        loop {
            match Message::read_from(&mut s) {
                Ok(Message::Heartbeat(h)) => {
                    Message::Heartbeat(h).write_to(&mut s).unwrap();
                }
                Ok(Message::Assignment(_)) if drop_on_assignment => return conf.worker_id,
                Ok(Message::Shutdown) | Err(_) => return conf.worker_id,
                Ok(other) => panic!("unexpected {} in raw peer", other.kind_name()),
            }
        }
    })
}

#[test]
fn loopback_handshake_tolerates_slow_joins() {
    let cfg = RunConfig { workers: 2, ..base_cfg() };
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let p0 = raw_peer(addr, false);
    // second joiner is deliberately slow: the join window tolerates it
    // while heartbeating the first peer (which thread wins shard 0 is up
    // to the scheduler — only the shard *set* is deterministic)
    let p1 = thread::spawn(move || {
        thread::sleep(Duration::from_millis(300));
        raw_peer(addr, false).join().unwrap()
    });
    let mut transport = server.accept_participants(&cfg, 2, &fast_opts()).unwrap();
    use fedlama::protocol::Transport;
    assert_eq!(transport.workers(), 2);
    let addrs = transport.peer_addrs();
    // shard ids go 0..n in join order, whatever order the threads won
    assert_eq!(addrs.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![0, 1]);
    transport.shutdown().unwrap();
    // both raw peers completed the handshake and saw the shutdown, and
    // together they covered both shards exactly once
    let mut shards = vec![p0.join().unwrap(), p1.join().unwrap()];
    shards.sort_unstable();
    assert_eq!(shards, vec![0, 1]);
}

#[test]
fn join_window_expiry_names_the_shortfall() {
    let cfg = RunConfig { workers: 3, ..base_cfg() };
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let opts = TcpOpts { join_timeout: Duration::from_millis(400), ..fast_opts() };
    // one of three shows up; the window must close with a clear count
    let p0 = raw_peer(addr, false);
    let err = server.accept_participants(&cfg, 3, &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("join window"), "{msg}");
    assert!(msg.contains("1/3"), "{msg}");
    drop(server);
    p0.join().unwrap();
}

#[test]
fn participant_drop_mid_round_names_the_shard() {
    let cfg = RunConfig { workers: 1, ..base_cfg() };
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let peer = raw_peer(addr, true);
    let mut coord = Coordinator::new(cfg).unwrap();
    let mut transport = server.accept_participants(&coord.cfg, 1, &fast_opts()).unwrap();
    let err = coord.run_with_transport(&mut transport).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 0"), "error must name the dropped shard: {msg}");
    assert!(msg.contains("closed the connection"), "{msg}");
    drop(transport);
    assert_eq!(peer.join().unwrap(), 0);
}

#[test]
fn corrupt_crc_frame_rejected_without_poisoning_the_stream() {
    // a real socket pair: one corrupt frame, then a valid frame written in
    // two halves (forcing the decoder through its Truncated state)
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let writer = thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut corrupt = Message::Heartbeat(Heartbeat { nonce: 7 }).to_frame().unwrap();
        let n = corrupt.len();
        corrupt[n - 6] ^= 0x10; // flip a body bit -> CRC mismatch
        s.write_all(&corrupt).unwrap();
        let good = Message::Heartbeat(Heartbeat { nonce: 8 }).to_frame().unwrap();
        s.write_all(&good[..5]).unwrap();
        s.flush().unwrap();
        thread::sleep(Duration::from_millis(100));
        s.write_all(&good[5..]).unwrap();
    });
    let (mut conn, _) = listener.accept().unwrap();
    let mut dec = StreamDecoder::new();
    let mut corrupt_errors = 0;
    let survivor = loop {
        match dec.poll_message() {
            Ok(Some(m)) => break m,
            Ok(None) => {
                use std::io::Read;
                let mut buf = [0u8; 4096];
                let n = conn.read(&mut buf).unwrap();
                assert!(n > 0, "writer closed before the good frame arrived");
                dec.extend(&buf[..n]);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("checksum mismatch"), "{msg}");
                corrupt_errors += 1;
            }
        }
    };
    assert_eq!(corrupt_errors, 1, "exactly one corrupt frame was rejected");
    match survivor {
        Message::Heartbeat(h) => assert_eq!(h.nonce, 8, "the frame after the corrupt one"),
        other => panic!("unexpected {}", other.kind_name()),
    }
    writer.join().unwrap();
}

#[test]
fn three_participants_bit_identical_to_inproc() {
    let cfg = base_cfg();
    let (inproc, m0) = run_inproc(&cfg);
    let (over_tcp, m3) = run_tcp(&cfg, 3);
    assert_metrics_identical(&m0, &m3, "fedlama(6,2)/tcp=3");
    for (gt, (a, b)) in inproc.global().iter().zip(over_tcp.global()).enumerate() {
        assert_eq!(a.data, b.data, "global tensor {gt} diverged over TCP");
    }
    // the per-participant ledger has one slot per shard, round-robin fold
    assert_eq!(m0.per_participant.len(), 1);
    assert_eq!(m3.per_participant.len(), 3);
    let up3: u64 = m3.per_participant.iter().map(|p| p.uplink_bytes).sum();
    assert_eq!(up3, m0.per_participant[0].uplink_bytes, "uplink bytes total");
    let down3: u64 = m3.per_participant.iter().map(|p| p.downlink_bytes).sum();
    assert_eq!(down3, m0.per_participant[0].downlink_bytes, "downlink bytes total");
    let updates3: u64 = m3.per_participant.iter().map(|p| p.updates).sum();
    assert_eq!(updates3, m0.per_participant[0].updates, "update count total");
}

/// One `--quorum 2` run over 3 participants: two healthy joins (the
/// second `stagger` later) plus a late third that departs cleanly after
/// serving the first block.  Blocks 2..4 commit on the 2-shard quorum.
fn run_quorum_with_stagger(stagger: Duration) -> RunMetrics {
    let cfg = RunConfig { workers: 3, quorum: 2, ..base_cfg() };
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let h0 = spawn_join(addr.clone(), Duration::ZERO, None);
    let h1 = spawn_join(addr.clone(), stagger, None);
    // joins last -> owns shard 2 (clients {2, 5}) in both runs
    let quitter = spawn_join(addr.clone(), Duration::from_millis(400), Some(1));
    let mut coord = Coordinator::new(cfg).unwrap();
    let mut transport = server.accept_participants(&coord.cfg, 3, &fast_opts()).unwrap();
    let metrics = coord.run_with_transport(&mut transport).unwrap();
    let mut healthy = vec![h0.join().unwrap(), h1.join().unwrap()];
    healthy.sort_unstable();
    assert_eq!(healthy, vec![0, 1], "healthy peers hold shards 0 and 1");
    assert_eq!(quitter.join().unwrap(), 2, "the late joiner owns shard 2");
    metrics
}

#[test]
fn quorum_commit_survives_departure_bit_identically() {
    // arrival timing must not leak into the numerics: the reduction folds
    // survivor updates in shard order, not reply order
    let m_a = run_quorum_with_stagger(Duration::ZERO);
    let m_b = run_quorum_with_stagger(Duration::from_millis(150));
    assert_metrics_identical(&m_a, &m_b, "quorum=2 with a block-1 departure");
    for (a, b) in m_a.per_participant.iter().zip(&m_b.per_participant) {
        assert_eq!(
            (a.departures, a.rejoins, a.missed_blocks),
            (b.departures, b.rejoins, b.missed_blocks),
            "membership accounting must match across arrival timings"
        );
    }
    let p2 = &m_a.per_participant[2];
    assert_eq!(p2.departures, 1, "shard 2 departed once");
    assert_eq!(p2.rejoins, 0);
    assert_eq!(p2.missed_blocks, 3, "shard 2 missed blocks 2..4");
    assert!(
        p2.uplink_bytes < m_a.per_participant[0].uplink_bytes,
        "the departed shard uploaded less than a full-run shard"
    );
}

#[test]
fn rejoin_reclaims_the_vacated_shard_at_a_round_boundary() {
    // 2 shards, quorum 1, 4 blocks in 2 rounds.  The quitter leaves after
    // block 1; block 2 commits 1/2; the spare (parked in the accept queue
    // since before the run) claims the vacant shard at block 3's round
    // boundary and serves rounds 2's blocks.
    let cfg = RunConfig { workers: 2, quorum: 1, ..base_cfg() };
    let server = TcpServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stayer = spawn_join(addr.clone(), Duration::ZERO, None);
    let quitter = spawn_join(addr.clone(), Duration::from_millis(50), Some(1));
    let mut coord = Coordinator::new(cfg).unwrap();
    let mut transport = server.accept_participants(&coord.cfg, 2, &fast_opts()).unwrap();
    // connect the spare while the fleet is still full, *before* training
    // starts: it parks until a shard goes vacant
    let spare = spawn_join(addr.clone(), Duration::ZERO, None);
    thread::sleep(Duration::from_millis(300));
    let metrics = coord.run_with_transport(&mut transport).unwrap();
    let stayer_shard = stayer.join().unwrap();
    let quit_shard = quitter.join().unwrap();
    let spare_shard = spare.join().unwrap();
    assert_ne!(stayer_shard, quit_shard);
    assert_eq!(spare_shard, quit_shard, "the spare re-claims the vacated shard");
    let p = &metrics.per_participant[quit_shard];
    assert_eq!(p.departures, 1, "shard {quit_shard} departed once");
    assert_eq!(p.rejoins, 1, "shard {quit_shard} was re-claimed");
    assert_eq!(p.missed_blocks, 1, "only block 2 ran without it");
    let q = &metrics.per_participant[stayer_shard];
    assert_eq!((q.departures, q.rejoins, q.missed_blocks), (0, 0, 0));
}

#[test]
fn compressed_uplink_bit_identical_over_tcp() {
    // q8 draws from per-(seed, k, group, client) streams, so the lossy
    // values must not depend on which socket carried them
    let cfg = RunConfig { compressor: "q8".into(), ..base_cfg() };
    let (_, m0) = run_inproc(&cfg);
    let (_, m3) = run_tcp(&cfg, 3);
    assert_metrics_identical(&m0, &m3, "q8/tcp=3");
    let cfg = RunConfig { compressor: "top10".into(), ..base_cfg() };
    let (_, m0) = run_inproc(&cfg);
    let (_, m2) = run_tcp(&cfg, 2);
    assert_metrics_identical(&m0, &m2, "top10/tcp=2");
}
