//! Integration tests for the layer-graph conv models: the zoo registry,
//! end-to-end training on real conv/ResNet architectures, cluster
//! bit-identity for conv compute, and graph-level gradient checks.
//!
//! Conv steps are ~50x the MLP's compute, so every run here is scaled to
//! a handful of iterations — the point is exercising the full stack, not
//! convergence (the MLP integration suite covers learning curves).

use fedlama::aggregation::Policy;
use fedlama::clients::ClientState;
use fedlama::config::{Algorithm, PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::{iid_partition, ClientData, DatasetKind, Generator};
use fedlama::runtime::{cluster, zoo, ComputeBackend, ModelGraph};
use fedlama::util::rng::Rng;

fn femnist_cfg() -> RunConfig {
    RunConfig {
        model: "femnist_cnn".into(),
        dataset: DatasetKind::Femnist,
        partition: PartitionKind::Writers,
        n_clients: 3,
        samples: 32,
        lr: 0.05,
        warmup_rounds: 0,
        iterations: 8,
        policy: Policy::fedlama(2, 2),
        eval_every_rounds: 0,
        eval_examples: 64,
        seed: 9,
        ..Default::default()
    }
}

/// Satellite: threads=1 vs threads=8 bit-identity for a conv model, over
/// the full coordinator loop (local conv training blocks + layer-wise
/// aggregation + eval).
#[test]
fn conv_model_threads_bit_identical() {
    let run = |threads: usize| {
        let cfg = RunConfig { threads, ..femnist_cfg() };
        let mut coord = Coordinator::new(cfg).unwrap();
        let metrics = coord.run().unwrap();
        (coord, metrics)
    };
    let (c1, m1) = run(1);
    let (c8, m8) = run(8);
    assert_eq!(m1.curve, m8.curve, "learning curves diverged");
    assert_eq!(m1.final_acc, m8.final_acc);
    assert_eq!(m1.final_loss, m8.final_loss);
    assert_eq!(m1.per_group, m8.per_group);
    for (gt, (a, b)) in c1.global.iter().zip(&c8.global).enumerate() {
        assert_eq!(a.data, b.data, "global tensor {gt} diverged at threads=8");
    }
}

/// Acceptance: `--model resnet20 --engine native` trains end-to-end with a
/// manifest of 20+ real parameter tensors and per-layer discrepancy
/// measured per real layer.
#[test]
fn resnet20_trains_end_to_end_with_real_layers() {
    let cfg = RunConfig {
        model: "resnet20".into(),
        dataset: DatasetKind::Cifar10,
        n_clients: 2,
        samples: 32,
        lr: 0.05,
        warmup_rounds: 0,
        iterations: 4,
        policy: Policy::fedlama(2, 2),
        eval_every_rounds: 0,
        eval_examples: 16,
        seed: 3,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg).unwrap();
    let n_groups = {
        let m = coord.manifest();
        assert!(m.num_tensors() >= 20, "resnet20 has only {} tensors", m.num_tensors());
        assert!(m.groups.len() >= 10, "resnet20 has only {} groups", m.groups.len());
        m.groups.len()
    };
    let metrics = coord.run().unwrap();
    assert!(metrics.final_loss.is_finite(), "loss {}", metrics.final_loss);
    // per-layer discrepancy was observed for every real layer at the
    // full-sync boundaries
    assert_eq!(coord.schedule().last_unit_disc.len(), n_groups);
    assert!(coord.schedule().last_unit_disc.iter().all(|d| d.is_finite()));
    assert!(
        coord.schedule().last_unit_disc.iter().any(|&d| d > 0.0),
        "clients trained but no layer diverged: {:?}",
        coord.schedule().last_unit_disc
    );
    // and the ledger reports each layer separately
    assert_eq!(metrics.per_group.len(), n_groups);
}

/// Acceptance: resnet20 local training fans out across worker threads
/// bit-identically (checked at the cluster layer to keep the runtime
/// budget small — the coordinator-level check runs on femnist_cnn above).
#[test]
fn resnet20_cluster_fanout_bit_identical() {
    let backend = zoo::build("resnet20", DatasetKind::Cifar10).unwrap();
    let part = iid_partition(2, 10, 32);
    let parts: Vec<&ClientData> = part.clients.iter().collect();
    let gen = Generator::new(DatasetKind::Cifar10, 5);
    let ctx = cluster::StepCtx {
        gen: &gen,
        parts: &parts,
        algorithm: Algorithm::Sgd,
        server_control: None,
        gap: 1,
        lr: 0.05,
        use_chunk: false,
    };
    let global = backend.init_params(7).unwrap();
    let fleet = || -> Vec<ClientState> {
        (0..2).map(|i| ClientState::new(i, global.clone(), 7)).collect()
    };
    let mut serial = fleet();
    let l1 = cluster::advance_serial(&backend, &ctx, &mut serial).unwrap();
    let mut parallel = fleet();
    let l2 = cluster::advance_parallel(&backend, &ctx, &mut parallel, 8).unwrap();
    assert_eq!(l1, l2, "losses diverged across the fan-out");
    for (a, b) in serial.iter().zip(&parallel) {
        for (t, (ta, tb)) in a.params.iter().zip(&b.params).enumerate() {
            assert_eq!(ta.data, tb.data, "client {} tensor {t} diverged", a.id);
        }
    }
}

/// Satellite: graph-level finite-difference gradient check through a conv
/// / groupnorm / pool stack (mirrors the MLP finite-diff test).
#[test]
fn conv_graph_gradients_match_finite_differences() {
    use fedlama::runtime::ops::{Conv2d, Dense, GroupNorm, LayerOp, MaxPool2d, Relu};
    let ops: Vec<Box<dyn LayerOp>> = vec![
        Box::new(Conv2d::new("c", [4, 4, 2], 3, 3, 1, 1)),
        Box::new(GroupNorm::new("gn", [4, 4, 3], 1)),
        Box::new(Relu::new("r")),
        Box::new(MaxPool2d::new("p", [4, 4, 3], 2)),
        Box::new(Dense::new("fc", 2 * 2 * 3, 3)),
    ];
    let g = ModelGraph::from_ops("fd-conv", "test", &[4, 4, 2], 3, 2, 2, 1, ops).unwrap();
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..2 * 32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y = vec![0i32, 2];
    let params = g.init_params(1).unwrap();
    let (grads, _) = g.grad_step(&params, &x, &y).unwrap();
    let eps = 5e-3f32;
    for t in 0..params.len() {
        let len = params[t].data.len();
        for j in [0, len / 2, len - 1] {
            let mut plus = params.clone();
            plus[t].data[j] += eps;
            let mut minus = params.clone();
            minus[t].data[j] -= eps;
            let (_, lp) = g.grad_step(&plus, &x, &y).unwrap();
            let (_, lm) = g.grad_step(&minus, &x, &y).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let an = grads[t].data[j];
            assert!(
                (fd - an).abs() < 3e-2 * (1.0 + an.abs()),
                "tensor {t} coord {j}: finite-diff {fd} vs analytic {an}"
            );
        }
    }
}

/// Satellite: the model registry errors on unknown names end-to-end —
/// config validation, coordinator construction, and direct zoo lookup.
#[test]
fn unknown_model_is_rejected_not_substituted() {
    let cfg = RunConfig { model: "resnet999".into(), ..Default::default() };
    let err = cfg.validate().unwrap_err();
    assert!(format!("{err:#}").contains("unknown model"), "{err:#}");
    assert!(Coordinator::new(RunConfig { model: "vgg16".into(), ..Default::default() }).is_err());
    // geometry mismatches are equally loud
    let err = zoo::build("femnist_cnn", DatasetKind::Toy).unwrap_err();
    assert!(format!("{err:#}").contains("requires"), "{err:#}");
}

/// The femnist_cnn actually reduces training loss over a few conv rounds
/// (sanity that backward through conv/pool drives learning, not just
/// determinism).
#[test]
fn conv_model_reduces_loss() {
    let cfg = RunConfig { iterations: 16, ..femnist_cfg() };
    let mut coord = Coordinator::new(cfg).unwrap();
    let metrics = coord.run().unwrap();
    let first = metrics.curve.first().unwrap().train_loss;
    let last = metrics.curve.last().unwrap().train_loss;
    assert!(
        last < first,
        "conv training did not reduce loss: {first} -> {last}"
    );
}
