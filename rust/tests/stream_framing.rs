//! Integration tests for the streamed per-layer wire framing (wire v2).
//!
//! Covers the properties the transports rely on:
//!
//!   - round-trips survive arbitrary read chunking (`MessageStream` is a
//!     push decoder — partial frames and partial *sequences* both buffer),
//!   - a truncated byte stream never errors and never fabricates a
//!     message from an incomplete per-layer sequence,
//!   - a corrupt mid-update layer frame fails *that* peer's stream without
//!     poisoning another peer's independently decoded stream (each
//!     connection owns its decoder + assembler),
//!   - heartbeats pass through an open per-layer sequence; any other kind
//!     interleaved into one is a protocol violation.

use fedlama::comm::compression::{Compressor, Quantizer};
use fedlama::protocol::messages::streamed_frame_count;
use fedlama::protocol::{Heartbeat, LayerUpdate, Message, MessageStream, Payload, SyncDecision};
use fedlama::util::prop::{forall, Pair, UsizeIn};
use fedlama::util::rng::Rng;

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// A mixed-payload update — dense + q8 + top-k tensors, so every payload
/// encoding crosses the scatter-gather path.
fn sample_update(seed: u64, n: usize) -> Message {
    let dense = randvec(n, seed);
    let mut lossy = randvec(n.max(8), seed ^ 1);
    Quantizer::new(8, seed ^ 2).compress(&mut lossy);
    let mut sparse = randvec(n.max(8), seed ^ 3);
    for (i, v) in sparse.iter_mut().enumerate() {
        if i % 3 != 0 {
            *v = 0.0;
        }
    }
    let nominal = sparse.len().div_ceil(3);
    Message::Update(LayerUpdate {
        k: 4,
        group: 1,
        client: (seed % 7) as usize,
        tensors: vec![
            Payload::Dense(dense),
            Payload::qbits_from(&lossy, 8, 1024),
            Payload::topk_from(&sparse, nominal),
        ],
    })
}

fn streamed_bytes(msgs: &[Message]) -> Vec<u8> {
    let mut out = Vec::new();
    for m in msgs {
        m.write_streamed(&mut out).unwrap();
    }
    out
}

fn drain(ms: &mut MessageStream) -> Vec<Message> {
    let mut got = Vec::new();
    while let Some(m) = ms.poll().unwrap() {
        got.push(m);
    }
    got
}

/// (offset, total length) of every frame in `buf`, from the wire layout:
/// 8-byte header `[magic2 version kind len4]`, body, 4-byte CRC.
fn frame_extents(buf: &[u8]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut at = 0usize;
    while at < buf.len() {
        let len = u32::from_le_bytes(buf[at + 4..at + 8].try_into().unwrap()) as usize;
        out.push((at, 8 + len + 4));
        at += 8 + len + 4;
    }
    assert_eq!(at, buf.len(), "frame extents must tile the buffer exactly");
    out
}

#[test]
fn streamed_messages_round_trip_under_arbitrary_chunking() {
    forall(11, 25, &Pair(UsizeIn { lo: 1, hi: 300 }, UsizeIn { lo: 1, hi: 97 }), |&(n, step)| {
        let msgs = vec![
            sample_update(n as u64, n),
            Message::Decision(SyncDecision {
                k: 4,
                group: 1,
                new_interval: 6,
                // includes an empty tensor: zero-length frames must work
                new_params: vec![randvec(n, 5), Vec::new(), randvec(7, 6)],
                // personalized mixing weights ride the Begin frame
                mix: vec![(0, 0.75), (n % 7, 1.0)],
            }),
            Message::Heartbeat(Heartbeat { nonce: n as u64 }),
        ];
        let bytes = streamed_bytes(&msgs);
        let mut ms = MessageStream::new();
        let mut got = Vec::new();
        for chunk in bytes.chunks(step) {
            ms.extend(chunk);
            got.extend(drain(&mut ms));
        }
        if got == msgs {
            Ok(())
        } else {
            Err(format!("decoded {} messages, sent {}", got.len(), msgs.len()))
        }
    });
}

#[test]
fn truncation_at_every_cut_never_errors_or_fabricates() {
    let msgs = vec![sample_update(9, 64)];
    let bytes = streamed_bytes(&msgs);
    assert_eq!(streamed_frame_count(&msgs[0]), 4); // Begin + 3 tensors
    for cut in 0..bytes.len() {
        let mut ms = MessageStream::new();
        ms.extend(&bytes[..cut]);
        // a strict prefix is missing at least one byte of the last layer
        // frame, so the update must not complete — and must not error
        assert!(
            drain(&mut ms).is_empty(),
            "cut {cut}: produced a message from a strict prefix"
        );
        // the remainder completes exactly the original message
        ms.extend(&bytes[cut..]);
        assert_eq!(drain(&mut ms), msgs, "cut {cut}");
    }
}

#[test]
fn corrupt_tensor_frame_fails_one_peer_without_poisoning_another() {
    // two shards, each with its own connection and therefore its own
    // MessageStream: a corrupt mid-update layer frame on peer B departs B
    // (its stream errors) while peer A's in-flight update is untouched
    let good = sample_update(21, 128);
    let bytes_a = streamed_bytes(std::slice::from_ref(&good));
    let mut bytes_b = streamed_bytes(&[sample_update(22, 128)]);

    let frames = frame_extents(&bytes_b);
    assert_eq!(frames.len(), 4);
    // flip one byte inside the *body* of the second tensor frame
    let (start, total) = frames[2];
    assert!(total > 8 + 6 + 4);
    bytes_b[start + 8 + 5] ^= 0xFF;

    let mut ms_a = MessageStream::new();
    let mut ms_b = MessageStream::new();
    // interleave the connections: half of A, all of B, the rest of A
    let half = bytes_a.len() / 2;
    ms_a.extend(&bytes_a[..half]);
    assert!(drain(&mut ms_a).is_empty());
    ms_b.extend(&bytes_b);
    assert!(ms_b.poll().is_err(), "corrupt layer frame must error peer B");
    ms_a.extend(&bytes_a[half..]);
    assert_eq!(drain(&mut ms_a), vec![good], "peer A must complete unaffected");
}

#[test]
fn heartbeat_spliced_mid_update_is_delivered_first() {
    let upd = sample_update(31, 40);
    let all = streamed_bytes(std::slice::from_ref(&upd));
    let (_, len0) = frame_extents(&all)[0];
    let hb = Message::Heartbeat(Heartbeat { nonce: 0xBEEF }).to_frame().unwrap();
    // splice the heartbeat between the Begin frame and the first tensor
    let mut bytes = Vec::with_capacity(all.len() + hb.len());
    bytes.extend_from_slice(&all[..len0]);
    bytes.extend_from_slice(&hb);
    bytes.extend_from_slice(&all[len0..]);
    let mut ms = MessageStream::new();
    ms.extend(&bytes);
    assert_eq!(
        drain(&mut ms),
        vec![Message::Heartbeat(Heartbeat { nonce: 0xBEEF }), upd],
        "the heartbeat passes through; the update completes after it"
    );
}

#[test]
fn non_heartbeat_interleaved_into_an_open_update_is_rejected() {
    let upd = sample_update(33, 16);
    let all = streamed_bytes(std::slice::from_ref(&upd));
    let (_, len0) = frame_extents(&all)[0];
    let mut bytes = all[..len0].to_vec();
    bytes.extend_from_slice(&Message::Shutdown.to_frame().unwrap());
    let mut ms = MessageStream::new();
    ms.extend(&bytes);
    let err = ms.poll().unwrap_err();
    assert!(format!("{err:#}").contains("interleaved"), "{err:#}");
}
