//! Benchmark harness (`cargo bench`), custom — no criterion offline.
//!
//! Sections, all hermetic (native backend, no artifacts):
//!   1. Microbenches: the SIMD matmul kernels vs forced-scalar (the same
//!      measurement `fedlama bench` records into BENCH_kernels.json); the
//!      native aggregation hot path across layer sizes and client counts;
//!      per-op dense vs conv2d forward/backward at the zoo's preset
//!      shapes; the scratch-buffer reuse delta; per-model train-step /
//!      train-chunk / eval latency.
//!   2. Cluster scaling: one federated round at threads = 1, 2, 4, 8 —
//!      the `runtime::cluster` fan-out speedup (results are bit-identical
//!      across thread counts; only wall time changes).
//!   3. Paper tables.  Since the layer-graph refactor, tables 1-5 train
//!      real conv/ResNet models natively — minutes, not seconds — so the
//!      default run covers only the MLP baselines ablation; BENCH_CONV=1
//!      adds tables 1-5 and BENCH_ALL=1 adds the appendix tables too.
//!   4. Paper figures: Figure 1 crossover curves, Figures 2/3 per-layer
//!      comm profile, Figures 4-6 learning-curve endpoints (MLP scale).
//!
//! Environment:
//!   BENCH_SCALE=smoke|default   experiment scale (default: smoke)
//!   BENCH_CONV=1                include the conv-model tables 1-5
//!   BENCH_ALL=1                 include every table incl. appendix
//!   BENCH_FILTER=<substr>       only run sections whose name matches

use std::time::Instant;

use fedlama::aggregation::{aggregate_native, Policy};
use fedlama::config::presets::{self, Scale};
use fedlama::config::{PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::tables::Table;
use fedlama::reports;
use fedlama::runtime::ops::{Conv2d, Dense, LayerOp, Scratch};
use fedlama::runtime::{zoo, ComputeBackend, HostTensor, NativeBackend};
use fedlama::util::rng::Rng;
use fedlama::util::stats;

fn main() -> anyhow::Result<()> {
    let filter = std::env::var("BENCH_FILTER").unwrap_or_default();
    let scale = Scale::parse(&std::env::var("BENCH_SCALE").unwrap_or_else(|_| "smoke".into()))
        .unwrap_or(Scale::Smoke);
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    let t0 = Instant::now();
    if run("micro-kernel") {
        bench_kernels()?;
    }
    if run("micro-agg") {
        bench_aggregation()?;
    }
    if run("micro-op") {
        bench_ops()?;
    }
    if run("micro-scratch") {
        bench_scratch_reuse()?;
    }
    if run("micro-step") {
        bench_model_steps()?;
    }
    if run("micro-cluster") {
        bench_cluster_scaling()?;
    }
    if run("tables") {
        bench_tables(scale)?;
    }
    if run("figures") {
        bench_figures()?;
    }
    eprintln!("\ntotal bench time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Section 1: the SIMD matmul kernels vs forced-scalar — the exact
/// measurement `fedlama bench` persists into BENCH_kernels.json, rendered
/// as a table here.
fn bench_kernels() -> anyhow::Result<()> {
    println!("\n### micro-kernel: SIMD matmul dispatch vs scalar (see BENCH_kernels.json)\n");
    let doc = fedlama::bench::kernels_doc(false);
    let isa = doc.req("isa")?.as_str().unwrap_or("?").to_string();
    let mut t = Table::new(
        &format!("matmul kernels, dispatch = {isa} (bit-identical to scalar)"),
        &["kernel", "shape", "GFLOP/s", "scalar GFLOP/s", "speedup"],
    );
    for k in doc.req("kernels")?.as_arr().unwrap_or(&[]) {
        t.row(vec![
            k.get("kernel").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            k.get("shape").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
            format!("{:.2}", k.get("gflops").and_then(|v| v.as_f64()).unwrap_or(0.0)),
            format!("{:.2}", k.get("scalar_gflops").and_then(|v| v.as_f64()).unwrap_or(0.0)),
            format!("{:.2}x", k.get("speedup_vs_scalar").and_then(|v| v.as_f64()).unwrap_or(0.0)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Section 1a: native aggregation throughput across sizes.
fn bench_aggregation() -> anyhow::Result<()> {
    println!("\n### micro-agg: aggregation hot path (u_l + d_l per sync)\n");
    let mut rng = Rng::new(7);
    let mut t = Table::new(
        "native aggregation throughput (one group sync)",
        &["dim", "m", "native (us)", "native GB/s"],
    );
    // representative group dims of the native MLP manifests (toy + cifar)
    let dims = [650usize, 8_256, 8_320, 65_536, 393_344];
    let ms = [4usize, 8, 16];
    for &dim in &dims {
        for &m in &ms {
            let stack: Vec<f32> = (0..m * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> = vec![1.0 / m as f32; m];
            let rows: Vec<&[f32]> = (0..m).map(|i| &stack[i * dim..(i + 1) * dim]).collect();
            let mut u = vec![0.0f32; dim];
            let reps = (4_000_000 / (m * dim)).clamp(3, 200);
            let mut nat = Vec::new();
            for _ in 0..reps {
                let s = Instant::now();
                let d = aggregate_native(&rows, &w, &mut u);
                nat.push(s.elapsed().as_secs_f64() * 1e6);
                std::hint::black_box(d);
            }
            let nat_us = stats::mean(&nat);
            let bytes = (m * dim * 4) as f64; // one pass reads the stack
            t.row(vec![
                dim.to_string(),
                m.to_string(),
                format!("{nat_us:.1}"),
                format!("{:.2}", 2.0 * bytes / (nat_us * 1e-6) / 1e9),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "(The PJRT/Pallas kernel path — `--features pjrt` + artifacts — pays a literal\n\
         round-trip per call on CPU; on TPU the same artifact runs from VMEM.)\n"
    );
    Ok(())
}

/// Section 1b: per-op microbench — dense vs conv2d forward/backward at
/// the zoo's preset shapes.  This is the baseline future SIMD work gets
/// compared against.
fn bench_ops() -> anyhow::Result<()> {
    println!("\n### micro-op: dense vs conv2d forward/backward (preset shapes, batch 8)\n");
    let b = 8usize;
    type OpCase = (&'static str, Box<dyn LayerOp>, Vec<usize>);
    let cases: Vec<OpCase> = vec![
        ("dense 784->64 (femnist fc1)", Box::new(Dense::new("d1", 784, 64)), vec![784]),
        ("dense 3072->128 (mlp fc1)", Box::new(Dense::new("d2", 3072, 128)), vec![3072]),
        (
            "conv3x3 3->16 @32x32 (stem)",
            Box::new(Conv2d::new("c1", [32, 32, 3], 16, 3, 1, 1)),
            vec![32, 32, 3],
        ),
        (
            "conv3x3 16->16 @32x32 (s1)",
            Box::new(Conv2d::new("c2", [32, 32, 16], 16, 3, 1, 1)),
            vec![32, 32, 16],
        ),
        (
            "conv3x3 16->32 @32x32 s2",
            Box::new(Conv2d::new("c3", [32, 32, 16], 32, 3, 2, 1)),
            vec![32, 32, 16],
        ),
    ];
    let mut t = Table::new(
        "per-op latency (scalar rust, deterministic accumulation)",
        &["op", "params", "fwd (ms)", "bwd (ms)", "fwd GFLOP/s"],
    );
    for (label, op, in_shape) in cases {
        let in_dim: usize = in_shape.iter().product();
        let out_shape = op.out_shape(&in_shape)?;
        let out_dim: usize = out_shape.iter().product();
        let root = Rng::new(3);
        let ps: Vec<HostTensor> = op
            .params()
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let mut r = root.fork(i as u64);
                spec.init.materialize(&spec.shape, &mut r)
            })
            .collect();
        let n_params: usize = ps.iter().map(|p| p.data.len()).sum();
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..b * in_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let dy: Vec<f32> = (0..b * out_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut y = vec![0.0f32; b * out_dim];
        let mut dx = vec![0.0f32; b * in_dim];
        let mut grads: Vec<HostTensor> = ps.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        let mut s = Scratch::default();
        op.forward(&ps, &x, &mut y, b, &mut s); // warm the scratch pool
        let reps = 10;
        let mut fwd = Vec::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            op.forward(&ps, &x, &mut y, b, &mut s);
            fwd.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mut bwd = Vec::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            op.backward(&ps, &x, &y, &dy, &mut dx, &mut grads, b, &mut s);
            bwd.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        // forward matmul flops: 2 · (b · spatial positions) · weight elems
        let cout = *out_shape.last().unwrap();
        let bias_len = ps.last().map(|p| p.data.len()).unwrap_or(0);
        let flops = 2.0 * (b * out_dim / cout) as f64 * (n_params - bias_len) as f64;
        let fwd_ms = stats::mean(&fwd);
        t.row(vec![
            label.to_string(),
            n_params.to_string(),
            format!("{fwd_ms:.3} ±{:.3}", stats::stddev(&fwd)),
            format!("{:.3} ±{:.3}", stats::mean(&bwd), stats::stddev(&bwd)),
            format!("{:.2}", flops / (fwd_ms * 1e-3) / 1e9),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Section 1c: the scratch/activation buffer-reuse win (the ROADMAP perf
/// item): identical numerics, fewer allocations per batch.
fn bench_scratch_reuse() -> anyhow::Result<()> {
    println!("\n### micro-scratch: per-batch buffer reuse (femnist_cnn train_step)\n");
    let timed = |reuse: bool| -> anyhow::Result<(f64, f32)> {
        let mut rt = zoo::build("femnist_cnn", DatasetKind::Femnist)?;
        rt.set_scratch_reuse(reuse);
        let mut params = rt.init_params(0)?;
        let b = rt.manifest().batch_size;
        let d: usize = rt.manifest().input_shape.iter().product();
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % rt.manifest().num_classes) as i32).collect();
        rt.train_step(&mut params, &x, &y, 0.05)?; // warmup
        let reps = 20;
        let mut last = 0.0f32;
        let t0 = Instant::now();
        for _ in 0..reps {
            last = rt.train_step(&mut params, &x, &y, 0.05)?;
        }
        Ok((t0.elapsed().as_secs_f64() * 1e3 / reps as f64, last))
    };
    let (reused_ms, l1) = timed(true)?;
    let (fresh_ms, l2) = timed(false)?;
    assert_eq!(l1, l2, "buffer reuse must not change numerics");
    println!(
        "train_step: {reused_ms:.3} ms with pooled buffers vs {fresh_ms:.3} ms reallocating \
         per batch ({:+.1}% wall)\n",
        100.0 * (reused_ms - fresh_ms) / fresh_ms
    );
    Ok(())
}

/// Section 1d: per-model native step latency.
fn bench_model_steps() -> anyhow::Result<()> {
    println!("\n### micro-step: native backend latency per dataset model\n");
    let mut t = Table::new(
        "native executable latency",
        &["model", "params", "train_step (ms)", "train_chunk/step (ms)", "eval_step (ms)"],
    );
    let models: Vec<(&str, NativeBackend)> = vec![
        ("toy-mlp", NativeBackend::for_dataset(DatasetKind::Toy)),
        ("cifar10-mlp", NativeBackend::for_dataset(DatasetKind::Cifar10)),
        ("femnist-cnn", zoo::build("femnist_cnn", DatasetKind::Femnist)?),
        ("cifar-cnn100", zoo::build("cifar_cnn100", DatasetKind::Cifar100)?),
    ];
    for (name, rt) in models {
        let mut params = rt.init_params(0)?;
        let b = rt.manifest().batch_size;
        let k = rt.chunk_k();
        let d: usize = rt.manifest().input_shape.iter().product();
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..k * b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..k * b).map(|i| (i % rt.manifest().num_classes) as i32).collect();
        let reps = 10;
        let mut ts = Vec::new();
        for _ in 0..reps {
            let s = Instant::now();
            rt.train_step(&mut params, &x[..b * d], &y[..b], 0.05)?;
            ts.push(s.elapsed().as_secs_f64() * 1e3);
        }
        let mut tc = Vec::new();
        for _ in 0..reps {
            let s = Instant::now();
            rt.train_chunk(&mut params, &x, &y, 0.05)?;
            tc.push(s.elapsed().as_secs_f64() * 1e3 / k as f64);
        }
        let eb = rt.manifest().eval_batch_size;
        let ex: Vec<f32> = (0..eb * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let ey: Vec<i32> = (0..eb).map(|i| (i % rt.manifest().num_classes) as i32).collect();
        let mut te = Vec::new();
        for _ in 0..reps {
            let s = Instant::now();
            rt.eval_step(&params, &ex, &ey)?;
            te.push(s.elapsed().as_secs_f64() * 1e3);
        }
        t.row(vec![
            name.to_string(),
            rt.manifest().num_params.to_string(),
            format!("{:.3} ±{:.3}", stats::mean(&ts), stats::stddev(&ts)),
            format!("{:.3} ±{:.3}", stats::mean(&tc), stats::stddev(&tc)),
            format!("{:.3} ±{:.3}", stats::mean(&te), stats::stddev(&te)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Section 2: cluster fan-out scaling (same work, more worker threads).
fn bench_cluster_scaling() -> anyhow::Result<()> {
    println!("\n### micro-cluster: parallel client fan-out (runtime::cluster)\n");
    let mk = |threads| RunConfig {
        dataset: DatasetKind::Cifar10,
        partition: PartitionKind::Dirichlet { alpha: 0.3 },
        n_clients: 16,
        samples: 128,
        lr: 0.1,
        warmup_rounds: 0,
        iterations: 24,
        eval_every_rounds: 0,
        eval_examples: 256,
        seed: 5,
        threads,
        ..Default::default()
    };
    let mut t = Table::new(
        "one fedavg(6) run, 16 clients x 24 iters (cifar10-mlp)",
        &["threads", "wall (s)", "speedup", "final loss"],
    );
    let mut base_wall = None;
    for threads in [1usize, 2, 4, 8] {
        let mut coord = Coordinator::new(mk(threads))?;
        let m = coord.run()?;
        let wall = m.wall_secs;
        let speedup = match base_wall {
            None => {
                base_wall = Some(wall);
                1.0
            }
            Some(b) => b / wall.max(1e-9),
        };
        t.row(vec![
            threads.to_string(),
            format!("{wall:.2}"),
            format!("{speedup:.2}x"),
            format!("{:.4}", m.final_loss),
        ]);
    }
    println!("{}", t.render());
    println!("(final loss is identical by construction: threads=N is bit-identical to 1)\n");
    Ok(())
}

/// Section 3: the paper tables.
fn bench_tables(scale: Scale) -> anyhow::Result<()> {
    let all = std::env::var("BENCH_ALL").ok().is_some_and(|v| v == "1");
    let conv = all || std::env::var("BENCH_CONV").ok().is_some_and(|v| v == "1");
    let ids: Vec<&str> = if all {
        presets::ALL_TABLE_IDS.to_vec()
    } else if conv {
        vec!["table1", "table2", "table3", "table4", "table5", "baselines"]
    } else {
        vec!["baselines"]
    };
    if !conv {
        println!(
            "\n(tables 1-5 now train their real conv/ResNet architectures natively — \
             minutes, not seconds; set BENCH_CONV=1 or BENCH_ALL=1 to include them)"
        );
    }
    for id in ids {
        let exp = presets::by_id(id, scale).unwrap();
        println!("\n### {id} ({:?} scale)\n", scale);
        let t0 = Instant::now();
        let results = reports::run_experiment(&exp, 1, false)?;
        println!("{}", reports::render_table(&exp, &results).render());
        eprintln!("[{id} took {:.1}s]", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

/// Section 4: the paper figures (compact textual form).
fn bench_figures() -> anyhow::Result<()> {
    println!("\n### figures\n");
    // Figure 1: crossover curves on the cifar10 workload
    let cfg = RunConfig {
        dataset: DatasetKind::Cifar10,
        partition: PartitionKind::Dirichlet { alpha: 0.1 },
        policy: Policy::fedlama(6, 2),
        n_clients: 4,
        samples: 128,
        lr: 0.1,
        warmup_rounds: 0,
        iterations: 24,
        eval_every_rounds: 0,
        eval_examples: 256,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg.clone())?;
    coord.run()?;
    if let Some(ascii) = reports::figure1_ascii(&coord, 56, 12) {
        println!("{ascii}");
    }

    // Figures 2/3: per-layer comm profile, FedAvg vs FedLAMA
    let mk = |policy| RunConfig { policy, iterations: 72, warmup_rounds: 2, ..cfg.clone() };
    let mut avg = Coordinator::new(mk(Policy::fedavg(6)))?;
    let m_avg = avg.run()?;
    let mut lama = Coordinator::new(mk(Policy::fedlama(6, 2)))?;
    let m_lama = lama.run()?;
    let top: Vec<_> = m_avg
        .per_group
        .iter()
        .zip(&m_lama.per_group)
        .filter(|(a, _)| a.1 > 1000)
        .map(|(a, l)| format!("{}(d={}): {} vs {} syncs", a.0, a.1, a.2, l.2))
        .collect();
    println!("Figure 2 (largest layers, FedAvg vs FedLAMA syncs over {} iters):", 72);
    for line in top {
        println!("  {line}");
    }
    println!(
        "Figure 3 totals (Eq.9): FedAvg {} vs FedLAMA {} ({:.1}%)\n",
        m_avg.total_comm_cost,
        m_lama.total_comm_cost,
        100.0 * m_lama.total_comm_cost as f64 / m_avg.total_comm_cost as f64
    );

    // Figures 4-6: learning-curve endpoints (full curves via `fedlama figure`)
    for (fig, ds, tau, lr) in [
        (4, DatasetKind::Cifar10, 6usize, 0.1f32),
        (5, DatasetKind::Cifar100, 6, 0.1),
        (6, DatasetKind::Femnist, 10, 0.06),
    ] {
        let iters = 8 * tau;
        let partition = if fig == 6 {
            PartitionKind::Writers
        } else {
            PartitionKind::Dirichlet { alpha: 0.1 }
        };
        let mk = |policy| RunConfig {
            dataset: ds,
            partition,
            policy,
            n_clients: 4,
            samples: 128,
            lr,
            warmup_rounds: 2,
            iterations: iters,
            eval_every_rounds: 0,
            eval_examples: 256,
            ..Default::default()
        };
        let mut lines = Vec::new();
        for (label, policy) in [
            (format!("FedAvg({tau})"), Policy::fedavg(tau)),
            (format!("FedAvg({})", 4 * tau), Policy::fedavg(4 * tau)),
            (format!("FedLAMA({tau},4)"), Policy::fedlama(tau, 4)),
        ] {
            let mut c = Coordinator::new(mk(policy))?;
            let m = c.run()?;
            lines.push(format!(
                "  {label:14} final loss {:.4}, acc {:.2}%, comm {}",
                m.final_loss,
                100.0 * m.final_acc,
                m.total_comm_cost
            ));
        }
        println!("Figure {fig} endpoints ({ds:?}, {iters} iters):");
        for l in lines {
            println!("{l}");
        }
    }
    Ok(())
}
