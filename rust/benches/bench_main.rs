//! Benchmark harness (`cargo bench`), custom — no criterion offline.
//!
//! Three sections:
//!   1. Microbenches: the aggregation hot path (native vs Pallas/XLA
//!      kernel) across layer sizes and client counts, plus per-model
//!      train-step / train-chunk / eval latency and the literal-boundary
//!      cost.  These are the §Perf numbers in EXPERIMENTS.md.
//!   2. Paper tables: regenerates Tables 1-5 (+ the baselines ablation) at
//!      smoke scale and prints the paper-format rows.  BENCH_ALL=1 also
//!      runs the appendix tables 6-11.
//!   3. Paper figures: Figure 1 crossover curves, Figures 2/3 per-layer
//!      comm profile, Figures 4-6 learning-curve endpoints.
//!
//! Environment:
//!   BENCH_SCALE=smoke|default   experiment scale (default: smoke)
//!   BENCH_ALL=1                 include appendix tables
//!   BENCH_FILTER=<substr>       only run sections whose name matches

use std::time::Instant;

use fedlama::aggregation::{aggregate_native, Policy};
use fedlama::config::presets::{self, Scale};
use fedlama::config::{PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::metrics::tables::Table;
use fedlama::reports;
use fedlama::runtime::ModelRuntime;
use fedlama::util::rng::Rng;
use fedlama::util::stats;

fn main() -> anyhow::Result<()> {
    let filter = std::env::var("BENCH_FILTER").unwrap_or_default();
    let scale = Scale::parse(&std::env::var("BENCH_SCALE").unwrap_or_else(|_| "smoke".into()))
        .unwrap_or(Scale::Smoke);
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    let t0 = Instant::now();
    if run("micro-agg") {
        bench_aggregation()?;
    }
    if run("micro-step") {
        bench_model_steps()?;
    }
    if run("micro-boundary") {
        bench_literal_boundary()?;
    }
    if run("tables") {
        bench_tables(scale)?;
    }
    if run("figures") {
        bench_figures()?;
    }
    eprintln!("\ntotal bench time: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Section 1a: fused aggregation kernel vs native rust across sizes.
fn bench_aggregation() -> anyhow::Result<()> {
    println!("\n### micro-agg: aggregation backends (u_l + d_l per sync)\n");
    let rt = ModelRuntime::load(std::path::Path::new("artifacts/resnet20"))?;
    let mut rng = Rng::new(7);
    let mut t = Table::new(
        "aggregation throughput (one group sync)",
        &["dim", "m", "native (us)", "pallas/xla (us)", "native GB/s", "speedup"],
    );
    // representative group dims present in the resnet20 artifact set
    let dims: Vec<usize> = rt.manifest.agg_by_dim.keys().cloned().collect();
    let ms = [4usize, 8, 16];
    for &dim in dims.iter().filter(|&&d| d >= 512) {
        for &m in &ms {
            let stack: Vec<f32> = (0..m * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> = vec![1.0 / m as f32; m];
            let rows: Vec<&[f32]> = (0..m).map(|i| &stack[i * dim..(i + 1) * dim]).collect();
            let mut u = vec![0.0f32; dim];
            let reps = (1_000_000 / (m * dim)).clamp(3, 100);
            // native
            let mut nat = Vec::new();
            for _ in 0..reps {
                let s = Instant::now();
                let d = aggregate_native(&rows, &w, &mut u);
                nat.push(s.elapsed().as_secs_f64() * 1e6);
                std::hint::black_box(d);
            }
            // pallas/xla (if artifact exists for this (dim, m))
            let xla_us = rt.agg_kernel(dim, m).map(|exe| {
                let mut xs = Vec::new();
                for _ in 0..reps.min(20) {
                    let s = Instant::now();
                    let out = rt.run_agg(&exe, &stack, &w, dim).unwrap();
                    xs.push(s.elapsed().as_secs_f64() * 1e6);
                    std::hint::black_box(out.1);
                }
                stats::mean(&xs)
            });
            let nat_us = stats::mean(&nat);
            let bytes = (m * dim * 4) as f64; // one pass reads the stack
            t.row(vec![
                dim.to_string(),
                m.to_string(),
                format!("{nat_us:.1}"),
                xla_us.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
                format!("{:.2}", 2.0 * bytes / (nat_us * 1e-6) / 1e9),
                xla_us.map(|v| format!("{:.2}x", v / nat_us)).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "(speedup < 1x means the Pallas/XLA path is slower than native here: on CPU the\n\
         kernel pays a literal round-trip per call; on TPU the same artifact runs from\n\
         VMEM — see DESIGN.md Hardware-Adaptation.)\n"
    );
    Ok(())
}

/// Section 1b: per-model executable latency.
fn bench_model_steps() -> anyhow::Result<()> {
    println!("\n### micro-step: AOT executable latency per model\n");
    let mut t = Table::new(
        "executable latency",
        &["model", "params", "train_step (ms)", "train_chunk/step (ms)", "eval_step (ms)"],
    );
    for model in ["mlp", "femnist_cnn", "cifar_cnn", "resnet20"] {
        let dir = std::path::Path::new("artifacts").join(model);
        if !dir.join("manifest.json").exists() {
            continue;
        }
        let rt = ModelRuntime::load(&dir)?;
        let mut params = rt.init_params(0)?;
        let b = rt.manifest.batch_size;
        let k = rt.manifest.chunk_k;
        let d: usize = rt.manifest.input_shape.iter().product();
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..k * b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..k * b).map(|i| (i % rt.manifest.num_classes) as i32).collect();
        let reps = if model == "mlp" { 10 } else { 3 };
        let mut ts = Vec::new();
        for _ in 0..reps {
            let s = Instant::now();
            rt.train_step(&mut params, &x[..b * d], &y[..b], 0.05)?;
            ts.push(s.elapsed().as_secs_f64() * 1e3);
        }
        let mut tc = Vec::new();
        for _ in 0..reps {
            let s = Instant::now();
            rt.train_chunk(&mut params, &x, &y, 0.05)?;
            tc.push(s.elapsed().as_secs_f64() * 1e3 / k as f64);
        }
        let eb = rt.manifest.eval_batch_size;
        let ex: Vec<f32> = (0..eb * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let ey: Vec<i32> = (0..eb).map(|i| (i % rt.manifest.num_classes) as i32).collect();
        let mut te = Vec::new();
        for _ in 0..reps {
            let s = Instant::now();
            rt.eval_step(&params, &ex, &ey)?;
            te.push(s.elapsed().as_secs_f64() * 1e3);
        }
        t.row(vec![
            model.to_string(),
            rt.manifest.num_params.to_string(),
            format!("{:.2} ±{:.2}", stats::mean(&ts), stats::stddev(&ts)),
            format!("{:.2} ±{:.2}", stats::mean(&tc), stats::stddev(&tc)),
            format!("{:.2} ±{:.2}", stats::mean(&te), stats::stddev(&te)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Section 1c: the rust<->PJRT literal boundary (what train_chunk amortizes).
fn bench_literal_boundary() -> anyhow::Result<()> {
    println!("\n### micro-boundary: literal construction + readback cost\n");
    let rt = ModelRuntime::load(std::path::Path::new("artifacts/resnet20"))?;
    let params = rt.init_params(0)?;
    let reps = 50;
    let mut build = Vec::new();
    for _ in 0..reps {
        let s = Instant::now();
        let lits: Vec<_> = params.iter().map(|p| p.to_literal().unwrap()).collect();
        build.push(s.elapsed().as_secs_f64() * 1e3);
        std::hint::black_box(lits.len());
    }
    println!(
        "building {} param literals ({} params): {:.2} ±{:.2} ms per call set",
        params.len(),
        rt.manifest.num_params,
        stats::mean(&build),
        stats::stddev(&build)
    );
    println!(
        "-> at chunk_k={} the boundary is paid once per {} local steps\n",
        rt.manifest.chunk_k, rt.manifest.chunk_k
    );
    Ok(())
}

/// Section 2: the paper tables.
fn bench_tables(scale: Scale) -> anyhow::Result<()> {
    let all = std::env::var("BENCH_ALL").ok().is_some_and(|v| v == "1");
    let ids: Vec<&str> = if all {
        presets::ALL_TABLE_IDS.to_vec()
    } else {
        vec!["table1", "table2", "table3", "table4", "table5", "baselines"]
    };
    for id in ids {
        let exp = presets::by_id(id, scale).unwrap();
        println!("\n### {id} ({:?} scale)\n", scale);
        let t0 = Instant::now();
        let results = reports::run_experiment(&exp, 1, false)?;
        println!("{}", reports::render_table(&exp, &results).render());
        eprintln!("[{id} took {:.1}s]", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

/// Section 3: the paper figures (compact textual form).
fn bench_figures() -> anyhow::Result<()> {
    println!("\n### figures\n");
    // Figure 1: crossover curves on resnet20
    let cfg = RunConfig {
        model_dir: "artifacts/resnet20".into(),
        dataset: DatasetKind::Cifar10,
        partition: PartitionKind::Dirichlet { alpha: 0.1 },
        policy: Policy::fedlama(6, 2),
        n_clients: 4,
        samples: 128,
        lr: 0.4,
        warmup_rounds: 0,
        iterations: 24,
        eval_every_rounds: 0,
        eval_examples: 512,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg.clone())?;
    coord.run()?;
    if let Some(ascii) = reports::figure1_ascii(&coord, 56, 12) {
        println!("{ascii}");
    }

    // Figures 2/3: per-layer comm profile, FedAvg vs FedLAMA
    let mk = |policy| RunConfig { policy, iterations: 72, warmup_rounds: 2, ..cfg.clone() };
    let mut avg = Coordinator::new(mk(Policy::fedavg(6)))?;
    let m_avg = avg.run()?;
    let mut lama = Coordinator::new(mk(Policy::fedlama(6, 2)))?;
    let m_lama = lama.run()?;
    let top: Vec<_> = m_avg
        .per_group
        .iter()
        .zip(&m_lama.per_group)
        .filter(|(a, _)| a.1 > 1000)
        .map(|(a, l)| format!("{}(d={}): {} vs {} syncs", a.0, a.1, a.2, l.2))
        .collect();
    println!("Figure 2 (largest layers, FedAvg vs FedLAMA syncs over {} iters):", 72);
    for line in top {
        println!("  {line}");
    }
    println!(
        "Figure 3 totals (Eq.9): FedAvg {} vs FedLAMA {} ({:.1}%)\n",
        m_avg.total_comm_cost,
        m_lama.total_comm_cost,
        100.0 * m_lama.total_comm_cost as f64 / m_avg.total_comm_cost as f64
    );

    // Figures 4-6: learning-curve endpoints (full curves via `fedlama figure`)
    for (fig, model, ds, tau, lr) in [
        (4, "resnet20", DatasetKind::Cifar10, 6usize, 0.4f32),
        (5, "cifar_cnn100", DatasetKind::Cifar100, 6, 0.3),
        (6, "femnist_cnn", DatasetKind::Femnist, 10, 0.06),
    ] {
        let iters = 8 * tau * 4 / 4; // 8 rounds of phi*tau with phi=4
        let partition = if fig == 6 {
            PartitionKind::Writers
        } else {
            PartitionKind::Dirichlet { alpha: 0.1 }
        };
        let mk = |policy| RunConfig {
            model_dir: format!("artifacts/{model}").into(),
            dataset: ds,
            partition,
            policy,
            n_clients: 4,
            samples: 128,
            lr,
            warmup_rounds: 2,
            iterations: iters,
            eval_every_rounds: 0,
            eval_examples: 512,
            ..Default::default()
        };
        let mut lines = Vec::new();
        for (label, policy) in [
            (format!("FedAvg({tau})"), Policy::fedavg(tau)),
            (format!("FedAvg({})", 4 * tau), Policy::fedavg(4 * tau)),
            (format!("FedLAMA({tau},4)"), Policy::fedlama(tau, 4)),
        ] {
            let mut c = Coordinator::new(mk(policy))?;
            let m = c.run()?;
            lines.push(format!(
                "  {label:14} final loss {:.4}, acc {:.2}%, comm {}",
                m.final_loss,
                100.0 * m.final_acc,
                m.total_comm_cost
            ));
        }
        println!("Figure {fig} endpoints ({model}, {iters} iters):");
        for l in lines {
            println!("{l}");
        }
    }
    Ok(())
}
