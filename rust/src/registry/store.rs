//! The store seam: a key/value blob store the registry spills per-client
//! state through.
//!
//! Two implementations share one trait so the coordinator can hold a
//! million-client roster without caring where the bytes live:
//!
//!   - [`MemStore`] — a `BTreeMap`; the default for tests and small runs.
//!   - [`FileStore`] — an append-only log on disk with an in-memory
//!     offset index.  Writes append `[key u64][len u32][value]` records;
//!     reads seek straight to the latest offset for a key
//!     (latest-write-wins).  Reopening rescans the log to rebuild the
//!     index, ignoring a torn tail from an interrupted write, which is
//!     what makes the registry survive a coordinator restart.
//!
//! Values are opaque byte blobs; the registry layers its record and
//! control-variate encodings (`protocol::wire::Enc`/`Dec`) on top.  Keys
//! are namespaced by the registry (client id shifted left, low bit
//! selecting record vs control blob), so one store holds both kinds.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Blob store seam.  `get`/`put` take `&mut self` because the file-backed
/// implementation seeks; the in-memory one simply ignores the mutability.
pub trait StateStore: Send {
    fn put(&mut self, key: u64, value: &[u8]) -> Result<()>;
    fn get(&mut self, key: u64) -> Result<Option<Vec<u8>>>;
    fn contains(&self, key: u64) -> bool;
    /// Number of distinct keys ever written (latest-write-wins).
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Distinct keys in ascending order — checkpoint serialization walks
    /// these so snapshots are byte-deterministic regardless of write order.
    fn keys(&self) -> Vec<u64>;
}

/// In-memory store: the trivial implementation of the seam.
#[derive(Default)]
pub struct MemStore {
    map: BTreeMap<u64, Vec<u8>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }
}

impl StateStore for MemStore {
    fn put(&mut self, key: u64, value: &[u8]) -> Result<()> {
        self.map.insert(key, value.to_vec());
        Ok(())
    }

    fn get(&mut self, key: u64) -> Result<Option<Vec<u8>>> {
        Ok(self.map.get(&key).cloned())
    }

    fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn keys(&self) -> Vec<u64> {
        self.map.keys().copied().collect()
    }
}

/// Record header bytes preceding each value: key(8) + len(4).
const REC_HEADER: u64 = 12;

/// Append-only log store.  The index maps each key to the offset and
/// length of its *latest* value in the log; stale versions stay on disk
/// until the file is rewritten (compaction is not needed for the
/// registry's write pattern — a few counters per sampled client per
/// round).
pub struct FileStore {
    file: File,
    path: PathBuf,
    index: BTreeMap<u64, (u64, u32)>,
    end: u64,
}

impl FileStore {
    /// Open (or create) the log at `path` and rebuild the offset index by
    /// scanning it.  A torn tail — a record whose header or value extends
    /// past the physical end, left by an interrupted write — is ignored
    /// and overwritten by the next append.
    pub fn open(path: &Path) -> Result<FileStore> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("open state store log {}", path.display()))?;
        let len = file.metadata()?.len();
        let mut index = BTreeMap::new();
        let mut pos = 0u64;
        let mut header = [0u8; REC_HEADER as usize];
        file.seek(SeekFrom::Start(0))?;
        while pos + REC_HEADER <= len {
            file.read_exact(&mut header)?;
            let key = u64::from_le_bytes(header[0..8].try_into().unwrap());
            let vlen = u32::from_le_bytes(header[8..12].try_into().unwrap());
            if pos + REC_HEADER + vlen as u64 > len {
                break; // torn tail
            }
            index.insert(key, (pos + REC_HEADER, vlen));
            pos += REC_HEADER + vlen as u64;
            file.seek(SeekFrom::Start(pos))?;
        }
        Ok(FileStore { file, path: path.to_path_buf(), index, end: pos })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes appended to the log so far (stale versions included).
    pub fn log_bytes(&self) -> u64 {
        self.end
    }
}

impl StateStore for FileStore {
    fn put(&mut self, key: u64, value: &[u8]) -> Result<()> {
        let vlen = u32::try_from(value.len())
            .with_context(|| format!("state store value for key {key} exceeds u32 length"))?;
        self.file.seek(SeekFrom::Start(self.end))?;
        self.file.write_all(&key.to_le_bytes())?;
        self.file.write_all(&vlen.to_le_bytes())?;
        self.file.write_all(value)?;
        self.index.insert(key, (self.end + REC_HEADER, vlen));
        self.end += REC_HEADER + vlen as u64;
        Ok(())
    }

    fn get(&mut self, key: u64) -> Result<Option<Vec<u8>>> {
        let Some(&(off, vlen)) = self.index.get(&key) else {
            return Ok(None);
        };
        self.file.seek(SeekFrom::Start(off))?;
        let mut buf = vec![0u8; vlen as usize];
        self.file.read_exact(&mut buf)?;
        Ok(Some(buf))
    }

    fn contains(&self, key: u64) -> bool {
        self.index.contains_key(&key)
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    fn keys(&self) -> Vec<u64> {
        self.index.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(store: &mut dyn StateStore) {
        assert!(store.is_empty());
        store.put(4, b"alpha").unwrap();
        store.put(2, b"beta").unwrap();
        store.put(4, b"gamma").unwrap(); // overwrite: latest wins
        assert_eq!(store.len(), 2);
        assert_eq!(store.keys(), vec![2, 4]);
        assert!(store.contains(2) && store.contains(4) && !store.contains(7));
        assert_eq!(store.get(2).unwrap().as_deref(), Some(&b"beta"[..]));
        assert_eq!(store.get(4).unwrap().as_deref(), Some(&b"gamma"[..]));
        assert_eq!(store.get(9).unwrap(), None);
    }

    #[test]
    fn mem_store_round_trips() {
        roundtrip(&mut MemStore::new());
    }

    #[test]
    fn file_store_round_trips_and_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("fedlama_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.log");
        let _ = std::fs::remove_file(&path);
        {
            let mut fs = FileStore::open(&path).unwrap();
            roundtrip(&mut fs);
        }
        // reopen: the index rebuilds from the log, latest-write-wins intact
        let mut fs = FileStore::open(&path).unwrap();
        assert_eq!(fs.len(), 2);
        assert_eq!(fs.get(4).unwrap().as_deref(), Some(&b"gamma"[..]));
        assert_eq!(fs.get(2).unwrap().as_deref(), Some(&b"beta"[..]));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn file_store_ignores_torn_tail() {
        let dir = std::env::temp_dir().join(format!("fedlama_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.log");
        let _ = std::fs::remove_file(&path);
        {
            let mut fs = FileStore::open(&path).unwrap();
            fs.put(1, b"whole").unwrap();
        }
        // simulate an interrupted write: a header promising more bytes
        // than the file holds
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&9u64.to_le_bytes()).unwrap();
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(b"short").unwrap();
        }
        let mut fs = FileStore::open(&path).unwrap();
        assert_eq!(fs.len(), 1);
        assert_eq!(fs.get(1).unwrap().as_deref(), Some(&b"whole"[..]));
        assert_eq!(fs.get(9).unwrap(), None);
        // the next append lands where the torn record began and reads back
        fs.put(9, b"redo").unwrap();
        assert_eq!(fs.get(9).unwrap().as_deref(), Some(&b"redo"[..]));
        std::fs::remove_file(&path).unwrap();
    }
}
