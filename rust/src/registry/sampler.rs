//! Streaming round sampling over a registered roster.
//!
//! The seed sampler (`clients::ClientSampler`) draws k active clients
//! with `Rng::choose_k`, a partial Fisher–Yates over a materialized
//! `Vec` of all n client ids — O(n) memory per draw, which is exactly
//! what a million-client roster cannot afford.  [`sample_stream`] runs
//! the *same* algorithm against a sparse map of displaced positions
//! instead of the dense vector: it consumes the identical `Rng::below`
//! draws in the identical order and returns the identical indices, but
//! touches at most k map entries, so per-round sampling memory is
//! O(sampled) regardless of roster size.
//!
//! Why the simulation is exact: `choose_k` swaps position `i` with
//! `j = i + below(n - i)` for `i in 0..k` and returns positions `0..k`.
//! Since `j >= i` always, a position below the current `i` is never read
//! again once written — so the value at any position `p` is either its
//! initial identity `p` or whatever the last swap displaced into it, and
//! a map recording only displacements reproduces every read the dense
//! vector would serve.
//!
//! [`RegistrySampler`] wraps the streaming draw with the *same* rng
//! stream derivation as the seed sampler (`fork(0x5A_3317)` off the run
//! seed), the same k-equals-n identity fast path (zero rng draws), and
//! the same sorted output — which is what makes a registry-backed run
//! with registered == sampled bit-identical to the seed across every
//! transport.  Its rng state is exposed for checkpointing so a resumed
//! run re-draws the exact active sets an uninterrupted run would.

use std::collections::HashMap;

use crate::util::rng::Rng;

/// Stream identifier for the round sampler — must match
/// `clients::ClientSampler` so both paths draw the same sequence.
pub const SAMPLER_STREAM: u64 = 0x5A_3317;

/// Draw `k` distinct indices from `[0, n)` consuming exactly the same
/// rng draws as `Rng::choose_k(n, k)` and returning the same indices in
/// the same order, in O(k) memory.
pub fn sample_stream(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n, "cannot sample {k} of {n}");
    // displaced[p] = value a prior swap moved into position p
    let mut displaced: HashMap<usize, usize> = HashMap::with_capacity(k * 2);
    let mut out = Vec::with_capacity(k);
    for i in 0..k {
        let j = i + rng.below(n - i);
        let vj = displaced.get(&j).copied().unwrap_or(j);
        let vi = displaced.get(&i).copied().unwrap_or(i);
        out.push(vj);
        displaced.insert(j, vi);
    }
    out
}

/// Round sampler over a registered roster: draws the active set for each
/// round directly from registry *size*, never materializing the roster.
pub struct RegistrySampler {
    /// Total registered clients (the roster size).
    pub n_registered: usize,
    /// Clients sampled per round.
    pub n_active: usize,
    rng: Rng,
}

impl RegistrySampler {
    /// `n_active` must already be validated against the roster
    /// (`RunConfig::validate` errors loudly on k == 0 or k > registered);
    /// the assertions here are the last line of defense for direct use.
    pub fn new(n_registered: usize, n_active: usize, seed: u64) -> RegistrySampler {
        assert!(n_registered > 0, "empty roster");
        assert!(
            n_active >= 1 && n_active <= n_registered,
            "sampled {n_active} outside [1, {n_registered}]"
        );
        RegistrySampler { n_registered, n_active, rng: Rng::new(seed).fork(SAMPLER_STREAM) }
    }

    /// Active client ids for the next round, ascending.  Full
    /// participation is the identity and consumes no rng draws — the
    /// seed sampler's fast path, preserved for bit-identity.
    pub fn sample(&mut self) -> Vec<usize> {
        if self.n_active == self.n_registered {
            return (0..self.n_registered).collect();
        }
        let mut ids = sample_stream(&mut self.rng, self.n_registered, self.n_active);
        ids.sort_unstable();
        ids
    }

    /// Rng snapshot for checkpointing.
    pub fn rng_state(&self) -> ([u64; 4], Option<f64>) {
        self.rng.state()
    }

    /// Restore the rng from a checkpoint snapshot.
    pub fn restore_rng(&mut self, s: [u64; 4], spare: Option<f64>) {
        self.rng = Rng::from_state(s, spare);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance-critical property: the streaming draw is an exact
    /// simulation of the eager `choose_k` — same draws, same output —
    /// across sizes, fractions, seeds, and consecutive rounds sharing
    /// one rng stream.
    #[test]
    fn stream_matches_eager_choose_k_exactly() {
        for seed in 0..20u64 {
            for &(n, k) in &[(1usize, 1usize), (5, 2), (64, 1), (64, 63), (100, 10), (1000, 7)] {
                let mut eager = Rng::new(seed).fork(SAMPLER_STREAM);
                let mut stream = Rng::new(seed).fork(SAMPLER_STREAM);
                for round in 0..5 {
                    let want = eager.choose_k(n, k);
                    let got = sample_stream(&mut stream, n, k);
                    assert_eq!(got, want, "n={n} k={k} seed={seed} round={round}");
                    // and the rng streams stay in lockstep after the draw
                    assert_eq!(eager.next_u64(), stream.next_u64());
                }
            }
        }
    }

    #[test]
    fn stream_memory_is_o_of_k() {
        // 10M roster, 100 sampled: would be a 80MB Vec on the eager path;
        // here only the displacement map exists.  Completing instantly is
        // the test.
        let mut rng = Rng::new(3).fork(SAMPLER_STREAM);
        let ids = sample_stream(&mut rng, 10_000_000, 100);
        assert_eq!(ids.len(), 100);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100, "indices must be distinct");
        assert!(sorted.iter().all(|&i| i < 10_000_000));
    }

    #[test]
    fn registry_sampler_is_deterministic_per_seed_and_round() {
        let mut a = RegistrySampler::new(10_000, 50, 42);
        let mut b = RegistrySampler::new(10_000, 50, 42);
        let mut other = RegistrySampler::new(10_000, 50, 43);
        let mut prev: Option<Vec<usize>> = None;
        for _ in 0..8 {
            let sa = a.sample();
            let sb = b.sample();
            assert_eq!(sa, sb, "same (seed, round) must agree");
            assert!(sa.windows(2).all(|w| w[0] < w[1]), "sorted distinct");
            if let Some(p) = prev {
                assert_ne!(p, sa, "rounds advance the stream");
            }
            prev = Some(sa);
        }
        assert_ne!(a.sample(), other.sample(), "different seeds diverge");
    }

    #[test]
    fn full_participation_is_identity_without_draws() {
        let mut s = RegistrySampler::new(12, 12, 7);
        assert_eq!(s.sample(), (0..12).collect::<Vec<_>>());
        // no draws happened: the stream equals a fresh fork
        let mut fresh = Rng::new(7).fork(SAMPLER_STREAM);
        let (state, _) = s.rng_state();
        let (want, _) = fresh.state();
        assert_eq!(state, want);
        let _ = fresh.next_u64();
    }

    #[test]
    fn rng_state_round_trips_through_checkpoint() {
        let mut live = RegistrySampler::new(500, 20, 11);
        let _ = live.sample();
        let (s, spare) = live.rng_state();
        let mut resumed = RegistrySampler::new(500, 20, 11);
        resumed.restore_rng(s, spare);
        for _ in 0..4 {
            assert_eq!(live.sample(), resumed.sample());
        }
    }
}
