//! Client registry subsystem: a persistent million-client roster behind
//! a store seam.
//!
//! The seed coordinator materializes every client each round, so memory
//! and scheduling are O(total clients) — the opposite of the paper's
//! scalability premise.  This module inverts that: clients are
//! *registered*, not resident.  A [`ClientRegistry`] records per-client
//! state (data size, partition seed, last-seen round, cumulative bytes,
//! and SCAFFOLD control variates) in a [`store::StateStore`] —
//! in-memory or spilled to an append-only log on disk — while
//! [`sampler::RegistrySampler`] draws the k active clients per round in
//! O(k) memory via a streaming Fisher–Yates that is bit-identical to the
//! seed sampler.  The split mirrors xaynet's `state_machine`/`storage`
//! layering: coordinator logic never touches bytes-at-rest directly, so
//! the process can restart mid-run ([`checkpoint`]).
//!
//! Records are **lazily defaulted**: a client that has never been
//! touched costs zero store entries — its record derives
//! deterministically from `(id, run seed)` on first read.  Only clients
//! that have actually participated are written back, which is what keeps
//! coordinator memory O(sampled) with a million registered.

pub mod checkpoint;
pub mod sampler;
pub mod store;

use anyhow::{ensure, Context, Result};

use crate::protocol::wire::{Dec, Enc};
use crate::runtime::HostTensor;
use store::{MemStore, StateStore};

/// Sentinel for "never seen" in the wire encoding of `last_seen_round`.
const NEVER: u64 = u64::MAX;

/// Per-client roster entry.  `data_size` is the client's local example
/// count (0 until its first participation reports one); `partition_seed`
/// is the deterministic per-client stream seed the data partition forks
/// from; the byte counters accumulate across rounds, surviving sampling
/// gaps and rejoin.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClientRecord {
    pub data_size: usize,
    pub partition_seed: u64,
    pub last_seen_round: Option<usize>,
    pub updates: u64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
}

impl ClientRecord {
    /// The record every client implicitly has before its first write:
    /// derived from `(id, seed)` alone, so an untouched client costs no
    /// store entry and any two coordinators derive the same roster.
    pub fn derived(id: usize, seed: u64) -> ClientRecord {
        ClientRecord {
            data_size: 0,
            partition_seed: seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            last_seen_round: None,
            updates: 0,
            uplink_bytes: 0,
            downlink_bytes: 0,
        }
    }

    pub fn encode(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.usize(self.data_size);
        e.u64(self.partition_seed);
        e.u64(self.last_seen_round.map_or(NEVER, |r| r as u64));
        e.u64(self.updates);
        e.u64(self.uplink_bytes);
        e.u64(self.downlink_bytes);
        Ok(e.buf)
    }

    pub fn decode(bytes: &[u8]) -> Result<ClientRecord> {
        let mut d = Dec::new(bytes);
        let rec = ClientRecord {
            data_size: d.usize()?,
            partition_seed: d.u64()?,
            last_seen_round: match d.u64()? {
                NEVER => None,
                r => Some(r as usize),
            },
            updates: d.u64()?,
            uplink_bytes: d.u64()?,
            downlink_bytes: d.u64()?,
        };
        d.finish()?;
        Ok(rec)
    }
}

/// Encode a control-variate tensor list (SCAFFOLD per-client state) as a
/// store blob.  Bit-exact: f32 payloads travel as IEEE bit patterns.
pub fn encode_tensors(tensors: &[HostTensor]) -> Result<Vec<u8>> {
    let mut e = Enc::new();
    e.u32(tensors.len() as u32);
    for t in tensors {
        e.usizes(&t.shape)?;
        e.f32s(&t.data)?;
    }
    Ok(e.buf)
}

/// Decode a [`encode_tensors`] blob.
pub fn decode_tensors(bytes: &[u8]) -> Result<Vec<HostTensor>> {
    let mut d = Dec::new(bytes);
    let n = d.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let shape = d.usizes()?;
        let data = d.f32s()?;
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "control tensor shape/data mismatch"
        );
        out.push(HostTensor { shape, data });
    }
    d.finish()?;
    Ok(out)
}

/// The persistent roster.  Holds only the roster *size* and the store
/// handle in memory; per-client state lives behind the seam.
pub struct ClientRegistry {
    n_registered: usize,
    seed: u64,
    store: Box<dyn StateStore>,
}

/// High-bit namespace for personalized per-client layer mixing weights
/// (pFedLA-style `--policy personalized` state).  Keeps the lambda blobs
/// out of the record/control key space so roster accounting
/// ([`ClientRegistry::touched`], [`ClientRegistry::spilled_controls`])
/// stays honest, while still riding `encode_state` into checkpoints.
const PERS_BIT: u64 = 1 << 63;

fn rec_key(id: usize) -> u64 {
    (id as u64) << 1
}

fn ctl_key(id: usize) -> u64 {
    ((id as u64) << 1) | 1
}

fn pers_key(id: usize) -> u64 {
    PERS_BIT | ((id as u64) << 1)
}

impl ClientRegistry {
    pub fn new(n_registered: usize, seed: u64, store: Box<dyn StateStore>) -> ClientRegistry {
        assert!(n_registered > 0, "empty roster");
        ClientRegistry { n_registered, seed, store }
    }

    /// In-memory roster — the default for ordinary runs.
    pub fn in_memory(n_registered: usize, seed: u64) -> ClientRegistry {
        ClientRegistry::new(n_registered, seed, Box::new(MemStore::new()))
    }

    /// Registered roster size.
    pub fn len(&self) -> usize {
        self.n_registered
    }

    pub fn is_empty(&self) -> bool {
        self.n_registered == 0
    }

    /// Clients with at least one written record — the resident set, which
    /// stays O(sampled x rounds), not O(registered).
    pub fn touched(&self) -> usize {
        self.store.keys().iter().filter(|k| *k & PERS_BIT == 0 && *k % 2 == 0).count()
    }

    /// Clients with a spilled control-variate blob.
    pub fn spilled_controls(&self) -> usize {
        self.store.keys().iter().filter(|k| *k & PERS_BIT == 0 && *k % 2 == 1).count()
    }

    /// Client ids with a spilled control-variate blob, ascending — the
    /// iteration order for rejoin/resume catchup broadcasts.
    pub fn spilled_control_ids(&self) -> Vec<usize> {
        self.store
            .keys()
            .iter()
            .filter(|k| *k & PERS_BIT == 0 && *k % 2 == 1)
            .map(|k| (k >> 1) as usize)
            .collect()
    }

    fn check_id(&self, id: usize) -> Result<()> {
        ensure!(id < self.n_registered, "client {id} outside roster of {}", self.n_registered);
        Ok(())
    }

    /// The client's record — stored if ever written, derived otherwise.
    pub fn record(&mut self, id: usize) -> Result<ClientRecord> {
        self.check_id(id)?;
        match self.store.get(rec_key(id))? {
            Some(bytes) => ClientRecord::decode(&bytes)
                .with_context(|| format!("corrupt registry record for client {id}")),
            None => Ok(ClientRecord::derived(id, self.seed)),
        }
    }

    fn write(&mut self, id: usize, rec: &ClientRecord) -> Result<()> {
        self.store.put(rec_key(id), &rec.encode()?)
    }

    /// Mark a client as having participated in `round` with `data_size`
    /// local examples, bumping its update counter.
    pub fn note_seen(&mut self, id: usize, round: usize, data_size: usize) -> Result<()> {
        let mut rec = self.record(id)?;
        rec.last_seen_round = Some(round);
        if data_size > 0 {
            rec.data_size = data_size;
        }
        rec.updates += 1;
        self.write(id, &rec)
    }

    /// Accumulate wire bytes attributed to a client (Eq.9 accounting at
    /// registry granularity).
    pub fn note_bytes(&mut self, id: usize, uplink: u64, downlink: u64) -> Result<()> {
        let mut rec = self.record(id)?;
        rec.uplink_bytes += uplink;
        rec.downlink_bytes += downlink;
        self.write(id, &rec)
    }

    /// Spill a client's SCAFFOLD control variates through the seam.
    pub fn put_control(&mut self, id: usize, tensors: &[HostTensor]) -> Result<()> {
        self.check_id(id)?;
        self.store.put(ctl_key(id), &encode_tensors(tensors)?)
    }

    /// Load a client's spilled control variates, if any.
    pub fn control(&mut self, id: usize) -> Result<Option<Vec<HostTensor>>> {
        self.check_id(id)?;
        match self.store.get(ctl_key(id))? {
            Some(bytes) => Ok(Some(decode_tensors(&bytes)?)),
            None => Ok(None),
        }
    }

    /// Store a client's personalized per-group layer mixing weights
    /// (lambda, one f32 per group).  Rides `encode_state` into
    /// checkpoints like every other spilled blob.
    pub fn put_mix_weights(&mut self, id: usize, lambda: &[f32]) -> Result<()> {
        self.check_id(id)?;
        let mut e = Enc::new();
        e.f32s(lambda)?;
        self.store.put(pers_key(id), &e.buf)
    }

    /// Load a client's personalized mixing weights, if any were stored.
    pub fn mix_weights(&mut self, id: usize) -> Result<Option<Vec<f32>>> {
        self.check_id(id)?;
        match self.store.get(pers_key(id))? {
            Some(bytes) => {
                let mut d = Dec::new(&bytes);
                let lambda = d.f32s()?;
                d.finish()?;
                Ok(Some(lambda))
            }
            None => Ok(None),
        }
    }

    /// Serialize every touched entry (records and control blobs) into a
    /// checkpoint body.  Keys ascend, so the bytes are deterministic.
    pub fn encode_state(&mut self, e: &mut Enc) -> Result<()> {
        e.usize(self.n_registered);
        e.u64(self.seed);
        let keys = self.store.keys();
        e.u32(keys.len() as u32);
        for k in keys {
            let blob = self.store.get(k)?.expect("listed key must resolve");
            e.u64(k);
            e.bytes(&blob)?;
        }
        Ok(())
    }

    /// Restore touched entries from a checkpoint body into this registry's
    /// store (which may be a different backend than the one that wrote
    /// the snapshot — the seam makes them interchangeable).
    pub fn decode_state(&mut self, d: &mut Dec) -> Result<()> {
        let n_registered = d.usize()?;
        let seed = d.u64()?;
        ensure!(
            n_registered == self.n_registered && seed == self.seed,
            "checkpoint registry shape mismatch: snapshot {n_registered} clients seed {seed}, \
             run has {} clients seed {}",
            self.n_registered,
            self.seed
        );
        let n = d.u32()? as usize;
        for _ in 0..n {
            let k = d.u64()?;
            let blob = d.bytes()?;
            self.store.put(k, &blob)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untouched_clients_derive_and_cost_nothing() {
        let mut reg = ClientRegistry::in_memory(1_000_000, 42);
        let a = reg.record(0).unwrap();
        let b = reg.record(999_999).unwrap();
        assert_ne!(a.partition_seed, b.partition_seed);
        assert_eq!(a.last_seen_round, None);
        assert_eq!(reg.touched(), 0, "reads must not materialize records");
        // same (id, seed) derives the same record in a fresh registry
        let mut other = ClientRegistry::in_memory(1_000_000, 42);
        assert_eq!(other.record(0).unwrap(), a);
    }

    #[test]
    fn participation_and_bytes_accumulate_across_rounds() {
        let mut reg = ClientRegistry::in_memory(100, 7);
        reg.note_seen(3, 0, 250).unwrap();
        reg.note_bytes(3, 1000, 4000).unwrap();
        reg.note_seen(3, 5, 250).unwrap(); // rejoin after a sampling gap
        reg.note_bytes(3, 1000, 4000).unwrap();
        let rec = reg.record(3).unwrap();
        assert_eq!(rec.last_seen_round, Some(5));
        assert_eq!(rec.updates, 2);
        assert_eq!(rec.uplink_bytes, 2000);
        assert_eq!(rec.downlink_bytes, 8000);
        assert_eq!(rec.data_size, 250);
        assert_eq!(reg.touched(), 1);
    }

    #[test]
    fn record_wire_round_trip_is_exact() {
        let rec = ClientRecord {
            data_size: 123,
            partition_seed: 0xDEAD_BEEF,
            last_seen_round: Some(17),
            updates: 9,
            uplink_bytes: u64::MAX - 1,
            downlink_bytes: 0,
        };
        assert_eq!(ClientRecord::decode(&rec.encode().unwrap()).unwrap(), rec);
        let never = ClientRecord::derived(5, 1);
        assert_eq!(ClientRecord::decode(&never.encode().unwrap()).unwrap(), never);
    }

    #[test]
    fn control_variates_spill_and_load_bit_identically() {
        let mut reg = ClientRegistry::in_memory(10, 3);
        let tensors = vec![
            HostTensor { shape: vec![2, 3], data: vec![1.5, -0.0, f32::MIN_POSITIVE, 3.25, -7.0, 0.1] },
            HostTensor { shape: vec![4], data: vec![f32::NAN, 1.0, -1.0, 2.0f32.powi(-120)] },
        ];
        reg.put_control(4, &tensors).unwrap();
        let got = reg.control(4).unwrap().unwrap();
        assert_eq!(got.len(), 2);
        for (g, w) in got.iter().zip(&tensors) {
            assert_eq!(g.shape, w.shape);
            let gb: Vec<u32> = g.data.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = w.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "bit-exact including NaN and -0.0");
        }
        assert_eq!(reg.control(5).unwrap(), None);
        assert_eq!(reg.spilled_controls(), 1);
        assert_eq!(reg.touched(), 0, "control blobs are not roster records");
    }

    #[test]
    fn mix_weights_live_in_their_own_namespace() {
        let mut reg = ClientRegistry::in_memory(20, 11);
        assert_eq!(reg.mix_weights(4).unwrap(), None);
        reg.put_mix_weights(4, &[0.25, -0.0, 1.0]).unwrap();
        reg.put_control(4, &[HostTensor { shape: vec![1], data: vec![2.0] }]).unwrap();
        let lam = reg.mix_weights(4).unwrap().unwrap();
        let bits: Vec<u32> = lam.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, vec![0.25f32.to_bits(), (-0.0f32).to_bits(), 1.0f32.to_bits()]);
        // lambda blobs must not pollute roster accounting
        assert_eq!(reg.touched(), 0);
        assert_eq!(reg.spilled_controls(), 1);
        assert_eq!(reg.spilled_control_ids(), vec![4]);
        // overwrite sticks
        reg.put_mix_weights(4, &[0.5]).unwrap();
        assert_eq!(reg.mix_weights(4).unwrap().unwrap(), vec![0.5]);
        // and the namespace rides the checkpoint encoding
        let mut e = Enc::new();
        reg.encode_state(&mut e).unwrap();
        let mut restored = ClientRegistry::in_memory(20, 11);
        let mut d = Dec::new(&e.buf);
        restored.decode_state(&mut d).unwrap();
        d.finish().unwrap();
        assert_eq!(restored.mix_weights(4).unwrap().unwrap(), vec![0.5]);
        assert_eq!(restored.spilled_controls(), 1);
    }

    #[test]
    fn state_round_trips_through_checkpoint_encoding() {
        let mut reg = ClientRegistry::in_memory(50, 9);
        reg.note_seen(1, 0, 10).unwrap();
        reg.note_bytes(1, 5, 6).unwrap();
        reg.note_seen(30, 2, 20).unwrap();
        reg.put_control(30, &[HostTensor { shape: vec![2], data: vec![0.5, -0.5] }]).unwrap();

        let mut e = Enc::new();
        reg.encode_state(&mut e).unwrap();

        let mut restored = ClientRegistry::in_memory(50, 9);
        let mut d = Dec::new(&e.buf);
        restored.decode_state(&mut d).unwrap();
        d.finish().unwrap();
        assert_eq!(restored.record(1).unwrap(), reg.record(1).unwrap());
        assert_eq!(restored.record(30).unwrap(), reg.record(30).unwrap());
        assert_eq!(restored.control(30).unwrap(), reg.control(30).unwrap());
        assert_eq!(restored.touched(), 2);

        // shape mismatch is refused loudly
        let mut wrong = ClientRegistry::in_memory(51, 9);
        let mut d = Dec::new(&e.buf);
        assert!(wrong.decode_state(&mut d).is_err());
    }
}
