//! Checkpoint file format and atomic write/read helpers.
//!
//! A checkpoint is one file, `fedlama.ckpt`, inside the directory passed
//! to `--checkpoint-dir`:
//!
//! ```text
//!   file := magic("FLCK") version(u32 LE) len(u64 LE) body(len) crc32(u32 LE)
//! ```
//!
//! The body is an opaque `protocol::wire::Enc` blob produced by
//! `CoordinatorCore::encode_checkpoint` (config fingerprint, round
//! cursor, global tensors, schedule intervals, ledger, sampler rng,
//! registry state).  The CRC covers the body, so a torn or bit-flipped
//! snapshot is rejected at `--resume` instead of silently corrupting the
//! run.  Writes go to a `.tmp` sibling first and `rename` into place —
//! on the same filesystem that is atomic, so a crash mid-snapshot leaves
//! the previous checkpoint intact.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::protocol::wire::crc32;

pub const CHECKPOINT_FILE: &str = "fedlama.ckpt";
pub const CHECKPOINT_MAGIC: [u8; 4] = *b"FLCK";
pub const CHECKPOINT_VERSION: u32 = 1;

/// The checkpoint path inside a `--checkpoint-dir`.
pub fn path_in(dir: &Path) -> PathBuf {
    dir.join(CHECKPOINT_FILE)
}

/// Does `dir` hold a checkpoint file (readable or not)?
pub fn exists(dir: &Path) -> bool {
    path_in(dir).is_file()
}

/// Atomically replace the checkpoint in `dir` with `body`.
pub fn write_atomic(dir: &Path, body: &[u8]) -> Result<()> {
    fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let tmp = dir.join(format!("{CHECKPOINT_FILE}.tmp"));
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("create checkpoint tmp {}", tmp.display()))?;
        f.write_all(&CHECKPOINT_MAGIC)?;
        f.write_all(&CHECKPOINT_VERSION.to_le_bytes())?;
        f.write_all(&(body.len() as u64).to_le_bytes())?;
        f.write_all(body)?;
        f.write_all(&crc32(body).to_le_bytes())?;
        f.sync_all().with_context(|| format!("sync checkpoint tmp {}", tmp.display()))?;
    }
    fs::rename(&tmp, path_in(dir))
        .with_context(|| format!("publish checkpoint into {}", dir.display()))?;
    Ok(())
}

/// Read and verify the checkpoint body from `dir`.
pub fn read(dir: &Path) -> Result<Vec<u8>> {
    let path = path_in(dir);
    let bytes =
        fs::read(&path).with_context(|| format!("read checkpoint {}", path.display()))?;
    ensure!(bytes.len() >= 16, "checkpoint too short ({} bytes)", bytes.len());
    ensure!(bytes[0..4] == CHECKPOINT_MAGIC, "not a fedlama checkpoint (bad magic)");
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != CHECKPOINT_VERSION {
        bail!("checkpoint version {version} unsupported (this build writes {CHECKPOINT_VERSION})");
    }
    let len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    ensure!(
        bytes.len() == 16 + len + 4,
        "checkpoint truncated: header promises {len} body bytes, file holds {}",
        bytes.len().saturating_sub(20)
    );
    let body = &bytes[16..16 + len];
    let want = u32::from_le_bytes(bytes[16 + len..].try_into().unwrap());
    ensure!(crc32(body) == want, "checkpoint CRC mismatch — snapshot is corrupt");
    Ok(body.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fedlama_ckpt_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn write_read_round_trip() {
        let dir = tmpdir("rt");
        assert!(!exists(&dir));
        write_atomic(&dir, b"round 3 state").unwrap();
        assert!(exists(&dir));
        assert_eq!(read(&dir).unwrap(), b"round 3 state");
        // overwrite is atomic-replace, latest wins
        write_atomic(&dir, b"round 4 state").unwrap();
        assert_eq!(read(&dir).unwrap(), b"round 4 state");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_is_rejected() {
        let dir = tmpdir("bad");
        write_atomic(&dir, b"precious bytes").unwrap();
        let path = path_in(&dir);
        let mut bytes = fs::read(&path).unwrap();
        let mid = 16 + 4;
        bytes[mid] ^= 0x40; // flip a body bit
        fs::write(&path, &bytes).unwrap();
        let err = read(&dir).unwrap_err().to_string();
        assert!(err.contains("CRC"), "want CRC error, got: {err}");
        // truncation is also refused
        write_atomic(&dir, b"precious bytes").unwrap();
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(read(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }
}
