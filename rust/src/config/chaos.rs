//! Deterministic fault injection: the `--chaos SPEC` plan.
//!
//! A `FaultPlan` turns designated participants adversarial and injects
//! wire-level faults into the TCP path, every injection drawn from a
//! dedicated seeded rng stream so a chaos run is exactly replayable and
//! bit-identical across transports with the same shard count.
//!
//! ```text
//!   spec  := fault (',' fault)*
//!   fault := 'signflip' [':N']            -- shards 0..N sign-flip uplinks
//!          | 'scale' ':Fx' [':N']         -- shards 0..N scale uplinks by F
//!          | 'noise' [':SIGMA'] [':N']    -- shards 0..N add N(0, SIGMA^2)
//!          | 'stall' [':N']               -- server trickles writes to 0..N
//!          | 'corrupt-frame' [':N']       -- server flips one bit in a frame
//!          each optionally suffixed '@rK' -- active from round K on
//!                                            (corrupt-frame: at round K only)
//! ```
//!
//! Examples: `signflip:2@r3`, `scale:10x:1`, `noise`, `stall`,
//! `corrupt-frame@r2`, `signflip:1,stall:1@r4`.
//!
//! Attackers are always the *lowest* N shard ids — a deterministic choice
//! so two executions and two transports designate the same participants.
//! Payload attacks (signflip/scale/noise) are produced client-side in
//! `Participant::encode_update`, before compression, so they ride every
//! transport identically; wire faults (stall, corrupt-frame) are injected
//! by the TCP server's write path and are inert no-ops on the in-proc and
//! stdio transports.

use anyhow::{bail, ensure, Context, Result};

/// What one fault entry does.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Negate every uplink value (a gradient-ascent Byzantine client).
    SignFlip,
    /// Multiply every uplink value by `factor`.
    Scale { factor: f32 },
    /// Add gaussian noise with this standard deviation to every uplink
    /// value, drawn from the per-(block, group, client) chaos stream.
    Noise { sigma: f32 },
    /// Server trickles its writes to the shard in tiny delayed chunks
    /// (exercises the partial-write/reassembly path; numerics untouched).
    Stall,
    /// Server flips one rng-chosen bit in one outbound frame body — the
    /// peer's CRC check rejects it, the connection drops, and the shard
    /// departs (survivable only under `--quorum Q < N`).
    CorruptFrame,
}

impl FaultKind {
    /// Does this fault corrupt uplink *content* (client-side)?
    pub fn is_payload(&self) -> bool {
        matches!(self, FaultKind::SignFlip | FaultKind::Scale { .. } | FaultKind::Noise { .. })
    }
}

/// One parsed fault entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    pub kind: FaultKind,
    /// Affected shards: the lowest `shards` ids.
    pub shards: usize,
    /// First affected round (corrupt-frame: the only affected round).
    pub from_round: usize,
}

impl Fault {
    fn applies(&self, shard: usize, round: usize) -> bool {
        shard < self.shards
            && match self.kind {
                FaultKind::CorruptFrame => round == self.from_round,
                _ => round >= self.from_round,
            }
    }
}

/// The full `--chaos` plan (empty spec = no faults).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(FaultPlan::default());
        }
        let mut faults = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            let (body, round) = match entry.split_once('@') {
                Some((b, r)) => {
                    let r = r
                        .strip_prefix('r')
                        .with_context(|| format!("bad --chaos round suffix in {entry:?} (use @rK)"))?;
                    let k: usize = r
                        .parse()
                        .with_context(|| format!("bad --chaos round suffix in {entry:?}"))?;
                    (b, Some(k))
                }
                None => (entry, None),
            };
            let mut parts = body.split(':');
            let name = parts.next().unwrap_or("");
            let args: Vec<&str> = parts.collect();
            let parse_shards = |a: Option<&&str>| -> Result<usize> {
                match a {
                    Some(s) => {
                        let n: usize = s
                            .parse()
                            .with_context(|| format!("bad --chaos shard count in {entry:?}"))?;
                        ensure!(n >= 1, "bad --chaos entry {entry:?}: shard count must be >= 1");
                        Ok(n)
                    }
                    None => Ok(1),
                }
            };
            let (kind, shards, default_round) = match name {
                "signflip" => {
                    ensure!(args.len() <= 1, "bad --chaos entry {entry:?}: signflip[:N]");
                    (FaultKind::SignFlip, parse_shards(args.first())?, 0)
                }
                "scale" => {
                    ensure!(
                        !args.is_empty() && args.len() <= 2,
                        "bad --chaos entry {entry:?}: scale:Fx[:N]"
                    );
                    let f = args[0].strip_suffix('x').unwrap_or(args[0]);
                    let factor: f32 = f
                        .parse()
                        .with_context(|| format!("bad --chaos scale factor in {entry:?}"))?;
                    ensure!(
                        factor.is_finite() && factor > 0.0,
                        "bad --chaos entry {entry:?}: scale factor must be finite and > 0"
                    );
                    (FaultKind::Scale { factor }, parse_shards(args.get(1))?, 0)
                }
                "noise" => {
                    ensure!(args.len() <= 2, "bad --chaos entry {entry:?}: noise[:SIGMA][:N]");
                    let sigma: f32 = match args.first() {
                        Some(s) => s
                            .parse()
                            .with_context(|| format!("bad --chaos noise sigma in {entry:?}"))?,
                        None => 1.0,
                    };
                    ensure!(
                        sigma.is_finite() && sigma > 0.0,
                        "bad --chaos entry {entry:?}: noise sigma must be finite and > 0"
                    );
                    (FaultKind::Noise { sigma }, parse_shards(args.get(1))?, 0)
                }
                "stall" => {
                    ensure!(args.len() <= 1, "bad --chaos entry {entry:?}: stall[:N]");
                    (FaultKind::Stall, parse_shards(args.first())?, 0)
                }
                "corrupt-frame" => {
                    ensure!(args.len() <= 1, "bad --chaos entry {entry:?}: corrupt-frame[:N]");
                    (FaultKind::CorruptFrame, parse_shards(args.first())?, 1)
                }
                other => bail!(
                    "bad --chaos fault {other:?} in {spec:?} \
                     (signflip[:N]|scale:Fx[:N]|noise[:SIGMA][:N]|stall[:N]|corrupt-frame[:N], \
                     each optionally @rK, comma-separated)"
                ),
            };
            faults.push(Fault { kind, shards, from_round: round.unwrap_or(default_round) });
        }
        Ok(FaultPlan { faults })
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Largest shard count any entry designates (validation bound).
    pub fn max_shards(&self) -> usize {
        self.faults.iter().map(|f| f.shards).max().unwrap_or(0)
    }

    /// Does any entry inject a departing wire fault (corrupt-frame)?
    pub fn has_corrupt_frame(&self) -> bool {
        self.faults.iter().any(|f| f.kind == FaultKind::CorruptFrame)
    }

    /// Is `shard` a payload attacker (signflip/scale/noise) at `round`?
    pub fn attacks_payload(&self, shard: usize, round: usize) -> bool {
        self.faults.iter().any(|f| f.kind.is_payload() && f.applies(shard, round))
    }

    /// Should the server trickle writes to `shard` at `round`?
    pub fn stalls(&self, shard: usize, round: usize) -> bool {
        self.faults.iter().any(|f| f.kind == FaultKind::Stall && f.applies(shard, round))
    }

    /// Should the server corrupt one outbound frame to `shard` at `round`?
    pub fn corrupts_frame(&self, shard: usize, round: usize) -> bool {
        self.faults.iter().any(|f| f.kind == FaultKind::CorruptFrame && f.applies(shard, round))
    }

    /// Mangler for one (block, group, client) uplink message, or `None`
    /// when `shard` is honest at `round`.  The rng stream is keyed by
    /// (seed, block, group, client) — never by transport or arrival order —
    /// so the attack bytes are identical on every transport.
    pub fn uplink_mangler(
        &self,
        shard: usize,
        round: usize,
        seed: u64,
        k: usize,
        group: usize,
        client: usize,
    ) -> Option<UplinkMangler<'_>> {
        let faults: Vec<&Fault> = self
            .faults
            .iter()
            .filter(|f| f.kind.is_payload() && f.applies(shard, round))
            .collect();
        if faults.is_empty() {
            return None;
        }
        Some(UplinkMangler { faults, rng: ChaosRng::new(chaos_stream_seed(seed, k, group, client)) })
    }
}

/// Applies one message's payload faults tensor-by-tensor; the embedded rng
/// advances across tensors in layer order, so noise draws are a pure
/// function of (seed, block, group, client, element index).
pub struct UplinkMangler<'a> {
    faults: Vec<&'a Fault>,
    rng: ChaosRng,
}

impl UplinkMangler<'_> {
    pub fn apply(&mut self, buf: &mut [f32]) {
        for fault in &self.faults {
            match fault.kind {
                FaultKind::SignFlip => {
                    for x in buf.iter_mut() {
                        *x = -*x;
                    }
                }
                FaultKind::Scale { factor } => {
                    for x in buf.iter_mut() {
                        *x *= factor;
                    }
                }
                FaultKind::Noise { sigma } => {
                    for x in buf.iter_mut() {
                        *x += sigma * self.rng.normal();
                    }
                }
                // wire faults never reach the payload path
                FaultKind::Stall | FaultKind::CorruptFrame => {}
            }
        }
    }
}

/// Dedicated chaos stream seed: the same splitmix-style mixing the
/// compressor streams use, under a distinct domain tag so chaos draws can
/// never collide with compression draws for the same (k, group, client).
pub fn chaos_stream_seed(seed: u64, k: usize, group: usize, client: usize) -> u64 {
    let mut h = seed ^ 0xC4A0_5C0F_FEED_FACE;
    for v in [k as u64, group as u64, client as u64] {
        h = splitmix(h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    }
    h
}

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Tiny deterministic rng for chaos draws (splitmix64 sequence).
#[derive(Debug, Clone)]
pub struct ChaosRng {
    state: u64,
}

impl ChaosRng {
    pub fn new(seed: u64) -> ChaosRng {
        ChaosRng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        splitmix(self.state)
    }

    /// Uniform draw in (0, 1] (never 0, safe under `ln`).
    fn unit(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.  Draws a fresh pair every call (no
    /// cached spare) so the draw count per element is always exactly two —
    /// simpler to replay than spare-caching.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.unit();
        let u2 = self.unit();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_parses_the_documented_examples() {
        let p = FaultPlan::parse("signflip:2@r3").unwrap();
        assert_eq!(
            p.faults,
            vec![Fault { kind: FaultKind::SignFlip, shards: 2, from_round: 3 }]
        );
        let p = FaultPlan::parse("scale:10x:1").unwrap();
        assert_eq!(
            p.faults,
            vec![Fault { kind: FaultKind::Scale { factor: 10.0 }, shards: 1, from_round: 0 }]
        );
        let p = FaultPlan::parse("noise").unwrap();
        assert_eq!(
            p.faults,
            vec![Fault { kind: FaultKind::Noise { sigma: 1.0 }, shards: 1, from_round: 0 }]
        );
        let p = FaultPlan::parse("stall").unwrap();
        assert_eq!(p.faults[0].kind, FaultKind::Stall);
        // corrupt-frame defaults to round 1, not 0: corrupting the very
        // first assignment would kill the shard before it ever worked
        let p = FaultPlan::parse("corrupt-frame").unwrap();
        assert_eq!(
            p.faults,
            vec![Fault { kind: FaultKind::CorruptFrame, shards: 1, from_round: 1 }]
        );
        let p = FaultPlan::parse("signflip:1,stall:1@r4,noise:0.5:2").unwrap();
        assert_eq!(p.faults.len(), 3);
        assert_eq!(p.faults[2].kind, FaultKind::Noise { sigma: 0.5 });
        assert_eq!(p.faults[2].shards, 2);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        for bad in
            ["bitsquat", "signflip:0", "scale", "scale:0x", "noise:-1", "signflip:1@x3", "scale:abcx"]
        {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} must not parse");
        }
    }

    #[test]
    fn applicability_windows() {
        let p = FaultPlan::parse("signflip:2@r3,corrupt-frame:1@r5").unwrap();
        assert!(!p.attacks_payload(0, 2));
        assert!(p.attacks_payload(0, 3) && p.attacks_payload(1, 7));
        assert!(!p.attacks_payload(2, 3), "only the lowest 2 shards attack");
        // corrupt-frame is one-shot at its round, not from it onward
        assert!(p.corrupts_frame(0, 5));
        assert!(!p.corrupts_frame(0, 4) && !p.corrupts_frame(0, 6) && !p.corrupts_frame(1, 5));
        assert!(p.has_corrupt_frame());
        assert_eq!(p.max_shards(), 2);
    }

    #[test]
    fn mangler_is_deterministic_and_transport_free() {
        let p = FaultPlan::parse("noise:0.1,signflip:1").unwrap();
        let mangle = |buf: &mut [f32]| {
            let mut m = p.uplink_mangler(0, 0, 42, 6, 1, 3).expect("shard 0 attacks");
            m.apply(buf);
        };
        let mut a = vec![1.0f32, -2.0, 3.0];
        let mut b = a.clone();
        mangle(&mut a);
        mangle(&mut b);
        assert_eq!(a, b, "same (seed, k, group, client) stream -> same bytes");
        assert_ne!(a, vec![1.0, -2.0, 3.0]);
        // a different client draws a different noise stream
        let mut c = vec![1.0f32, -2.0, 3.0];
        let mut m = p.uplink_mangler(0, 0, 42, 6, 1, 4).unwrap();
        m.apply(&mut c);
        assert_ne!(a, c);
        // honest shards get no mangler at all
        assert!(p.uplink_mangler(1, 0, 42, 6, 1, 3).is_none());
    }

    #[test]
    fn signflip_is_exactly_negation() {
        let p = FaultPlan::parse("signflip").unwrap();
        let mut buf = vec![1.5f32, -0.25, 0.0];
        p.uplink_mangler(0, 9, 7, 3, 0, 0).unwrap().apply(&mut buf);
        assert_eq!(buf, vec![-1.5, 0.25, -0.0]);
    }

    #[test]
    fn chaos_rng_normal_is_sane() {
        let mut rng = ChaosRng::new(chaos_stream_seed(1, 2, 3, 4));
        let n = 4096;
        let draws: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = draws.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var =
            draws.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.15, "var {var}");
        assert!(draws.iter().all(|x| x.is_finite()));
    }
}
