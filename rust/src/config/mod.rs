//! Experiment configuration + presets for every paper table/figure.

pub mod chaos;
pub mod presets;

use std::path::PathBuf;

use crate::aggregation::{AggBackend, Policy};
use crate::data::DatasetKind;

/// Local training algorithm (the paper's baselines, §3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algorithm {
    /// Plain local SGD (FedAvg / FedLAMA local step).
    Sgd,
    /// FedProx: prox term mu/2 * ||x - x_round_start||^2.
    Prox { mu: f32 },
    /// SCAFFOLD: control variates, refreshed at round boundaries and
    /// folded on the coordinator from `AlgoState` wire messages.
    Scaffold,
    /// FedNova: normalized averaging over heterogeneous local step counts,
    /// folded on the coordinator from `AlgoState` wire messages.
    Nova,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Sgd => "sgd",
            Algorithm::Prox { .. } => "fedprox",
            Algorithm::Scaffold => "scaffold",
            Algorithm::Nova => "fednova",
        }
    }
    pub fn parse(s: &str, mu: f32) -> Option<Algorithm> {
        match s {
            "sgd" | "fedavg" | "fedlama" => Some(Algorithm::Sgd),
            "fedprox" | "prox" => Some(Algorithm::Prox { mu }),
            "scaffold" => Some(Algorithm::Scaffold),
            "fednova" | "nova" => Some(Algorithm::Nova),
            _ => None,
        }
    }
}

/// How local data is distributed across clients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionKind {
    Iid,
    Dirichlet { alpha: f64 },
    /// FEMNIST's natural writer-based heterogeneity.
    Writers,
    /// Extreme label skew: client c holds samples of exactly one class
    /// (c mod num_classes) — the pathological non-IID shard.
    SingleClass,
    /// Extreme quantity skew: client c's data size is proportional to
    /// (c+1)^-exponent (IID class mix within each client).
    PowerLaw { exponent: f64 },
}

/// Which compute backend executes the model (DESIGN.md, "Execution paths").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-rust MLP compute with an in-memory manifest — hermetic, `Sync`,
    /// parallelizable across the cluster's worker threads.  The default.
    Native,
    /// PJRT execution of AOT HLO artifacts from `model_dir` (requires the
    /// `pjrt` cargo feature and a real `xla` crate).  Thread-confined.
    Pjrt,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
        }
    }
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "pjrt" | "xla" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }
}

/// Full specification of one training run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Compute backend (native is hermetic; pjrt reads `model_dir`).
    pub engine: EngineKind,
    /// Worker threads for the per-client local-training fan-out
    /// (`runtime::cluster`): 1 = serial, 0 = auto (leave two cores for the
    /// runtime), N > 1 = fixed.  Results are bit-identical for every value.
    pub threads: usize,
    /// Worker *processes* for the federation protocol's multi-process
    /// transport: 0 (default) runs the in-proc transport (one process, one
    /// participant owning every client); N > 0 spawns N `fedlama worker`
    /// subprocesses and shards the client fleet across them over stdio
    /// pipes.  Results are bit-identical for every value.  Composes with
    /// `threads` (each worker fans its shard across that many threads).
    pub workers: usize,
    /// Minimum shards whose updates a block must gather before it commits
    /// (TCP transport only).  0 (default) means the full roster: every
    /// block waits for all `workers` shards and any disconnect is fatal —
    /// today's bit-identical behavior.  With 0 < quorum < workers, peers
    /// that drop mid-run are marked departed, the block commits over the
    /// surviving shards (folded in shard order, so the result does not
    /// depend on arrival timing), and vacated shards can be re-claimed by
    /// rejoining participants at the next round boundary.
    pub quorum: usize,
    /// Model architecture by name.  The native engine resolves it through
    /// the `runtime::zoo` registry (mlp | femnist_cnn | cifar_cnn100 |
    /// resnet20); unknown names are a validation error, never a silent
    /// MLP substitution.
    pub model: String,
    /// artifacts/<model> directory (pjrt engine only).
    pub model_dir: PathBuf,
    pub dataset: DatasetKind,
    pub algorithm: Algorithm,
    pub policy: Policy,
    pub n_clients: usize,
    pub active_ratio: f64,
    pub partition: PartitionKind,
    /// IID / Writers: samples per client.  Dirichlet: samples per class.
    pub samples: usize,
    pub lr: f32,
    /// Linear LR warmup over this many rounds (paper: 10 epochs).
    pub warmup_rounds: usize,
    /// Total local iterations K.
    pub iterations: usize,
    /// Evaluate every this many rounds (0 = only at the end).
    pub eval_every_rounds: usize,
    /// Validation examples (multiple of the eval batch is used).
    pub eval_examples: usize,
    pub seed: u64,
    pub backend: AggBackend,
    /// Use the fused train_chunk entry when the gap allows it.
    pub use_chunk: bool,
    /// FedNova: give clients heterogeneous local budgets ~ data size.
    pub hetero_local_steps: bool,
    /// Uplink update compression: "dense" (default), "qN" (QSGD N bits),
    /// "topP" (top-P% sparsification).  Composes with the layer-wise
    /// schedule — the paper's stated future work (§2, §7).
    pub compressor: String,
    /// Per-group robust aggregation spec ("mean" default; see
    /// `aggregation::robust::RobustSpec` for the grammar — e.g.
    /// "trimmed:1", "median", "normclip:2+trimmed:1").  Applied inside
    /// `apply_updates_quorum` at each group's sync point, with weights
    /// renormalized over accepted updates.
    pub aggregator: String,
    /// Deterministic fault-injection plan ("" default = none; see
    /// `config::chaos::FaultPlan` for the grammar — e.g. "signflip:1",
    /// "scale:10x:1,stall").  Shipped to participants in the `Configure`
    /// frame so designated shards turn adversarial on every transport.
    pub chaos: String,
    pub verbose: bool,
    /// Snapshot coordinator state into this directory at every round
    /// boundary (`registry::checkpoint` format).  `None` disables
    /// checkpointing.  Every algorithm checkpoints: SCAFFOLD control
    /// variates and personalized mixing weights ride the registry into
    /// the snapshot, so nothing cross-round lives outside it — except the
    /// personalized policy's blended client replicas, which is why
    /// `--resume` refuses that policy (see `validate`).
    pub checkpoint_dir: Option<PathBuf>,
    /// Restart from the checkpoint in `checkpoint_dir` instead of round 0.
    pub resume: bool,
    /// Internal: blocks already completed before this (resumed) run
    /// started.  Set by the coordinator when restoring a checkpoint and
    /// shipped to participants in the `Configure` frame so they fast-
    /// forward their client rng streams; 0 for a fresh run.  Not a CLI
    /// flag.
    pub resume_blocks: usize,
    /// Internal testing knob: halt the run after this many completed
    /// rounds (0 = run to the configured end).  Used by checkpoint/resume
    /// tests to simulate an interruption at a round boundary.
    pub halt_after_rounds: usize,
}

impl RunConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_clients > 0, "n_clients must be > 0");
        anyhow::ensure!(self.iterations > 0, "iterations must be > 0");
        anyhow::ensure!(self.lr > 0.0, "lr must be > 0");
        anyhow::ensure!(
            self.active_ratio > 0.0 && self.active_ratio <= 1.0,
            "active_ratio in (0,1]"
        );
        // The sampled-per-round count the sampler will derive.  Reject a
        // degenerate draw *here*, loudly, instead of letting the sampler
        // clamp it mid-run: k == 0 means the ratio rounds to no clients at
        // this roster size, and k > roster can only come from a float edge
        // case — both are config mistakes the user should see.
        let k = (self.n_clients as f64 * self.active_ratio).round() as usize;
        anyhow::ensure!(
            k >= 1,
            "active_ratio {} samples zero of {} registered clients per round — raise the \
             ratio (>= {:.6}) or shrink the roster",
            self.active_ratio,
            self.n_clients,
            0.5 / self.n_clients.max(1) as f64
        );
        anyhow::ensure!(
            k <= self.n_clients,
            "active_ratio {} samples {k} clients, more than the registered roster of {}",
            self.active_ratio,
            self.n_clients
        );
        anyhow::ensure!(self.samples > 0, "samples must be > 0");
        if let Policy::DivergenceFeedback { threshold, .. } = self.policy {
            anyhow::ensure!(
                threshold >= 0.0 && threshold.is_finite(),
                "--threshold must be a finite non-negative unit discrepancy, got {threshold}"
            );
        }
        if let Policy::Personalized { eta, .. } = self.policy {
            anyhow::ensure!(
                eta > 0.0 && eta <= 1.0,
                "--mix-eta must lie in (0, 1], got {eta}"
            );
        }
        if let PartitionKind::PowerLaw { exponent } = self.partition {
            anyhow::ensure!(
                exponent > 0.0 && exponent.is_finite(),
                "--exponent must be a finite positive power-law exponent, got {exponent}"
            );
        }
        anyhow::ensure!(
            crate::comm::Spec::parse(&self.compressor).is_some(),
            "unknown compressor {:?} (dense|qN|topP)",
            self.compressor
        );
        if self.backend == AggBackend::Xla {
            anyhow::ensure!(
                self.compressor == "dense",
                "backend=xla forces the fused aggregation kernel, which the compressed \
                 uplink path bypasses — use backend=auto with --compress"
            );
        }
        // The training loop is blocked by the base interval gap; a non-
        // multiple would silently drop the tail iterations.
        anyhow::ensure!(
            self.iterations % self.policy.base_interval() == 0,
            "iterations ({}) must be a multiple of the base interval gap ({}) — the block \
             loop would silently drop the tail iterations",
            self.iterations,
            self.policy.base_interval()
        );
        anyhow::ensure!(
            self.iterations % self.policy.round_len() == 0,
            "iterations ({}) must be a multiple of the round length ({})",
            self.iterations,
            self.policy.round_len()
        );
        if self.workers > 0 {
            self.validate_sharded("--workers")?;
        }
        anyhow::ensure!(
            !self.resume || self.checkpoint_dir.is_some(),
            "--resume needs --checkpoint-dir to know where the snapshot lives"
        );
        if self.resume {
            anyhow::ensure!(
                !matches!(self.policy, Policy::Personalized { .. }),
                "--resume with --policy personalized would silently diverge: the blended \
                 per-client replicas live on participants and are not captured by the \
                 snapshot (the mixing weights are, the parameters they produced are not) — \
                 run uninterrupted or switch policies"
            );
        }
        if self.quorum > 0 {
            anyhow::ensure!(
                self.workers > 0,
                "--quorum only applies to sharded transports (serve/--workers)"
            );
            anyhow::ensure!(
                self.quorum <= self.workers,
                "--quorum {} exceeds the roster of {} participants",
                self.quorum,
                self.workers
            );
        }
        let robust = crate::aggregation::robust::RobustSpec::parse(&self.aggregator)?;
        if !robust.is_mean() {
            anyhow::ensure!(
                self.backend != AggBackend::Xla,
                "backend=xla forces the fused mean-aggregation kernel, which robust \
                 reducers bypass — use --backend auto/native with --aggregator"
            );
            // Tolerance vs quorum: a trimmed fold discards exactly f updates
            // per group, so it needs a strict majority of honest survivors
            // even in the worst commit the quorum allows.  Survivors are
            // *client* updates: losing a shard loses every active client it
            // owns (round-robin, at most ceil(n/workers) each).
            let f = robust.guaranteed_trim();
            if f > 0 {
                let k = (self.n_clients as f64 * self.active_ratio).round() as usize;
                let lost_shards = if self.workers > 0 && self.quorum > 0 {
                    self.workers - self.quorum
                } else {
                    0
                };
                let per_shard = self.n_clients.div_ceil(self.workers.max(1));
                let min_survivors = k.saturating_sub(lost_shards * per_shard);
                anyhow::ensure!(
                    2 * f < min_survivors,
                    "--aggregator trimmed:{f} needs more than {} surviving client updates \
                     per group, but the worst quorum commit ({}/{} shards, {} active of {} \
                     clients) guarantees only {min_survivors} — lower the trim count, raise \
                     --quorum, or raise --active-ratio (a trim the quorum cannot cover would \
                     silently degenerate, so it is rejected here instead)",
                    2 * f,
                    if self.quorum > 0 { self.quorum } else { self.workers.max(1) },
                    self.workers.max(1),
                    k,
                    self.n_clients
                );
            }
        }
        let plan = chaos::FaultPlan::parse(&self.chaos)?;
        if !plan.is_empty() {
            anyhow::ensure!(
                self.workers == 0 || plan.max_shards() <= self.workers,
                "--chaos designates {} attacker shard(s) but the roster has only {} — \
                 an attacker id that never exists would make the plan a silent no-op",
                plan.max_shards(),
                self.workers
            );
            if plan.has_corrupt_frame() && self.workers > 0 {
                anyhow::ensure!(
                    self.quorum > 0 && self.quorum < self.workers,
                    "--chaos corrupt-frame departs the victim shard when its connection \
                     drops; with a strict full roster that is fatal — run with \
                     --quorum Q < {} so the round can commit over the survivors",
                    self.workers
                );
            }
        }
        if self.engine == EngineKind::Native {
            anyhow::ensure!(
                crate::runtime::zoo::is_known(&self.model),
                "unknown model {:?}: the native engine builds {:?} and never substitutes \
                 a different architecture silently (use --engine pjrt with artifacts for \
                 anything else)",
                self.model,
                crate::runtime::zoo::MODELS
            );
            anyhow::ensure!(
                self.backend != AggBackend::Xla,
                "backend=xla forces the fused Pallas aggregation kernel, which the \
                 native engine does not provide (use --engine pjrt or backend=auto)"
            );
        }
        Ok(())
    }

    /// Constraints every *sharded* transport shares — `--workers`
    /// subprocesses and TCP participants alike: only the native engine can
    /// rebuild its compute backend from the `Configure` frame (PJRT
    /// artifacts are not shipped).  Every algorithm is transport-complete:
    /// SCAFFOLD/FedNova state rides `AlgoState`/`ControlUpdate` frames.
    /// `transport` names the flag for the error message.
    pub fn validate_sharded(&self, transport: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.engine == EngineKind::Native,
            "{transport} requires the native engine (participants rebuild their \
             compute backend from the wire config; PJRT artifacts are not shipped)"
        );
        Ok(())
    }

    /// A human-readable tag used in reports, e.g. "fedlama(6,4)".
    pub fn tag(&self) -> String {
        match &self.policy {
            Policy::FullSync { interval } => match self.algorithm {
                Algorithm::Sgd => format!("fedavg({interval})"),
                _ => format!("{}({interval})", self.algorithm.name()),
            },
            Policy::FedLama { tau, phi, accelerate } => {
                if *accelerate {
                    format!("fedlama-acc({tau},{phi})")
                } else {
                    format!("fedlama({tau},{phi})")
                }
            }
            Policy::DivergenceFeedback { tau, phi, threshold } => {
                format!("divfb({tau},{phi},{threshold})")
            }
            Policy::Personalized { interval, eta } => {
                format!("personalized({interval},{eta})")
            }
        }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            engine: EngineKind::Native,
            threads: 1,
            workers: 0,
            quorum: 0,
            model: "mlp".to_string(),
            model_dir: PathBuf::from("artifacts/mlp"),
            dataset: DatasetKind::Toy,
            algorithm: Algorithm::Sgd,
            policy: Policy::fedavg(6),
            n_clients: 8,
            active_ratio: 1.0,
            partition: PartitionKind::Iid,
            samples: 512,
            lr: 0.1,
            warmup_rounds: 5,
            iterations: 120,
            eval_every_rounds: 5,
            eval_examples: 512,
            seed: 1,
            backend: AggBackend::Auto,
            use_chunk: true,
            hetero_local_steps: false,
            compressor: "dense".to_string(),
            aggregator: "mean".to_string(),
            chaos: String::new(),
            verbose: false,
            checkpoint_dir: None,
            resume: false,
            resume_blocks: 0,
            halt_after_rounds: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn every_algorithm_composes_with_every_policy() {
        // the zoo is transport-complete: scaffold/fednova no longer
        // require FullSync, and the new policies accept every optimizer
        for algo in [
            Algorithm::Sgd,
            Algorithm::Prox { mu: 0.01 },
            Algorithm::Scaffold,
            Algorithm::Nova,
        ] {
            for policy in [
                Policy::fedavg(6),
                Policy::fedlama(6, 2),
                Policy::divergence_feedback(6, 2, 0.5),
                Policy::personalized(6, 0.5),
            ] {
                let cfg = RunConfig {
                    algorithm: algo,
                    policy: policy.clone(),
                    iterations: 120,
                    ..Default::default()
                };
                cfg.validate().unwrap_or_else(|e| {
                    panic!("{}+{policy:?} should validate: {e:#}", algo.name())
                });
            }
        }
    }

    #[test]
    fn policy_and_partition_parameter_ranges() {
        let cfg = RunConfig {
            policy: Policy::divergence_feedback(6, 2, -0.5),
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("--threshold"), "{err:#}");
        for eta in [0.0, 1.5] {
            let cfg = RunConfig { policy: Policy::personalized(6, eta), ..Default::default() };
            let err = cfg.validate().unwrap_err();
            assert!(format!("{err:#}").contains("--mix-eta"), "{err:#}");
        }
        let cfg = RunConfig {
            partition: PartitionKind::PowerLaw { exponent: 0.0 },
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("--exponent"), "{err:#}");
        let cfg = RunConfig {
            policy: Policy::divergence_feedback(6, 2, 0.0),
            partition: PartitionKind::SingleClass,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let cfg = RunConfig {
            policy: Policy::personalized(6, 1.0),
            partition: PartitionKind::PowerLaw { exponent: 1.2 },
            ..Default::default()
        };
        cfg.validate().unwrap();
    }

    #[test]
    fn iterations_must_align_to_rounds() {
        let cfg = RunConfig { policy: Policy::fedlama(6, 4), iterations: 100, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = RunConfig { policy: Policy::fedlama(6, 4), iterations: 120, ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn iterations_must_align_to_base_interval_gap() {
        // 100 is not a multiple of tau = 6: the block loop would silently
        // drop the 4 tail iterations, so validation must reject it and the
        // error must name the gap.
        let cfg = RunConfig { policy: Policy::fedlama(6, 4), iterations: 100, ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("base interval gap"), "{err:#}");
        // 102 = 17 * 6 is gap-aligned but not round-aligned (round = 24):
        // the round-length check still fires.
        let cfg = RunConfig { policy: Policy::fedlama(6, 4), iterations: 102, ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("round length"), "{err:#}");
        // FullSync: gap == round length, one aligned check covers both.
        let cfg = RunConfig { policy: Policy::fedavg(7), iterations: 120, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = RunConfig { policy: Policy::fedavg(6), iterations: 120, ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn multiprocess_transport_constraints() {
        // every algorithm is transport-complete: scaffold/fednova state
        // rides AlgoState/ControlUpdate frames, so workers > 0 composes
        // with the whole zoo
        for algo in [
            Algorithm::Sgd,
            Algorithm::Prox { mu: 0.01 },
            Algorithm::Scaffold,
            Algorithm::Nova,
        ] {
            let cfg = RunConfig { workers: 2, algorithm: algo, ..Default::default() };
            cfg.validate()
                .unwrap_or_else(|e| panic!("{} over --workers should validate: {e:#}", algo.name()));
        }
        // but sharding still requires the native engine
        let cfg = RunConfig { workers: 2, engine: EngineKind::Pjrt, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn quorum_bounds() {
        // quorum without a sharded transport is meaningless
        let cfg = RunConfig { quorum: 1, ..Default::default() };
        assert!(cfg.validate().is_err());
        // quorum larger than the roster can never be met
        let cfg = RunConfig { workers: 2, quorum: 3, ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("roster"), "{err:#}");
        for q in [0, 1, 2] {
            let cfg = RunConfig { workers: 2, quorum: q, ..Default::default() };
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn tags() {
        assert_eq!(RunConfig::default().tag(), "fedavg(6)");
        let c = RunConfig { policy: Policy::fedlama(6, 4), ..Default::default() };
        assert_eq!(c.tag(), "fedlama(6,4)");
        let c = RunConfig {
            algorithm: Algorithm::Prox { mu: 0.01 },
            ..Default::default()
        };
        assert_eq!(c.tag(), "fedprox(6)");
        let c = RunConfig {
            policy: Policy::divergence_feedback(6, 4, 0.5),
            ..Default::default()
        };
        assert_eq!(c.tag(), "divfb(6,4,0.5)");
        let c = RunConfig { policy: Policy::personalized(6, 0.25), ..Default::default() };
        assert_eq!(c.tag(), "personalized(6,0.25)");
    }

    #[test]
    fn engine_parse_and_default() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("pjrt"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("xla"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("bogus"), None);
        let cfg = RunConfig::default();
        assert_eq!(cfg.engine, EngineKind::Native);
        assert_eq!(cfg.threads, 1);
    }

    #[test]
    fn native_engine_rejects_xla_agg_backend() {
        let cfg = RunConfig { backend: AggBackend::Xla, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = RunConfig {
            engine: EngineKind::Pjrt,
            backend: AggBackend::Xla,
            ..Default::default()
        };
        cfg.validate().unwrap();
        // threads is free-form: 0 (auto) and large values are both valid
        let cfg = RunConfig { threads: 0, ..Default::default() };
        cfg.validate().unwrap();
        let cfg = RunConfig { threads: 64, ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn xla_agg_backend_rejects_compressed_uplink() {
        // the compressed path bypasses the fused kernel entirely, so
        // forcing backend=xla alongside it must fail loudly
        let cfg = RunConfig {
            engine: EngineKind::Pjrt,
            backend: AggBackend::Xla,
            compressor: "q8".into(),
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("fused aggregation"), "{err:#}");
        let cfg = RunConfig {
            engine: EngineKind::Pjrt,
            backend: AggBackend::Xla,
            ..Default::default()
        };
        cfg.validate().unwrap();
    }

    #[test]
    fn native_engine_rejects_unknown_models() {
        let cfg = RunConfig { model: "vgg16".into(), ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("unknown model"), "{err:#}");
        for m in ["mlp", "femnist_cnn", "cifar_cnn100", "resnet20"] {
            let cfg = RunConfig { model: m.into(), ..Default::default() };
            cfg.validate().unwrap_or_else(|e| panic!("{m} should validate: {e:#}"));
        }
        // the pjrt engine loads arbitrary artifacts; names are not checked
        let cfg = RunConfig {
            engine: EngineKind::Pjrt,
            model: "anything".into(),
            ..Default::default()
        };
        cfg.validate().unwrap();
    }

    #[test]
    fn degenerate_sampling_errors_at_config_time() {
        // 1000 clients at 0.0004 rounds to k = 0: must fail loudly here,
        // not clamp silently inside the sampler mid-run
        let cfg = RunConfig { n_clients: 1000, active_ratio: 0.0004, ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("zero of 1000 registered"), "{err:#}");
        // the smallest ratio that rounds to 1 is fine
        let cfg = RunConfig { n_clients: 1000, active_ratio: 0.001, ..Default::default() };
        cfg.validate().unwrap();
        // ratio > 1 is already rejected by the range check
        let cfg = RunConfig { n_clients: 10, active_ratio: 1.5, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn checkpoint_flags_validate() {
        let dir = Some(PathBuf::from("/tmp/ckpt"));
        let cfg = RunConfig { checkpoint_dir: dir.clone(), ..Default::default() };
        cfg.validate().unwrap();
        let cfg = RunConfig {
            checkpoint_dir: dir.clone(),
            algorithm: Algorithm::Prox { mu: 0.01 },
            ..Default::default()
        };
        cfg.validate().unwrap();
        // server-side-state baselines checkpoint too: control variates and
        // step counts ride the registry snapshot
        for algo in [Algorithm::Scaffold, Algorithm::Nova] {
            let cfg = RunConfig {
                checkpoint_dir: dir.clone(),
                algorithm: algo,
                ..Default::default()
            };
            cfg.validate()
                .unwrap_or_else(|e| panic!("{} should checkpoint: {e:#}", algo.name()));
        }
        // resume without a checkpoint dir has nowhere to read from
        let cfg = RunConfig { resume: true, ..Default::default() };
        assert!(cfg.validate().is_err());
        let cfg = RunConfig { resume: true, checkpoint_dir: dir, ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn personalized_resume_is_refused() {
        // writing snapshots under the personalized policy is fine (global +
        // lambda weights are real artifacts) ...
        let dir = Some(PathBuf::from("/tmp/ckpt"));
        let cfg = RunConfig {
            checkpoint_dir: dir.clone(),
            policy: Policy::personalized(6, 0.25),
            ..Default::default()
        };
        cfg.validate().unwrap();
        // ... but resuming would silently lose the blended client replicas,
        // so it is refused loudly instead
        let cfg = RunConfig {
            checkpoint_dir: dir,
            resume: true,
            policy: Policy::personalized(6, 0.25),
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("personalized"), "{err:#}");
    }

    #[test]
    fn robust_aggregator_tolerance_vs_quorum() {
        // plain robust run: trimmed:1 over 8 clients is fine
        let cfg = RunConfig { aggregator: "trimmed:1".into(), ..Default::default() };
        cfg.validate().unwrap();
        // trimming more than half the active updates can silently
        // degenerate — rejected loudly
        let cfg = RunConfig { aggregator: "trimmed:4".into(), ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("trimmed:4"), "{err:#}");
        // quorum survivors bound the tolerance: 8 clients over 4 shards,
        // quorum 3 can lose one shard (2 clients) -> 6 survivors; trimmed:2
        // needs > 4, ok; quorum 2 can lose 4 -> 4 survivors, rejected
        let cfg = RunConfig {
            workers: 4,
            quorum: 3,
            aggregator: "trimmed:2".into(),
            ..Default::default()
        };
        cfg.validate().unwrap();
        let cfg = RunConfig {
            workers: 4,
            quorum: 2,
            aggregator: "trimmed:2".into(),
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("worst quorum commit"), "{err:#}");
        // active-ratio shrinks the survivor pool the same way
        let cfg = RunConfig {
            active_ratio: 0.5,
            aggregator: "trimmed:2".into(),
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        // unknown specs are loud
        let cfg = RunConfig { aggregator: "krum".into(), ..Default::default() };
        assert!(cfg.validate().is_err());
        // screens-only specs have no guaranteed trim and pass
        let cfg = RunConfig { aggregator: "normclip:2".into(), ..Default::default() };
        cfg.validate().unwrap();
    }

    #[test]
    fn chaos_plan_validates() {
        let cfg = RunConfig { chaos: "signflip:1".into(), ..Default::default() };
        cfg.validate().unwrap();
        // more attackers than shards is a silent no-op -> rejected
        let cfg = RunConfig { workers: 2, chaos: "signflip:3".into(), ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("attacker shard"), "{err:#}");
        // corrupt-frame departs its victim: strict full roster would be fatal
        let cfg = RunConfig { workers: 3, chaos: "corrupt-frame".into(), ..Default::default() };
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err:#}").contains("quorum"), "{err:#}");
        let cfg = RunConfig {
            workers: 3,
            quorum: 2,
            chaos: "corrupt-frame".into(),
            ..Default::default()
        };
        cfg.validate().unwrap();
        // bad grammar is loud
        let cfg = RunConfig { chaos: "bitsquat".into(), ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!(Algorithm::parse("fedavg", 0.0), Some(Algorithm::Sgd));
        assert_eq!(Algorithm::parse("fedprox", 0.1), Some(Algorithm::Prox { mu: 0.1 }));
        assert_eq!(Algorithm::parse("scaffold", 0.0), Some(Algorithm::Scaffold));
        assert_eq!(Algorithm::parse("bogus", 0.0), None);
    }
}
