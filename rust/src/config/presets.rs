//! Experiment presets: one grid per paper table/figure (DESIGN.md §6).
//!
//! Scale note: the paper runs 128 clients / 300 epochs on 8 V100s.  This
//! testbed is CPU-PJRT, so presets default to a scaled grid (16 clients,
//! a few hundred rounds) whose *relative* accuracy/comm trade-offs are the
//! quantities the paper's tables report.  `--scale full` widens toward the
//! paper's sizes for long runs.

use std::path::PathBuf;

use super::{Algorithm, PartitionKind, RunConfig};
use crate::aggregation::Policy;
use crate::data::DatasetKind;

/// One experiment row: a tag plus the run configuration.
#[derive(Debug, Clone)]
pub struct ExperimentRow {
    pub label: String,
    pub lr: f32,
    pub cfg: RunConfig,
}

/// An experiment = a paper table or figure.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub id: String,
    pub title: String,
    pub rows: Vec<ExperimentRow>,
    /// Index of the row used as the 100% comm-cost baseline.
    pub baseline_row: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Seconds-scale smoke configuration (CI).
    Smoke,
    /// Minutes-scale default (EXPERIMENTS.md numbers).
    Default,
    /// Closer to paper scale (hours on CPU).
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "default" => Some(Scale::Default),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }
}

pub struct PresetParams {
    pub n_clients: usize,
    pub iterations_t1: usize,  // iteration budget for tau'=6 grids
    pub iterations_t10: usize, // for tau'=10 grids (femnist)
    pub samples: usize,
    pub eval_examples: usize,
}

pub fn scale_params(scale: Scale) -> PresetParams {
    match scale {
        Scale::Smoke => PresetParams {
            n_clients: 4,
            iterations_t1: 96,
            iterations_t10: 80,
            samples: 128,
            eval_examples: 512,
        },
        Scale::Default => PresetParams {
            n_clients: 6,
            iterations_t1: 240,
            iterations_t10: 200,
            samples: 256,
            eval_examples: 768,
        },
        Scale::Full => PresetParams {
            n_clients: 16,
            iterations_t1: 1920,
            iterations_t10: 1600,
            samples: 512,
            eval_examples: 2048,
        },
    }
}

fn artifacts_root() -> PathBuf {
    std::env::var_os("FEDLAMA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn base_cfg(model: &str, dataset: DatasetKind, p: &PresetParams) -> RunConfig {
    RunConfig {
        model: model.to_string(),
        model_dir: artifacts_root().join(model),
        dataset,
        n_clients: p.n_clients,
        samples: p.samples,
        eval_examples: p.eval_examples,
        eval_every_rounds: 4,
        warmup_rounds: 4,
        ..Default::default()
    }
}

fn row(label: &str, lr: f32, policy: Policy, base: &RunConfig, iters: usize) -> ExperimentRow {
    ExperimentRow {
        label: label.to_string(),
        lr,
        cfg: RunConfig { policy, lr, iterations: iters, ..base.clone() },
    }
}

/// Tables 1 & 2 grid: FedAvg tau' in {t,2t,4t} vs FedLAMA (t,2) and (t,4).
fn iid_grid(
    model: &str,
    dataset: DatasetKind,
    tau: usize,
    lr: f32,
    p: &PresetParams,
) -> Vec<ExperimentRow> {
    let base = base_cfg(model, dataset, p);
    let iters = p.iterations_t1;
    vec![
        row(&format!("FedAvg tau'={tau}"), lr, Policy::fedavg(tau), &base, iters),
        row(&format!("FedAvg tau'={}", 2 * tau), lr, Policy::fedavg(2 * tau), &base, iters),
        row(&format!("FedLAMA ({tau},2)"), lr * 0.75, Policy::fedlama(tau, 2), &base, iters),
        row(&format!("FedAvg tau'={}", 4 * tau), lr, Policy::fedavg(4 * tau), &base, iters),
        row(&format!("FedLAMA ({tau},4)"), lr * 0.75, Policy::fedlama(tau, 4), &base, iters),
    ]
}

pub fn table1(scale: Scale) -> Experiment {
    let p = scale_params(scale);
    Experiment {
        id: "table1".into(),
        title: "Table 1: (IID) CIFAR-10 (synthetic), ResNet20".into(),
        rows: iid_grid("resnet20", DatasetKind::Cifar10, 6, 0.4, &p),
        baseline_row: 0,
    }
}

pub fn table2(scale: Scale) -> Experiment {
    let p = scale_params(scale);
    Experiment {
        id: "table2".into(),
        title: "Table 2: (IID) CIFAR-100 (synthetic), VGG-CNN (WRN stand-in)".into(),
        rows: iid_grid("cifar_cnn100", DatasetKind::Cifar100, 6, 0.3, &p),
        baseline_row: 0,
    }
}

/// Table 3: FEMNIST grid across active ratios {25, 50, 100}%.
pub fn table3(scale: Scale) -> Experiment {
    let p = scale_params(scale);
    let mut rows = Vec::new();
    let tau = 10;
    let lr = 0.06;
    for &ratio in &[0.25, 0.5, 1.0] {
        let mut base = base_cfg("femnist_cnn", DatasetKind::Femnist, &p);
        base.partition = PartitionKind::Writers;
        base.active_ratio = ratio;
        // partial participation needs >= 2 active clients to be meaningful
        if ratio < 1.0 {
            base.n_clients = base.n_clients.max(8);
        }
        let iters = p.iterations_t10;
        let pct = (ratio * 100.0) as usize;
        rows.push(row(&format!("[{pct}%] FedAvg tau'=10"), lr, Policy::fedavg(tau), &base, iters));
        rows.push(row(
            &format!("[{pct}%] FedAvg tau'=20"),
            lr,
            Policy::fedavg(2 * tau),
            &base,
            iters,
        ));
        rows.push(row(&format!("[{pct}%] FedLAMA (10,2)"), lr, Policy::fedlama(tau, 2), &base, iters));
        rows.push(row(
            &format!("[{pct}%] FedAvg tau'=40"),
            lr,
            Policy::fedavg(4 * tau),
            &base,
            iters,
        ));
        rows.push(row(&format!("[{pct}%] FedLAMA (10,4)"), lr, Policy::fedlama(tau, 4), &base, iters));
    }
    Experiment {
        id: "table3".into(),
        title: "Table 3: (Non-IID) FEMNIST (synthetic writers), CNN".into(),
        rows,
        baseline_row: 0,
    }
}

/// Table 4: non-IID CIFAR-10, Dirichlet alpha x active-ratio grid.
pub fn table4(scale: Scale) -> Experiment {
    let p = scale_params(scale);
    let mut rows = Vec::new();
    for &(ratio, alpha) in &[(0.25, 0.1), (0.25, 1.0), (1.0, 0.1), (1.0, 1.0)] {
        let mut base = base_cfg("resnet20", DatasetKind::Cifar10, &p);
        base.partition = PartitionKind::Dirichlet { alpha };
        base.active_ratio = ratio;
        if ratio < 1.0 {
            base.n_clients = base.n_clients.max(8);
        }
        let iters = p.iterations_t1;
        let lr = 0.4;
        let tag = format!("[{}%,a={alpha}]", (ratio * 100.0) as usize);
        rows.push(row(&format!("{tag} FedAvg tau'=6"), lr, Policy::fedavg(6), &base, iters));
        rows.push(row(&format!("{tag} FedAvg tau'=24"), lr, Policy::fedavg(24), &base, iters));
        rows.push(row(&format!("{tag} FedLAMA (6,4)"), lr, Policy::fedlama(6, 4), &base, iters));
    }
    Experiment {
        id: "table4".into(),
        title: "Table 4: (Non-IID) CIFAR-10 (synthetic), ResNet20, Dirichlet".into(),
        rows,
        baseline_row: 0,
    }
}

/// Table 5: non-IID CIFAR-100, Dirichlet grid with phi=2.
pub fn table5(scale: Scale) -> Experiment {
    let p = scale_params(scale);
    let mut rows = Vec::new();
    for &(ratio, alpha) in &[(0.25, 0.1), (0.25, 0.5), (1.0, 0.1), (1.0, 0.5)] {
        let mut base = base_cfg("cifar_cnn100", DatasetKind::Cifar100, &p);
        base.partition = PartitionKind::Dirichlet { alpha };
        base.active_ratio = ratio;
        if ratio < 1.0 {
            base.n_clients = base.n_clients.max(8);
        }
        let iters = p.iterations_t1;
        let lr = 0.3;
        let tag = format!("[{}%,a={alpha}]", (ratio * 100.0) as usize);
        rows.push(row(&format!("{tag} FedAvg tau'=6"), lr, Policy::fedavg(6), &base, iters));
        rows.push(row(&format!("{tag} FedAvg tau'=12"), lr, Policy::fedavg(12), &base, iters));
        rows.push(row(&format!("{tag} FedLAMA (6,2)"), lr, Policy::fedlama(6, 2), &base, iters));
    }
    Experiment {
        id: "table5".into(),
        title: "Table 5: (Non-IID) CIFAR-100 (synthetic), VGG-CNN, Dirichlet".into(),
        rows,
        baseline_row: 0,
    }
}

/// Appendix tables 6/7 & 9/10: phi sweeps.
pub fn phi_sweep(
    id: &str,
    model: &str,
    dataset: DatasetKind,
    non_iid: Option<f64>,
    scale: Scale,
) -> Experiment {
    let p = scale_params(scale);
    let mut base = base_cfg(model, dataset, &p);
    if let Some(alpha) = non_iid {
        base.partition = PartitionKind::Dirichlet { alpha };
    }
    let iters = p.iterations_t1;
    let lr = 0.4;
    let mut rows = vec![row("FedAvg tau'=6 (phi=1)", lr, Policy::fedavg(6), &base, iters)];
    for phi in [2usize, 4, 8] {
        rows.push(row(&format!("FedLAMA (6,{phi})"), lr, Policy::fedlama(6, phi), &base, iters));
    }
    Experiment {
        id: id.into(),
        title: format!(
            "phi sweep: {model} / {dataset:?}{}",
            non_iid.map(|a| format!(" Dirichlet({a})")).unwrap_or_default()
        ),
        rows,
        baseline_row: 0,
    }
}

/// Appendix tables 8 & 11: tau' sweeps for FedAvg.
pub fn tau_sweep(id: &str, model: &str, dataset: DatasetKind, scale: Scale) -> Experiment {
    let p = scale_params(scale);
    let base = base_cfg(model, dataset, &p);
    let iters = p.iterations_t1;
    let lr = 0.4;
    let rows = [6usize, 12, 24]
        .iter()
        .map(|&t| row(&format!("FedAvg tau'={t}"), lr, Policy::fedavg(t), &base, iters))
        .collect();
    Experiment { id: id.into(), title: format!("tau' sweep: {model}"), rows, baseline_row: 0 }
}

/// Baseline-algorithm comparison (FedAvg/FedProx/SCAFFOLD/FedNova vs
/// FedLAMA) — the §2-related ablation, not a paper table.
pub fn baselines(scale: Scale) -> Experiment {
    let p = scale_params(scale);
    let mut base = base_cfg("mlp", DatasetKind::Toy, &p);
    base.partition = PartitionKind::Dirichlet { alpha: 0.2 };
    base.use_chunk = false;
    let iters = p.iterations_t1.min(480);
    let lr = 0.08;
    let mk = |label: &str, algo: Algorithm, policy: Policy, hetero: bool| ExperimentRow {
        label: label.to_string(),
        lr,
        cfg: RunConfig {
            algorithm: algo,
            policy,
            lr,
            iterations: iters,
            hetero_local_steps: hetero,
            ..base.clone()
        },
    };
    Experiment {
        id: "baselines".into(),
        title: "Baselines: local-SGD algorithms under non-IID data".into(),
        rows: vec![
            mk("FedAvg(6)", Algorithm::Sgd, Policy::fedavg(6), false),
            mk("FedProx(6) mu=0.01", Algorithm::Prox { mu: 0.01 }, Policy::fedavg(6), false),
            mk("SCAFFOLD(6)", Algorithm::Scaffold, Policy::fedavg(6), false),
            mk("FedNova(6) hetero", Algorithm::Nova, Policy::fedavg(6), true),
            mk("FedLAMA(6,2)", Algorithm::Sgd, Policy::fedlama(6, 2), false),
        ],
        baseline_row: 0,
    }
}

/// Look up an experiment by id ("table1".."table11", "baselines").
pub fn by_id(id: &str, scale: Scale) -> Option<Experiment> {
    match id {
        "table1" => Some(table1(scale)),
        "table2" => Some(table2(scale)),
        "table3" => Some(table3(scale)),
        "table4" => Some(table4(scale)),
        "table5" => Some(table5(scale)),
        "table6" => Some(phi_sweep("table6", "resnet20", DatasetKind::Cifar10, None, scale)),
        "table7" => Some(phi_sweep("table7", "resnet20", DatasetKind::Cifar10, Some(0.1), scale)),
        "table8" => Some(tau_sweep("table8", "resnet20", DatasetKind::Cifar10, scale)),
        "table9" => Some(phi_sweep("table9", "cifar_cnn100", DatasetKind::Cifar100, None, scale)),
        "table10" => {
            Some(phi_sweep("table10", "cifar_cnn100", DatasetKind::Cifar100, Some(0.1), scale))
        }
        "table11" => Some(tau_sweep("table11", "cifar_cnn100", DatasetKind::Cifar100, scale)),
        "baselines" => Some(baselines(scale)),
        _ => None,
    }
}

pub const ALL_TABLE_IDS: &[&str] = &[
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "table9",
    "table10", "table11", "baselines",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for id in ALL_TABLE_IDS {
            for scale in [Scale::Smoke, Scale::Default, Scale::Full] {
                let exp = by_id(id, scale).unwrap_or_else(|| panic!("missing {id}"));
                assert!(!exp.rows.is_empty(), "{id} empty");
                assert!(exp.baseline_row < exp.rows.len());
                for r in &exp.rows {
                    r.cfg.validate().unwrap_or_else(|e| panic!("{id} / {}: {e}", r.label));
                }
            }
        }
    }

    #[test]
    fn presets_build_their_real_architecture_natively() {
        for id in ALL_TABLE_IDS {
            let exp = by_id(id, Scale::Smoke).unwrap();
            for r in &exp.rows {
                let g = crate::runtime::zoo::build(&r.cfg.model, r.cfg.dataset)
                    .unwrap_or_else(|e| panic!("{id}/{}: {e:#}", r.label));
                assert_eq!(g.manifest().input_shape, r.cfg.dataset.input_shape());
            }
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(by_id("table99", Scale::Smoke).is_none());
    }

    #[test]
    fn table4_covers_the_paper_grid() {
        let t = table4(Scale::Smoke);
        assert_eq!(t.rows.len(), 12); // 4 (ratio, alpha) cells x 3 settings
        assert!(t.rows.iter().any(|r| r.label.contains("FedLAMA (6,4)")));
    }
}
