//! Batch construction: procedural, deterministic mini-batches per client.

use super::partition::ClientData;
use super::synthetic::Generator;
use crate::util::rng::Rng;

/// Builds mini-batches for one client, deterministic in (seed, draw order).
#[derive(Debug, Clone)]
pub struct BatchSource<'a> {
    gen: &'a Generator,
    data: &'a ClientData,
    rng: Rng,
}

impl<'a> BatchSource<'a> {
    pub fn new(gen: &'a Generator, data: &'a ClientData, seed: u64, client_id: usize) -> Self {
        BatchSource { gen, data, rng: Rng::new(seed).fork(client_id as u64 ^ 0xBA7C_85EED) }
    }

    /// Fill a batch of size b into the provided buffers.
    pub fn next_batch(&mut self, b: usize, xs: &mut Vec<f32>, ys: &mut Vec<i32>) {
        let d = self.gen.input_dim;
        xs.resize(b * d, 0.0);
        ys.resize(b, 0);
        for i in 0..b {
            let class = self.data.sample_class(&mut self.rng);
            let writer = self.data.sample_writer(&mut self.rng);
            ys[i] = class as i32;
            self.gen.gen_example(class, writer, &mut self.rng, &mut xs[i * d..(i + 1) * d]);
        }
    }

    /// Fill K stacked batches (for the fused train_chunk entry).
    pub fn next_chunk(&mut self, k: usize, b: usize, xs: &mut Vec<f32>, ys: &mut Vec<i32>) {
        let d = self.gen.input_dim;
        xs.resize(k * b * d, 0.0);
        ys.resize(k * b, 0);
        for s in 0..k {
            for i in 0..b {
                let class = self.data.sample_class(&mut self.rng);
                let writer = self.data.sample_writer(&mut self.rng);
                ys[s * b + i] = class as i32;
                let off = (s * b + i) * d;
                self.gen.gen_example(class, writer, &mut self.rng, &mut xs[off..off + d]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_partition;
    use crate::data::synthetic::DatasetKind;

    #[test]
    fn deterministic_batches() {
        let gen = Generator::new(DatasetKind::Toy, 11);
        let part = iid_partition(2, 10, 100);
        let (mut x1, mut y1) = (Vec::new(), Vec::new());
        let (mut x2, mut y2) = (Vec::new(), Vec::new());
        BatchSource::new(&gen, &part.clients[0], 5, 0).next_batch(8, &mut x1, &mut y1);
        BatchSource::new(&gen, &part.clients[0], 5, 0).next_batch(8, &mut x2, &mut y2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        // different client id -> different stream
        BatchSource::new(&gen, &part.clients[1], 5, 1).next_batch(8, &mut x2, &mut y2);
        assert_ne!(x1, x2);
    }

    #[test]
    fn chunk_matches_sequential_draws() {
        let gen = Generator::new(DatasetKind::Toy, 11);
        let part = iid_partition(1, 10, 100);
        let (mut xc, mut yc) = (Vec::new(), Vec::new());
        BatchSource::new(&gen, &part.clients[0], 5, 0).next_chunk(3, 4, &mut xc, &mut yc);
        let mut src = BatchSource::new(&gen, &part.clients[0], 5, 0);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        let (mut xall, mut yall) = (Vec::new(), Vec::new());
        for _ in 0..3 {
            src.next_batch(4, &mut xs, &mut ys);
            xall.extend_from_slice(&xs);
            yall.extend_from_slice(&ys);
        }
        assert_eq!(xc, xall);
        assert_eq!(yc, yall);
    }
}
