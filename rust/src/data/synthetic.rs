//! Synthetic dataset substrates.
//!
//! The paper evaluates on CIFAR-10, CIFAR-100 and FEMNIST; this testbed has
//! no network access, so we build class-conditional Gaussian-mixture
//! generators that preserve the property FedLAMA's mechanism depends on:
//! per-client data heterogeneity inducing per-layer model discrepancy
//! (DESIGN.md §4).  Each class has a fixed random prototype in input space;
//! an example is `signal * prototype[c] + noise * eps`.  FEMNIST
//! additionally applies a per-writer style shift, mirroring its natural
//! writer heterogeneity.
//!
//! Data is generated procedurally per batch (nothing stored), deterministic
//! in (dataset seed, client id, draw index).

use crate::util::rng::Rng;

/// Which benchmark a generator mimics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    Cifar10,
    Cifar100,
    Femnist,
    /// Low-dimensional dataset for the MLP quickstart/tests.
    Toy,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s {
            "cifar10" => Some(DatasetKind::Cifar10),
            "cifar100" => Some(DatasetKind::Cifar100),
            "femnist" => Some(DatasetKind::Femnist),
            "toy" => Some(DatasetKind::Toy),
            _ => None,
        }
    }
    /// Canonical name; `parse(name())` is the identity (used by the
    /// federation protocol's config wire schema).
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Cifar10 => "cifar10",
            DatasetKind::Cifar100 => "cifar100",
            DatasetKind::Femnist => "femnist",
            DatasetKind::Toy => "toy",
        }
    }
    pub fn input_shape(&self) -> Vec<usize> {
        match self {
            DatasetKind::Cifar10 | DatasetKind::Cifar100 => vec![32, 32, 3],
            DatasetKind::Femnist => vec![28, 28, 1],
            DatasetKind::Toy => vec![64],
        }
    }
    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::Cifar10 => 10,
            DatasetKind::Cifar100 => 100,
            DatasetKind::Femnist => 62,
            DatasetKind::Toy => 10,
        }
    }
    pub fn num_writers(&self) -> usize {
        match self {
            DatasetKind::Femnist => 355, // 10% of the 3,550 writers, as in the paper
            _ => 0,
        }
    }
}

/// Class-conditional Gaussian-mixture generator.
#[derive(Debug, Clone)]
pub struct Generator {
    pub kind: DatasetKind,
    pub input_dim: usize,
    /// [num_classes][input_dim] class prototypes.
    protos: Vec<Vec<f32>>,
    /// [num_writers][input_dim] writer style offsets (FEMNIST only).
    styles: Vec<Vec<f32>>,
    pub signal: f32,
    pub noise: f32,
    pub style_strength: f32,
    seed: u64,
}

impl Generator {
    pub fn new(kind: DatasetKind, seed: u64) -> Generator {
        let input_dim: usize = kind.input_shape().iter().product();
        let mut rng = Rng::new(seed ^ 0x5EED_DA7A);
        let protos = (0..kind.num_classes())
            .map(|_| (0..input_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let styles = (0..kind.num_writers())
            .map(|_| (0..input_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        Generator {
            kind,
            input_dim,
            protos,
            styles,
            // Signal/noise tuned so the task is learnable but not trivial:
            // Bayes-optimal accuracy is high, random init is ~1/C.
            signal: 1.0,
            noise: 1.25,
            style_strength: if kind == DatasetKind::Femnist { 0.5 } else { 0.0 },
            seed,
        }
    }

    pub fn num_classes(&self) -> usize {
        self.kind.num_classes()
    }

    /// Write one example for (class, writer) into `out`.
    pub fn gen_example(&self, class: usize, writer: usize, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.input_dim);
        let proto = &self.protos[class];
        if self.styles.is_empty() {
            for (o, &p) in out.iter_mut().zip(proto) {
                *o = self.signal * p + self.noise * rng.normal_f32(0.0, 1.0);
            }
        } else {
            let style = &self.styles[writer % self.styles.len()];
            for ((o, &p), &s) in out.iter_mut().zip(proto).zip(style) {
                *o = self.signal * p
                    + self.style_strength * s
                    + self.noise * rng.normal_f32(0.0, 1.0);
            }
        }
    }

    /// Deterministic held-out validation set: `n` examples with uniform
    /// class coverage (class i at index i mod C), independent of training
    /// draws.
    pub fn validation_set(&self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(self.seed ^ 0x7A11_DA7A_5E7F_00D5);
        let mut xs = vec![0.0f32; n * self.input_dim];
        let mut ys = vec![0i32; n];
        let c = self.num_classes();
        let w = self.kind.num_writers().max(1);
        for i in 0..n {
            let class = i % c;
            let writer = rng.below(w);
            ys[i] = class as i32;
            self.gen_example(class, writer, &mut rng, &mut xs[i * self.input_dim..(i + 1) * self.input_dim]);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g1 = Generator::new(DatasetKind::Toy, 42);
        let g2 = Generator::new(DatasetKind::Toy, 42);
        let mut a = vec![0.0; g1.input_dim];
        let mut b = vec![0.0; g2.input_dim];
        g1.gen_example(3, 0, &mut Rng::new(7), &mut a);
        g2.gen_example(3, 0, &mut Rng::new(7), &mut b);
        assert_eq!(a, b);
        let (x1, y1) = g1.validation_set(100);
        let (x2, y2) = g2.validation_set(100);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn classes_are_separable() {
        // Examples of the same class must be closer to their own prototype.
        let g = Generator::new(DatasetKind::Toy, 1);
        let mut rng = Rng::new(2);
        let mut x = vec![0.0; g.input_dim];
        let mut correct = 0;
        let trials = 200;
        for t in 0..trials {
            let class = t % g.num_classes();
            g.gen_example(class, 0, &mut rng, &mut x);
            // nearest-prototype classification
            let best = (0..g.num_classes())
                .min_by(|&a, &b| {
                    let da: f32 = g.protos[a].iter().zip(&x).map(|(p, v)| (v - p) * (v - p)).sum();
                    let db: f32 = g.protos[b].iter().zip(&x).map(|(p, v)| (v - p) * (v - p)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == class {
                correct += 1;
            }
        }
        assert!(correct as f64 > 0.8 * trials as f64, "only {correct}/{trials} separable");
    }

    #[test]
    fn writer_styles_shift_femnist() {
        let g = Generator::new(DatasetKind::Femnist, 3);
        let mut x1 = vec![0.0; g.input_dim];
        let mut x2 = vec![0.0; g.input_dim];
        // Same class + same rng stream, different writers -> different data.
        g.gen_example(5, 0, &mut Rng::new(9), &mut x1);
        g.gen_example(5, 1, &mut Rng::new(9), &mut x2);
        assert_ne!(x1, x2);
        let d: f32 = x1.iter().zip(&x2).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
            / g.input_dim as f32;
        assert!(d > 0.1, "style shift too weak: {d}");
    }

    #[test]
    fn validation_covers_classes() {
        let g = Generator::new(DatasetKind::Cifar10, 4);
        let (_, ys) = g.validation_set(50);
        for c in 0..10 {
            assert!(ys.iter().filter(|&&y| y == c).count() == 5);
        }
    }

    #[test]
    fn shapes() {
        assert_eq!(DatasetKind::Cifar100.num_classes(), 100);
        assert_eq!(DatasetKind::Femnist.input_shape(), vec![28, 28, 1]);
        assert_eq!(DatasetKind::parse("cifar10"), Some(DatasetKind::Cifar10));
        assert_eq!(DatasetKind::parse("nope"), None);
    }
}
