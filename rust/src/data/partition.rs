//! Federated data partitioning: IID and Dirichlet non-IID label skew.
//!
//! Follows the FedML partitioner the paper cites: for each class c, a
//! Dirichlet(alpha) draw over clients decides how many of that class's
//! samples each client holds.  A small alpha therefore skews both the label
//! mix *and* the per-client dataset size, as the paper notes in §A.2.

use crate::util::rng::Rng;

/// One client's local data distribution: per-class sample counts.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientData {
    pub counts: Vec<usize>,
    pub total: usize,
    /// FEMNIST: the writers this client owns (empty for other datasets).
    pub writers: Vec<usize>,
}

impl ClientData {
    pub fn new(counts: Vec<usize>) -> ClientData {
        let total = counts.iter().sum();
        ClientData { counts, total, writers: Vec::new() }
    }

    /// Sample a class label according to this client's local distribution.
    pub fn sample_class(&self, rng: &mut Rng) -> usize {
        debug_assert!(self.total > 0);
        let mut r = rng.below(self.total);
        for (c, &n) in self.counts.iter().enumerate() {
            if r < n {
                return c;
            }
            r -= n;
        }
        self.counts.len() - 1
    }

    /// Sample a writer (FEMNIST) or 0.
    pub fn sample_writer(&self, rng: &mut Rng) -> usize {
        if self.writers.is_empty() {
            0
        } else {
            self.writers[rng.below(self.writers.len())]
        }
    }
}

/// A full partition of a federated dataset across clients.
#[derive(Debug, Clone)]
pub struct Partition {
    pub clients: Vec<ClientData>,
    pub total: usize,
}

impl Partition {
    /// Aggregation weight p_i = n_i / n (paper Eq. 1).
    pub fn weight(&self, client: usize) -> f64 {
        self.clients[client].total as f64 / self.total as f64
    }

    /// Renormalized weights over an active subset (partial participation).
    pub fn active_weights(&self, active: &[usize]) -> Vec<f32> {
        let sum: f64 = active.iter().map(|&i| self.clients[i].total as f64).sum();
        active.iter().map(|&i| (self.clients[i].total as f64 / sum) as f32).collect()
    }
}

/// IID: every client gets `per_client` samples uniformly over classes.
pub fn iid_partition(n_clients: usize, num_classes: usize, per_client: usize) -> Partition {
    let base = per_client / num_classes;
    let rem = per_client % num_classes;
    let clients = (0..n_clients)
        .map(|_| {
            let counts: Vec<usize> =
                (0..num_classes).map(|c| base + usize::from(c < rem)).collect();
            ClientData::new(counts)
        })
        .collect::<Vec<_>>();
    let total = clients.iter().map(|c| c.total).sum();
    Partition { clients, total }
}

/// Dirichlet non-IID: class c's `samples_per_class` are split across
/// clients by a Dirichlet(alpha) draw (FedML scheme).  Clients that end up
/// empty are given one sample of a random class so every p_i > 0.
pub fn dirichlet_partition(
    n_clients: usize,
    num_classes: usize,
    samples_per_class: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Partition {
    let mut counts = vec![vec![0usize; num_classes]; n_clients];
    for c in 0..num_classes {
        let props = rng.dirichlet(alpha, n_clients);
        // Largest-remainder apportionment of samples_per_class.
        let mut assigned = 0usize;
        let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(n_clients);
        for (i, p) in props.iter().enumerate() {
            let exact = p * samples_per_class as f64;
            let fl = exact.floor() as usize;
            counts[i][c] += fl;
            assigned += fl;
            fracs.push((i, exact - fl as f64));
        }
        fracs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for &(i, _) in fracs.iter().take(samples_per_class - assigned) {
            counts[i][c] += 1;
        }
    }
    for row in counts.iter_mut() {
        if row.iter().sum::<usize>() == 0 {
            row[rng.below(num_classes)] = 1;
        }
    }
    let clients: Vec<ClientData> = counts.into_iter().map(ClientData::new).collect();
    let total = clients.iter().map(|c| c.total).sum();
    Partition { clients, total }
}

/// FEMNIST natural partition: split `n_writers` writers across clients;
/// each client's class mix is near-uniform but its data carries its
/// writers' style shift (the natural heterogeneity of the benchmark).
pub fn femnist_partition(
    n_clients: usize,
    num_classes: usize,
    n_writers: usize,
    per_client: usize,
    rng: &mut Rng,
) -> Partition {
    let mut writer_ids: Vec<usize> = (0..n_writers).collect();
    rng.shuffle(&mut writer_ids);
    let mut clients = Vec::with_capacity(n_clients);
    for i in 0..n_clients {
        // near-uniform class counts with small multiplicative jitter
        let mut counts = vec![0usize; num_classes];
        let mut remaining = per_client;
        for (c, cnt) in counts.iter_mut().enumerate() {
            let base = remaining / (num_classes - c);
            let jitter = if base > 1 { rng.below(base / 2 + 1) } else { 0 };
            let take = (base + jitter).min(remaining);
            *cnt = take;
            remaining -= take;
        }
        counts[rng.below(num_classes)] += remaining;
        let mut cd = ClientData::new(counts);
        // round-robin writer ownership
        cd.writers = writer_ids.iter().skip(i).step_by(n_clients).copied().collect();
        if cd.writers.is_empty() {
            cd.writers.push(writer_ids[i % n_writers]);
        }
        clients.push(cd);
    }
    let total = clients.iter().map(|c| c.total).sum();
    Partition { clients, total }
}

/// Extreme label skew: client c holds `per_client` samples of exactly one
/// class, c mod num_classes — every local gradient pulls toward a single
/// label, the pathological case for layer-wise interval relaxation.
pub fn single_class_partition(
    n_clients: usize,
    num_classes: usize,
    per_client: usize,
) -> Partition {
    let clients: Vec<ClientData> = (0..n_clients)
        .map(|i| {
            let mut counts = vec![0usize; num_classes];
            counts[i % num_classes] = per_client;
            ClientData::new(counts)
        })
        .collect();
    let total = clients.iter().map(|c| c.total).sum();
    Partition { clients, total }
}

/// Extreme quantity skew: client c's data size is proportional to
/// (c+1)^-exponent, scaled so the fleet holds ~ n_clients * per_client
/// samples in aggregate.  Class mix within each client is IID.  Every
/// client keeps at least one sample so every p_i > 0.
pub fn power_law_partition(
    n_clients: usize,
    num_classes: usize,
    per_client: usize,
    exponent: f64,
) -> Partition {
    let raw: Vec<f64> = (0..n_clients).map(|i| ((i + 1) as f64).powf(-exponent)).collect();
    let norm: f64 = raw.iter().sum();
    let budget = (n_clients * per_client) as f64;
    let clients: Vec<ClientData> = raw
        .iter()
        .map(|&w| {
            let n = ((w / norm * budget).round() as usize).max(1);
            // spread n over classes like the IID partitioner
            let base = n / num_classes;
            let rem = n % num_classes;
            let counts: Vec<usize> =
                (0..num_classes).map(|c| base + usize::from(c < rem)).collect();
            ClientData::new(counts)
        })
        .collect();
    let total = clients.iter().map(|c| c.total).sum();
    Partition { clients, total }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_is_uniform() {
        let p = iid_partition(8, 10, 100);
        assert_eq!(p.total, 800);
        for c in &p.clients {
            assert_eq!(c.total, 100);
            assert!(c.counts.iter().all(|&n| n == 10));
        }
        assert!((p.weight(0) - 1.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn dirichlet_conserves_samples() {
        let mut rng = Rng::new(1);
        let p = dirichlet_partition(16, 10, 500, 0.1, &mut rng);
        // every class's samples are fully assigned (plus possible +1 fills)
        assert!(p.total >= 5000);
        assert!(p.total <= 5000 + 16);
        for c in &p.clients {
            assert!(c.total > 0, "no empty clients allowed");
        }
    }

    #[test]
    fn dirichlet_small_alpha_skews() {
        let mut rng = Rng::new(2);
        let skewed = dirichlet_partition(8, 10, 1000, 0.05, &mut rng);
        let uniform = dirichlet_partition(8, 10, 1000, 1000.0, &mut rng);
        // max class share per client: skewed >> uniform
        let max_share = |p: &Partition| {
            p.clients
                .iter()
                .map(|c| {
                    c.counts.iter().cloned().max().unwrap_or(0) as f64 / c.total.max(1) as f64
                })
                .fold(0.0, f64::max)
        };
        assert!(max_share(&skewed) > 0.5, "alpha=0.05 should skew: {}", max_share(&skewed));
        assert!(max_share(&uniform) < 0.25, "alpha=1000 should be uniform: {}", max_share(&uniform));
    }

    #[test]
    fn sampling_respects_counts() {
        let cd = ClientData::new(vec![0, 100, 0, 50]);
        let mut rng = Rng::new(3);
        let mut seen = [0usize; 4];
        for _ in 0..3000 {
            seen[cd.sample_class(&mut rng)] += 1;
        }
        assert_eq!(seen[0], 0);
        assert_eq!(seen[2], 0);
        let ratio = seen[1] as f64 / seen[3] as f64;
        assert!((ratio - 2.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn active_weights_renormalize() {
        let mut rng = Rng::new(4);
        let p = dirichlet_partition(10, 5, 200, 0.5, &mut rng);
        let w = p.active_weights(&[0, 3, 7]);
        assert_eq!(w.len(), 3);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn single_class_is_maximally_skewed() {
        let p = single_class_partition(12, 10, 64);
        assert_eq!(p.total, 12 * 64);
        for (i, c) in p.clients.iter().enumerate() {
            assert_eq!(c.total, 64);
            assert_eq!(c.counts[i % 10], 64, "client {i} holds exactly one class");
            assert_eq!(c.counts.iter().filter(|&&n| n > 0).count(), 1);
        }
        // deterministic: no rng input at all
        let q = single_class_partition(12, 10, 64);
        assert_eq!(p.clients, q.clients);
    }

    #[test]
    fn power_law_skews_sizes_and_keeps_everyone() {
        let p = power_law_partition(16, 10, 100, 1.5);
        // head client dominates, tail clients survive with >= 1 sample
        assert!(p.clients[0].total > 8 * p.clients[15].total.max(1));
        for c in &p.clients {
            assert!(c.total >= 1, "no empty clients allowed");
        }
        // budget is approximately conserved (rounding + the >= 1 floor)
        let budget = 16 * 100;
        assert!(p.total >= budget / 2 && p.total <= budget + 16, "total {}", p.total);
        // a gentler exponent flattens the head/tail ratio
        let flat = power_law_partition(16, 10, 100, 0.2);
        let ratio = |p: &Partition| p.clients[0].total as f64 / p.clients[15].total as f64;
        assert!(ratio(&p) > ratio(&flat));
    }

    #[test]
    fn femnist_assigns_all_writers() {
        let mut rng = Rng::new(5);
        let p = femnist_partition(8, 62, 100, 300, &mut rng);
        let mut owned: Vec<usize> = p.clients.iter().flat_map(|c| c.writers.clone()).collect();
        owned.sort_unstable();
        owned.dedup();
        assert_eq!(owned.len(), 100, "every writer owned exactly once");
        for c in &p.clients {
            assert_eq!(c.total, 300);
        }
    }
}
