//! Federated data substrates: synthetic benchmark generators, IID /
//! Dirichlet / writer-based partitioning plus extreme-non-IID scenarios
//! (single-class shards, power-law sizes), batch iterators (DESIGN.md §4).

pub mod batches;
pub mod partition;
pub mod synthetic;

pub use batches::BatchSource;
pub use partition::{
    dirichlet_partition, femnist_partition, iid_partition, power_law_partition,
    single_class_partition, ClientData, Partition,
};
pub use synthetic::{DatasetKind, Generator};

use crate::config::{PartitionKind, RunConfig};
use crate::util::rng::Rng;

/// Build the run's client data partition from its config, on a fixed RNG
/// stream derived from `cfg.seed`.  Every federation role (coordinator
/// core, in-proc participant, worker processes) calls this with the same
/// config and therefore reconstructs the *identical* partition — the
/// distribution is never shipped over the wire.
pub fn partition_for(cfg: &RunConfig) -> Partition {
    let mut rng = Rng::new(cfg.seed).fork(0x9A27);
    let classes = cfg.dataset.num_classes();
    match cfg.partition {
        PartitionKind::Iid => iid_partition(cfg.n_clients, classes, cfg.samples),
        PartitionKind::Dirichlet { alpha } => {
            dirichlet_partition(cfg.n_clients, classes, cfg.samples, alpha, &mut rng)
        }
        PartitionKind::Writers => femnist_partition(
            cfg.n_clients,
            classes,
            cfg.dataset.num_writers().max(cfg.n_clients),
            cfg.samples,
            &mut rng,
        ),
        PartitionKind::SingleClass => {
            single_class_partition(cfg.n_clients, classes, cfg.samples)
        }
        PartitionKind::PowerLaw { exponent } => {
            power_law_partition(cfg.n_clients, classes, cfg.samples, exponent)
        }
    }
}
