//! Federated data substrates: synthetic benchmark generators, IID /
//! Dirichlet / writer-based partitioning, batch iterators (DESIGN.md §4).

pub mod batches;
pub mod partition;
pub mod synthetic;

pub use batches::BatchSource;
pub use partition::{dirichlet_partition, femnist_partition, iid_partition, ClientData, Partition};
pub use synthetic::{DatasetKind, Generator};
