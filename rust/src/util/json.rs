//! Minimal JSON parser + writer.
//!
//! Substrate built from scratch: the offline vendor set has no `serde_json`
//! (see DESIGN.md §4).  Supports the full JSON grammar needed by the
//! artifact manifests and experiment reports: objects, arrays, strings with
//! escapes, numbers, bools, null.  Key order is preserved on round-trip.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// `get` that fails loudly with the missing key name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(items) => items.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0).map(|v| v as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }
    /// Convenience: object -> map view.
    pub fn to_map(&self) -> BTreeMap<&str, &Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().map(|(k, v)| (k.as_str(), v)).collect(),
            _ => BTreeMap::new(),
        }
    }

    // -- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- writing -----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }
    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => write_seq(out, indent, '[', ']', items.len(), |out, i, ind| {
                items[i].write(out, ind)
            }),
            Json::Obj(pairs) => write_seq(out, indent, '{', '}', pairs.len(), |out, i, ind| {
                write_str(out, &pairs[i].0);
                out.push_str(": ");
                pairs[i].1.write(out, ind);
            }),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_num(out: &mut String, v: f64) {
    if v.is_finite() && v == v.trunc() && v.abs() < 9.0e15 {
        out.push_str(&format!("{}", v as i64));
    } else if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    indent: Option<usize>,
    open: char,
    close: char,
    n: usize,
    mut item: impl FnMut(&mut String, usize, Option<usize>),
) {
    out.push(open);
    if n == 0 {
        out.push(close);
        return;
    }
    let inner = indent.map(|i| i + 1);
    for i in 0..n {
        if let Some(ind) = inner {
            out.push('\n');
            out.push_str(&" ".repeat(ind));
        }
        item(out, i, inner);
        if i + 1 != n {
            out.push(',');
            if indent.is_none() {
                out.push(' ');
            }
        }
    }
    if let Some(ind) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(ind));
    }
    out.push(close);
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad unicode escape"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"model": "resnet20", "params": [{"name": "stem.w", "shape": [3, 3, 3, 16], "dim": 432}], "lr": 0.8, "ok": true, "x": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "b": false, "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("n").unwrap().as_i64(), Some(7));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.req("nope").is_err());
    }

    #[test]
    fn builder_api() {
        let v = Json::obj(vec![
            ("name", Json::str("x")),
            ("vals", Json::arr([Json::num(1), Json::num(2.5)])),
        ]);
        assert_eq!(v.to_string(), r#"{"name": "x", "vals": [1, 2.5]}"#);
    }
}
