//! Small statistics helpers used by metrics and the bench harness.

/// Running mean/variance (Welford) + min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a copy of the data (nearest-rank on sorted values).
pub fn percentile(data: &[f64], p: f64) -> f64 {
    assert!(!data.is_empty());
    let mut v = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(data: &[f64]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    data.iter().sum::<f64>() / data.len() as f64
}

pub fn stddev(data: &[f64]) -> f64 {
    if data.len() < 2 {
        return 0.0;
    }
    let m = mean(data);
    (data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (data.len() - 1) as f64).sqrt()
}

/// Exponential moving average over a series (used for smoothed loss curves).
pub fn ema(data: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(data.len());
    let mut acc = None;
    for &x in data {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct_computation() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0], 0.5);
        assert_eq!(out, vec![0.0, 5.0]);
        assert!(ema(&[], 0.3).is_empty());
    }
}
