//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed getters with defaults; unknown-flag detection.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                let (key, val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let val = match val {
                    Some(v) => v,
                    None => {
                        // consume the next token as the value unless it is
                        // itself a flag -> boolean switch
                        match it.peek() {
                            Some(nxt) if !nxt.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                out.seen.push(key.clone());
                out.flags.insert(key, val);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).map(|v| v == "true" || v == "1" || v == "yes").unwrap_or(default)
    }
    /// Comma-separated list, e.g. `--phi 2,4`.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        }
    }
    /// Keys the user actually passed (for unknown-flag diagnostics).
    pub fn given_keys(&self) -> &[String] {
        &self.seen
    }
    /// Error on any flag not in `known` (catches typos in experiment scripts).
    pub fn check_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for k in &self.seen {
            if !known.contains(&k.as_str()) {
                anyhow::bail!("unknown flag --{k}; known: {}", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_forms() {
        let a = args("train pos1 pos2 --model resnet20 --tau=6 --phi 4 --verbose");
        assert_eq!(a.positional, vec!["train", "pos1", "pos2"]);
        assert_eq!(a.str_or("model", "x"), "resnet20");
        assert_eq!(a.usize_or("tau", 0), 6);
        assert_eq!(a.usize_or("phi", 0), 4);
        assert!(a.bool_or("verbose", false));
        assert!(!a.has("missing"));
    }

    #[test]
    fn defaults_and_lists() {
        let a = args("--phis 2,4,8 --lr 0.8");
        assert_eq!(a.list_or::<usize>("phis", &[]), vec![2, 4, 8]);
        assert_eq!(a.list_or::<usize>("taus", &[6]), vec![6]);
        assert!((a.f64_or("lr", 0.0) - 0.8).abs() < 1e-12);
        assert_eq!(a.usize_or("clients", 16), 16);
    }

    #[test]
    fn boolean_before_flag() {
        let a = args("--dry-run --out x.json");
        assert!(a.bool_or("dry-run", false));
        assert_eq!(a.str_or("out", ""), "x.json");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = args("--model mlp --typo 3");
        assert!(a.check_known(&["model"]).is_err());
        assert!(a.check_known(&["model", "typo"]).is_ok());
    }
}
