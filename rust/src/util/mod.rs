//! Zero-dependency substrates (see DESIGN.md §4: the offline vendor set has
//! no serde_json / clap / rand / rayon / proptest, so these are built from
//! scratch and tested here).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
