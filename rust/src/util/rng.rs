//! Deterministic PRNG + distributions.
//!
//! Substrate built from scratch (no `rand`/`rand_distr` in the offline
//! vendor set).  Core generator is SplitMix64 feeding xoshiro256**, the
//! usual high-quality non-crypto pairing.  Distributions implemented on
//! top: uniform, normal (Box–Muller), gamma (Marsaglia–Tsang), Dirichlet
//! (normalized gammas — what the paper uses for non-IID label skew) and
//! categorical sampling.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)], spare: None }
    }

    /// Snapshot the full generator state — xoshiro words plus the cached
    /// Box–Muller spare — so a checkpoint can resume the stream exactly.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot; the restored
    /// stream continues bit-identically from the snapshot point.
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Rng {
        Rng { s, spare }
    }

    /// Independent child stream (for per-client determinism regardless of
    /// scheduling order).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA0761D6478BD642F).wrapping_add(0xE7037ED1A0B428DB);
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)], spare: None }
    }

    /// xoshiro256** next
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n || n.is_power_of_two() {
                return (m >> 64) as usize;
            }
            if lo >= n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang; boost for k < 1.
    pub fn gamma(&mut self, k: f64) -> f64 {
        debug_assert!(k > 0.0);
        if k < 1.0 {
            // Gamma(k) = Gamma(k+1) * U^(1/k)
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(k + 1.0) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_n): the label-skew generator the paper uses.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = g.iter().sum();
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices out of [0, n) (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
        let mut f1 = Rng::new(7).fork(1);
        let mut f2 = Rng::new(7).fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(4);
        for &k in &[0.1, 0.5, 1.0, 3.0, 10.0] {
            let n = 30_000;
            let mut s = 0.0;
            for _ in 0..n {
                let v = r.gamma(k);
                assert!(v >= 0.0);
                s += v;
            }
            let mean = s / n as f64;
            assert!((mean - k).abs() < 0.12 * k.max(1.0), "k={k} mean={mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentrates() {
        let mut r = Rng::new(5);
        let p = r.dirichlet(0.1, 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // alpha=0.1 is highly skewed: the max component should dominate.
        let mx = p.iter().cloned().fold(0.0, f64::max);
        assert!(mx > 0.3, "alpha=0.1 should concentrate, got max {mx}");
        // alpha=100 is nearly uniform.
        let p = r.dirichlet(100.0, 10);
        for v in &p {
            assert!((v - 0.1).abs() < 0.05);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(7);
        for _ in 0..100 {
            let mut ks = r.choose_k(20, 5);
            ks.sort_unstable();
            ks.dedup();
            assert_eq!(ks.len(), 5);
            assert!(ks.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn state_snapshot_resumes_bit_identically() {
        let mut r = Rng::new(9);
        let _ = r.normal(); // park a Box–Muller spare in the state
        let (s, spare) = r.state();
        assert!(spare.is_some());
        let mut resumed = Rng::from_state(s, spare);
        for _ in 0..64 {
            assert_eq!(r.next_u64(), resumed.next_u64());
            assert_eq!(r.normal().to_bits(), resumed.normal().to_bits());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
