//! Mini property-based testing framework (no `proptest` offline).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` randomly generated
//! inputs; on failure it greedily shrinks the input via the strategy's
//! `shrink` before reporting, and always reports the failing seed so runs
//! reproduce.  Strategies compose with `map`/`filter`/tuples.

use crate::util::rng::Rng;

pub trait Strategy {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; empty = fully shrunk.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run a property over `cases` random inputs (seed fixed by caller for
/// reproducibility).  Panics with the shrunk counterexample on failure.
pub fn forall<S, F>(seed: u64, cases: usize, strat: &S, prop: F)
where
    S: Strategy,
    F: Fn(&S::Value) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = strat.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (shrunk, msg) = shrink_loop(strat, input, msg, &prop);
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\n  counterexample (shrunk): {shrunk:?}"
            );
        }
    }
}

fn shrink_loop<S, F>(strat: &S, mut cur: S::Value, mut msg: String, prop: &F) -> (S::Value, String)
where
    S: Strategy,
    F: Fn(&S::Value) -> Result<(), String>,
{
    // Greedy descent, bounded to avoid pathological loops.
    'outer: for _ in 0..200 {
        for cand in strat.shrink(&cur) {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (cur, msg)
}

// ---------------------------------------------------------------------------
// Base strategies
// ---------------------------------------------------------------------------

pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize, // inclusive
}

impl Strategy for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below(self.hi - self.lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

pub struct F64In {
    pub lo: f64,
    pub hi: f64,
}

impl Strategy for F64In {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = (self.lo + self.hi) / 2.0;
        if (*v - self.lo).abs() > 1e-9 {
            vec![self.lo, self.lo + (v - self.lo) / 2.0, mid.min(*v)]
        } else {
            vec![]
        }
    }
}

/// Vector of f64 with length in [min_len, max_len].
pub struct VecF64 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f64,
    pub hi: f64,
}

impl Strategy for VecF64 {
    type Value = Vec<f64>;
    fn generate(&self, rng: &mut Rng) -> Vec<f64> {
        let n = self.min_len + rng.below(self.max_len - self.min_len + 1);
        (0..n).map(|_| rng.range_f64(self.lo, self.hi)).collect()
    }
    fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() - 1].to_vec());
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
        }
        // zero-out elements one at a time
        for i in 0..v.len().min(8) {
            if v[i] != self.lo {
                let mut w = v.clone();
                w[i] = self.lo;
                out.push(w);
            }
        }
        out
    }
}

/// Pair of independent strategies.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Map a strategy through a function (no shrinking through the map).
pub struct Map<S, F> {
    pub inner: S,
    pub f: F,
}

impl<S: Strategy, T: std::fmt::Debug + Clone, F: Fn(S::Value) -> T> Strategy for Map<S, F> {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        (self.f)(self.inner.generate(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(1, 200, &UsizeIn { lo: 0, hi: 100 }, |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "counterexample")]
    fn fails_and_reports() {
        forall(2, 200, &UsizeIn { lo: 0, hi: 100 }, |&v| {
            if v < 50 {
                Ok(())
            } else {
                Err(format!("{v} >= 50"))
            }
        });
    }

    #[test]
    fn shrinks_toward_minimum() {
        // capture the panic message and check the counterexample is small
        let result = std::panic::catch_unwind(|| {
            forall(3, 500, &UsizeIn { lo: 0, hi: 1000 }, |&v| {
                if v < 37 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink should land on exactly 37 (smallest failing value)
        assert!(msg.contains("(shrunk): 37"), "got: {msg}");
    }

    #[test]
    fn vec_strategy_respects_bounds() {
        forall(4, 100, &VecF64 { min_len: 2, max_len: 9, lo: -1.0, hi: 1.0 }, |v| {
            if v.len() >= 2 && v.len() <= 9 && v.iter().all(|x| (-1.0..1.0).contains(x)) {
                Ok(())
            } else {
                Err(format!("bad vec {v:?}"))
            }
        });
    }

    #[test]
    fn pair_strategy() {
        forall(
            5,
            100,
            &Pair(UsizeIn { lo: 1, hi: 8 }, F64In { lo: 0.0, hi: 1.0 }),
            |(n, x)| {
                if *n >= 1 && *x < 1.0 {
                    Ok(())
                } else {
                    Err("bad pair".into())
                }
            },
        );
    }
}
