//! Scoped parallel-map over clients.
//!
//! Substrate: no rayon/tokio offline, so client fan-out uses
//! `std::thread::scope` with a work-stealing-free static chunking that is
//! deterministic (each worker owns a fixed index stride).  The PJRT CPU
//! client is itself multi-threaded for large ops, so the pool is for
//! overlapping many small per-client executions.

/// Parallel map `f(i)` for `i in 0..n`, preserving output order.
/// `threads == 0 or 1` runs inline (deterministic and allocation-free).
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunks = split_mut_indexed(&mut out, threads);
    std::thread::scope(|s| {
        for (offset, chunk) in chunks {
            let f = &f;
            s.spawn(move || {
                for (j, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(offset + j));
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("par_map worker panicked")).collect()
}

/// Parallel map with mutable access: `f(i, &mut items[i])` for every item,
/// preserving output order.  Items are split into contiguous per-worker
/// chunks (the same deterministic partition as `par_map`), so disjoint
/// mutable access is guaranteed by construction.  `threads <= 1` runs
/// inline, in index order — the cluster runtime relies on the parallel
/// path being observationally identical to that serial order for
/// independent per-item work.
pub fn par_map_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter_mut().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let item_chunks = split_mut_indexed(items, threads);
    let out_chunks = split_mut_indexed(&mut out, threads);
    std::thread::scope(|s| {
        for ((offset, ichunk), (_, ochunk)) in item_chunks.into_iter().zip(out_chunks) {
            let f = &f;
            s.spawn(move || {
                for (j, (item, slot)) in ichunk.iter_mut().zip(ochunk.iter_mut()).enumerate() {
                    *slot = Some(f(offset + j, item));
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("par_map_mut worker panicked")).collect()
}

/// Split a mutable slice into ~equal chunks, tagging each with its offset.
fn split_mut_indexed<T>(xs: &mut [T], parts: usize) -> Vec<(usize, &mut [T])> {
    let n = xs.len();
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut rest = xs;
    let mut offset = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        let (head, tail) = rest.split_at_mut(len);
        if !head.is_empty() {
            out.push((offset, head));
        }
        offset += len;
        rest = tail;
    }
    out
}

/// Number of worker threads to use by default: leave two cores for the
/// PJRT runtime's own pool.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get().saturating_sub(2).max(1)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let out = par_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_all_once() {
        let counter = AtomicUsize::new(0);
        let out = par_map(37, 5, |i| {
            counter.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(counter.load(Ordering::SeqCst), 37);
        assert_eq!(out.len(), 37);
    }

    #[test]
    fn inline_path_and_empty() {
        assert_eq!(par_map(3, 1, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(2, 100, |i| i), vec![0, 1]); // threads clamped to n
    }

    #[test]
    fn par_map_mut_mutates_and_preserves_order() {
        for threads in [1, 3, 8] {
            let mut items: Vec<usize> = (0..37).collect();
            let out = par_map_mut(&mut items, threads, |i, v| {
                *v += 100;
                i * 2
            });
            assert_eq!(out, (0..37).map(|i| i * 2).collect::<Vec<_>>(), "t={threads}");
            assert_eq!(items, (100..137).collect::<Vec<_>>(), "t={threads}");
        }
    }

    #[test]
    fn par_map_mut_runs_each_exactly_once() {
        let counter = AtomicUsize::new(0);
        let mut items = vec![0u8; 23];
        let out = par_map_mut(&mut items, 5, |i, _| {
            counter.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(counter.load(Ordering::SeqCst), 23);
        assert_eq!(out.len(), 23);
        let mut empty: Vec<u8> = Vec::new();
        assert!(par_map_mut(&mut empty, 4, |i, _| i).is_empty());
    }
}
