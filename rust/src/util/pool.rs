//! Deterministic parallel-map over a **persistent** worker pool.
//!
//! Substrate: no rayon/tokio offline.  Historically every `par_map*` call
//! spawned fresh `std::thread::scope` threads; at federated scale (one
//! fan-out per training block) thread creation became measurable, so the
//! workers are now long-lived: spawned lazily on first use, parked on a
//! condvar between calls, and reused by every subsequent fan-out
//! (`runtime::cluster`, per-block parallelism, benches).
//!
//! Determinism is unchanged: work is split into the same contiguous
//! per-call chunks as before (static chunking keyed by the `threads`
//! argument, no work stealing), each chunk writes its own disjoint output
//! slots, and the caller blocks until every chunk finished — so which
//! worker runs which chunk (and how many workers exist) can never
//! influence results.  `threads <= 1` still runs inline.
//!
//! Lifecycle: the pool is a lazy global; `shutdown()` parks it cleanly
//! (signals, wakes, joins) and the next parallel call respawns it.  A
//! panicking task is contained on the worker (the worker survives for the
//! next call) and re-raised on the caller **after** every sibling chunk
//! finished, so borrowed inputs never outlive the call.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Upper bound on pool size: oversubscribing beyond this only adds
/// scheduler pressure (chunk counts are not capped — excess chunks queue).
const MAX_WORKERS: usize = 64;

struct PoolState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

static POOL: Mutex<Option<Pool>> = Mutex::new(None);
/// Cumulative workers ever spawned (reuse observability; see tests).
static SPAWNED_TOTAL: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True on pool worker threads.  A fan-out issued from *inside* a
    /// pool task must not wait on the same fixed-size pool (all workers
    /// could be blocked on outer chunks — a deadlock the historical
    /// per-call `thread::scope` never had), so nested `run_tasks` calls
    /// on worker threads run their chunks inline instead.
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn worker_loop(shared: Arc<Shared>) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Jobs are wrapped by `run_tasks` and never unwind; `job()` is
        // still the only uncontained call site, so keep it last.
        job();
    }
}

/// Queue `jobs` on the global pool, growing it to at least `want` workers
/// (capped).  Spawns lazily: a process that never fans out never spawns.
fn submit(jobs: Vec<Job>, want: usize) {
    let mut guard = POOL.lock().unwrap();
    let pool = guard.get_or_insert_with(|| Pool {
        shared: Arc::new(Shared {
            state: Mutex::new(PoolState { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        }),
        handles: Vec::new(),
    });
    let want = want.clamp(1, MAX_WORKERS);
    while pool.handles.len() < want {
        let shared = Arc::clone(&pool.shared);
        let handle = std::thread::Builder::new()
            .name(format!("fedlama-pool-{}", pool.handles.len()))
            .spawn(move || worker_loop(shared))
            .expect("failed to spawn pool worker");
        pool.handles.push(handle);
        SPAWNED_TOTAL.fetch_add(1, Ordering::Relaxed);
    }
    {
        let mut st = pool.shared.state.lock().unwrap();
        st.jobs.extend(jobs);
    }
    pool.shared.work_cv.notify_all();
}

/// Cumulative number of worker threads ever spawned by this process —
/// stable across repeated `par_map*` calls once the pool is warm.
pub fn workers_spawned_total() -> usize {
    SPAWNED_TOTAL.load(Ordering::Relaxed)
}

/// Live worker count (0 when the pool is not running).
pub fn pool_size() -> usize {
    POOL.lock().unwrap().as_ref().map(|p| p.handles.len()).unwrap_or(0)
}

/// Shut the pool down cleanly: signal, wake, join.  Queued jobs finish
/// first.  The next parallel call transparently respawns the pool, so
/// this is safe to call at any quiescent point (process exit, tests).
pub fn shutdown() {
    let pool = POOL.lock().unwrap().take();
    if let Some(mut pool) = pool {
        {
            let mut st = pool.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        pool.shared.work_cv.notify_all();
        for h in pool.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Counts completed sibling tasks so the caller can block until its
/// borrows are released by every worker.
struct Latch {
    remaining: Mutex<usize>,
    done_cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { remaining: Mutex::new(n), done_cv: Condvar::new() }
    }
    fn count_down(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.done_cv.notify_all();
        }
    }
    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.done_cv.wait(g).unwrap();
        }
    }
}

/// Run `tasks` to completion: the first on the calling thread, the rest
/// on the persistent pool.  Returns only after **every** task finished
/// (even when one panicked — the panic is re-raised here afterwards), so
/// tasks may borrow from the caller's frame.
///
/// Safe to call from within a pool task: nested calls on worker threads
/// execute their chunks inline, in order (bit-identical — chunks are
/// disjoint and chunk order equals serial order), instead of deadlocking
/// the fixed-size pool.
pub fn run_tasks(tasks: Vec<Box<dyn FnOnce() + Send + '_>>) {
    let n = tasks.len();
    if n == 0 {
        return;
    }
    if IS_POOL_WORKER.with(|f| f.get()) {
        // Nested fan-out on a worker: no remote borrows outstanding, so
        // running (and unwinding) inline is safe.
        for t in tasks {
            t();
        }
        return;
    }
    let mut iter = tasks.into_iter();
    let local = iter.next().expect("n >= 1");
    if n == 1 {
        // No remote borrows outstanding: run (and unwind) directly.
        local();
        return;
    }
    let latch = Arc::new(Latch::new(n - 1));
    let panicked = Arc::new(AtomicBool::new(false));
    let mut remote: Vec<Job> = Vec::with_capacity(n - 1);
    for t in iter {
        // SAFETY: `run_tasks` does not return (or unwind) before the
        // latch has counted every remote task down, so the non-'static
        // borrows captured by `t` strictly outlive its execution.
        let t = unsafe { erase_lifetime(t) };
        let latch = Arc::clone(&latch);
        let panicked = Arc::clone(&panicked);
        remote.push(Box::new(move || {
            if catch_unwind(AssertUnwindSafe(t)).is_err() {
                panicked.store(true, Ordering::SeqCst);
            }
            latch.count_down();
        }));
    }
    submit(remote, n - 1);
    let local_ok = catch_unwind(AssertUnwindSafe(local)).is_ok();
    latch.wait();
    if !local_ok || panicked.load(Ordering::SeqCst) {
        panic!("pool task panicked");
    }
}

/// Pretend a scoped task is `'static` so it can cross into the persistent
/// pool's queue.
///
/// # Safety
/// The caller must not return (or unwind) before the task has finished
/// executing — `run_tasks` guarantees this with its completion latch.
unsafe fn erase_lifetime<'a>(
    t: Box<dyn FnOnce() + Send + 'a>,
) -> Box<dyn FnOnce() + Send + 'static> {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send + 'static>>(t)
}

/// Parallel map `f(i)` for `i in 0..n`, preserving output order.
/// `threads == 0 or 1` runs inline (deterministic and allocation-free).
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let f = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = split_mut_indexed(&mut out, threads)
            .into_iter()
            .map(|(offset, chunk)| {
                Box::new(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = Some(f(offset + j));
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_tasks(tasks);
    }
    out.into_iter().map(|v| v.expect("par_map worker panicked")).collect()
}

/// Parallel map with mutable access: `f(i, &mut items[i])` for every item,
/// preserving output order.  Items are split into contiguous per-worker
/// chunks (the same deterministic partition as `par_map`), so disjoint
/// mutable access is guaranteed by construction.  `threads <= 1` runs
/// inline, in index order — the cluster runtime relies on the parallel
/// path being observationally identical to that serial order for
/// independent per-item work.
pub fn par_map_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter_mut().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    {
        let f = &f;
        let item_chunks = split_mut_indexed(items, threads);
        let out_chunks = split_mut_indexed(&mut out, threads);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = item_chunks
            .into_iter()
            .zip(out_chunks)
            .map(|((offset, ichunk), (_, ochunk))| {
                Box::new(move || {
                    for (j, (item, slot)) in
                        ichunk.iter_mut().zip(ochunk.iter_mut()).enumerate()
                    {
                        *slot = Some(f(offset + j, item));
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        run_tasks(tasks);
    }
    out.into_iter().map(|v| v.expect("par_map_mut worker panicked")).collect()
}

/// Split a mutable slice into ~equal chunks, tagging each with its offset.
fn split_mut_indexed<T>(xs: &mut [T], parts: usize) -> Vec<(usize, &mut [T])> {
    let n = xs.len();
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut rest = xs;
    let mut offset = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        let (head, tail) = rest.split_at_mut(len);
        if !head.is_empty() {
            out.push((offset, head));
        }
        offset += len;
        rest = tail;
    }
    out
}

/// Number of worker threads to use by default: leave two cores for the
/// PJRT runtime's own pool.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get().saturating_sub(2).max(1)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let out = par_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_all_once() {
        let counter = AtomicUsize::new(0);
        let out = par_map(37, 5, |i| {
            counter.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(counter.load(Ordering::SeqCst), 37);
        assert_eq!(out.len(), 37);
    }

    #[test]
    fn inline_path_and_empty() {
        assert_eq!(par_map(3, 1, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(2, 100, |i| i), vec![0, 1]); // threads clamped to n
    }

    #[test]
    fn par_map_mut_mutates_and_preserves_order() {
        for threads in [1, 3, 8] {
            let mut items: Vec<usize> = (0..37).collect();
            let out = par_map_mut(&mut items, threads, |i, v| {
                *v += 100;
                i * 2
            });
            assert_eq!(out, (0..37).map(|i| i * 2).collect::<Vec<_>>(), "t={threads}");
            assert_eq!(items, (100..137).collect::<Vec<_>>(), "t={threads}");
        }
    }

    #[test]
    fn par_map_mut_runs_each_exactly_once() {
        let counter = AtomicUsize::new(0);
        let mut items = vec![0u8; 23];
        let out = par_map_mut(&mut items, 5, |i, _| {
            counter.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(counter.load(Ordering::SeqCst), 23);
        assert_eq!(out.len(), 23);
        let mut empty: Vec<u8> = Vec::new();
        assert!(par_map_mut(&mut empty, 4, |i, _| i).is_empty());
    }

    #[test]
    fn nested_par_map_runs_inline_instead_of_deadlocking() {
        // outer chunk on a worker thread fans out again: the nested call
        // must run inline (same results, no deadlock)
        let out = par_map(4, 2, |i| par_map(3, 2, move |j| i * 10 + j));
        let want: Vec<Vec<usize>> =
            (0..4).map(|i| (0..3).map(|j| i * 10 + j).collect()).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn panicking_task_is_contained_and_reraised() {
        let hit = std::panic::catch_unwind(|| {
            par_map(8, 4, |i| {
                if i == 5 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(hit.is_err(), "panic must propagate to the caller");
        // the pool survives a panicking task
        assert_eq!(par_map(6, 3, |i| i + 1), vec![1, 2, 3, 4, 5, 6]);
    }
}
