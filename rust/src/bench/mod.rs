//! Kernel / op / end-to-end microbenches behind `fedlama bench`.
//!
//! Produces the machine-readable perf artifact `BENCH_kernels.json`
//! (repo root by default): per-shape GFLOP/s and ns/iter for every matmul
//! kernel on both the detected SIMD path and the forced-scalar path
//! (`speedup_vs_scalar` is the headline number), plus op-level
//! forward/backward latency, end-to-end native train-step latency,
//! the persistent pool's dispatch overhead, and the wire `transport`
//! section (encode/decode throughput + peak staging, monolithic vs
//! streamed per-layer framing).  `--quick` shrinks the rep budget for CI
//! smoke runs; the measured numbers stay comparable across runs of the
//! same machine but are *not* normalized across machines — always read
//! the `isa` field next to the numbers.
//!
//! The same entry point backs the `micro-kernel` section of the
//! `cargo bench` harness, so the CLI artifact and the bench table can
//! never drift apart.

use std::time::Instant;

use anyhow::Result;

use crate::data::DatasetKind;
use crate::runtime::ops::matmul::{matmul_acc_with, matmul_at_acc_with, matmul_bt_with};
use crate::runtime::ops::{Conv2d, Dense, LayerOp, Scratch};
use crate::runtime::simd::{self, Isa};
use crate::runtime::{zoo, ComputeBackend};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Rng;

#[derive(Default)]
pub struct BenchOpts {
    /// Shrink rep budgets (CI smoke).
    pub quick: bool,
    /// Worker threads for the pool section; 0 = auto.
    pub threads: usize,
    /// Add the `scale` section: registry roster rounds at million-client
    /// scale with spill-to-disk state and O(sampled) round memory.
    pub scale: bool,
    /// Roster size for `--scale`; 0 = default (1M, or 10k with --quick).
    pub registered: usize,
    /// Clients sampled per round for `--scale`; 0 = default (1000, or
    /// 100 with --quick).
    pub sampled: usize,
}

/// The bench shapes: the Dense layers of the zoo presets and the im2col
/// matmul shapes of the conv stem / stage-1 / stage-2 layers (batch 8).
/// (label, m, k, n) with `c[m,n] += a[m,k] b[k,n]`.
const SHAPES: &[(&str, usize, usize, usize)] = &[
    ("dense_784x64_b8", 8, 784, 64),
    ("dense_3072x128_b8", 8, 3072, 128),
    ("conv_stem_3x3x3_16_im2col_b8", 8 * 32 * 32, 27, 16),
    ("conv_s1_3x3x16_16_im2col_b8", 8 * 32 * 32, 144, 16),
    ("conv_s2_3x3x16_32_im2col_b8", 8 * 16 * 16, 144, 32),
];

/// Run every section and assemble the artifact document.
pub fn run(opts: &BenchOpts) -> Result<Json> {
    let isa = simd::active_isa();
    let threads = if opts.threads == 0 { pool::default_threads() } else { opts.threads };
    let kernels = bench_kernels(opts.quick, isa);
    let ops = bench_ops(opts.quick)?;
    let end_to_end = bench_end_to_end(opts.quick)?;
    let pool_section = bench_pool(threads);
    let transport = bench_transport(opts.quick)?;
    let mut doc = vec![
        ("schema", Json::num(1)),
        ("generated_by", Json::str("fedlama bench")),
        ("measured", Json::Bool(true)),
        ("quick", Json::Bool(opts.quick)),
        ("isa", Json::str(isa.name())),
        ("lane_width", Json::num(isa.lane_width() as f64)),
        ("kernels", kernels),
        ("ops", ops),
        ("end_to_end", end_to_end),
        ("pool", pool_section),
        ("transport", transport),
    ];
    if opts.scale {
        doc.push(("scale", bench_scale(opts)?));
    }
    Ok(Json::obj(doc))
}

/// Just the kernel section plus its dispatch metadata — the `cargo
/// bench` harness renders this without re-measuring the op / end-to-end
/// / pool sections it already benches itself.
pub fn kernels_doc(quick: bool) -> Json {
    let isa = simd::active_isa();
    Json::obj(vec![
        ("isa", Json::str(isa.name())),
        ("kernels", bench_kernels(quick, isa)),
    ])
}

/// Best-of-`reps` wall time of `f`, in nanoseconds.
fn time_ns<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_secs_f64() * 1e9;
        if ns < best {
            best = ns;
        }
    }
    best
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn kernel_entry(
    kernel: &str,
    shape: &str,
    (m, k, n): (usize, usize, usize),
    isa: Isa,
    simd_ns: f64,
    scalar_ns: f64,
    flops: f64,
) -> Json {
    Json::obj(vec![
        ("kernel", Json::str(kernel)),
        ("shape", Json::str(shape)),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("n", Json::num(n as f64)),
        ("dispatch", Json::str(isa.name())),
        ("ns_per_iter", Json::num(simd_ns)),
        // flops / ns == GFLOP/s
        ("gflops", Json::num(flops / simd_ns.max(1.0))),
        ("scalar_ns_per_iter", Json::num(scalar_ns)),
        ("scalar_gflops", Json::num(flops / scalar_ns.max(1.0))),
        ("speedup_vs_scalar", Json::num(scalar_ns / simd_ns.max(1.0))),
    ])
}

fn bench_kernels(quick: bool, isa: Isa) -> Json {
    let budget = if quick { 6.0e6 } else { 4.0e7 };
    let mut rng = Rng::new(11);
    let mut out = Vec::new();
    for &(label, m, k, n) in SHAPES {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let dy = randv(&mut rng, m * n);
        let flops = 2.0 * (m * k * n) as f64;
        let reps = ((budget / flops) as usize).clamp(3, 200);

        let mut c = vec![0.0f32; m * n];
        let t_simd = time_ns(reps, || matmul_acc_with(isa, &a, &b, &mut c, m, k, n));
        let t_scalar =
            time_ns(reps, || matmul_acc_with(Isa::Scalar, &a, &b, &mut c, m, k, n));
        std::hint::black_box(&c);
        out.push(kernel_entry("matmul_acc", label, (m, k, n), isa, t_simd, t_scalar, flops));

        let mut gw = vec![0.0f32; k * n];
        let t_simd = time_ns(reps, || matmul_at_acc_with(isa, &a, &dy, &mut gw, m, k, n));
        let t_scalar =
            time_ns(reps, || matmul_at_acc_with(Isa::Scalar, &a, &dy, &mut gw, m, k, n));
        std::hint::black_box(&gw);
        out.push(kernel_entry("matmul_at_acc", label, (m, k, n), isa, t_simd, t_scalar, flops));

        let mut dx = vec![0.0f32; m * k];
        let t_simd = time_ns(reps, || matmul_bt_with(isa, &dy, &b, &mut dx, m, n, k));
        let t_scalar =
            time_ns(reps, || matmul_bt_with(Isa::Scalar, &dy, &b, &mut dx, m, n, k));
        std::hint::black_box(&dx);
        out.push(kernel_entry("matmul_bt", label, (m, k, n), isa, t_simd, t_scalar, flops));
    }
    Json::Arr(out)
}

fn bench_ops(quick: bool) -> Result<Json> {
    let b = 8usize;
    let reps = if quick { 3 } else { 10 };
    type OpCase = (&'static str, Box<dyn LayerOp>, Vec<usize>);
    let cases: Vec<OpCase> = vec![
        ("dense_3072_128", Box::new(Dense::new("d", 3072, 128)), vec![3072]),
        (
            "conv3x3_16_16_at32",
            Box::new(Conv2d::new("c", [32, 32, 16], 16, 3, 1, 1)),
            vec![32, 32, 16],
        ),
    ];
    let mut out = Vec::new();
    for (label, op, in_shape) in cases {
        let in_dim: usize = in_shape.iter().product();
        let out_shape = op.out_shape(&in_shape)?;
        let out_dim: usize = out_shape.iter().product();
        let root = Rng::new(3);
        let ps: Vec<crate::runtime::HostTensor> = op
            .params()
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let mut r = root.fork(i as u64);
                spec.init.materialize(&spec.shape, &mut r)
            })
            .collect();
        let n_params: usize = ps.iter().map(|p| p.data.len()).sum();
        let mut rng = Rng::new(4);
        let x = randv(&mut rng, b * in_dim);
        let dy = randv(&mut rng, b * out_dim);
        let mut y = vec![0.0f32; b * out_dim];
        let mut dx = vec![0.0f32; b * in_dim];
        let mut grads: Vec<crate::runtime::HostTensor> =
            ps.iter().map(|p| crate::runtime::HostTensor::zeros(&p.shape)).collect();
        let mut s = Scratch::default();
        op.forward(&ps, &x, &mut y, b, &mut s); // warm the scratch pool
        let fwd_ns = time_ns(reps, || op.forward(&ps, &x, &mut y, b, &mut s));
        let bwd_ns =
            time_ns(reps, || op.backward(&ps, &x, &y, &dy, &mut dx, &mut grads, b, &mut s));
        let cout = *out_shape.last().unwrap();
        let bias_len = ps.last().map(|p| p.data.len()).unwrap_or(0);
        let flops = 2.0 * (b * out_dim / cout) as f64 * (n_params - bias_len) as f64;
        out.push(Json::obj(vec![
            ("op", Json::str(label)),
            ("params", Json::num(n_params as f64)),
            ("fwd_ms", Json::num(fwd_ns / 1e6)),
            ("bwd_ms", Json::num(bwd_ns / 1e6)),
            ("fwd_gflops", Json::num(flops / fwd_ns.max(1.0))),
        ]));
    }
    Ok(Json::Arr(out))
}

fn bench_end_to_end(quick: bool) -> Result<Json> {
    let reps = if quick { 3 } else { 10 };
    let rt = zoo::build("femnist_cnn", DatasetKind::Femnist)?;
    let mut params = rt.init_params(0)?;
    let b = rt.manifest().batch_size;
    let d: usize = rt.manifest().input_shape.iter().product();
    let classes = rt.manifest().num_classes;
    let mut rng = Rng::new(1);
    let x = randv(&mut rng, b * d);
    let y: Vec<i32> = (0..b).map(|i| (i % classes) as i32).collect();
    rt.train_step(&mut params, &x, &y, 0.05)?; // warmup
    let mut err = None;
    let step_ns = time_ns(reps, || {
        if let Err(e) = rt.train_step(&mut params, &x, &y, 0.05) {
            err = Some(e);
        }
    });
    if let Some(e) = err {
        return Err(e);
    }
    Ok(Json::arr([Json::obj(vec![
        ("name", Json::str("femnist_cnn_train_step_b8")),
        ("ms_per_step", Json::num(step_ns / 1e6)),
    ])]))
}

/// The wire `transport` section: encode/decode throughput, frame rate,
/// and peak *owned staging* bytes for a model-sync worst case — one dense
/// `LayerUpdate` per parameter group — on both wire paths:
///
///   - `monolithic`: one frame per message (the historical v1 shape;
///     still decodable, so it is benchable from the same binary) — the
///     whole message is copied into a frame buffer, so peak staging is
///     the largest *message*.
///   - `streamed`: per-layer frames with scatter-gather encode — tensor
///     storage is borrowed, so peak staging is the framing plus the
///     largest tensor's non-borrowed bytes.
///
/// Decode timing drives `MessageStream` over the produced bytes, which
/// exercises deframe + CRC + reassembly exactly as the transports do.
fn bench_transport(quick: bool) -> Result<Json> {
    use crate::protocol::messages::{
        streamed_frame_count, streamed_staging_bytes, LayerUpdate, Message, MessageStream, Payload,
    };
    let reps = if quick { 2 } else { 8 };
    let mut out = Vec::new();
    for &(model, dataset) in &[("mlp", DatasetKind::Toy), ("resnet20", DatasetKind::Cifar10)] {
        let rt = zoo::build(model, dataset)?;
        let params = rt.init_params(0)?;
        let msgs: Vec<Message> = rt
            .manifest()
            .groups
            .iter()
            .enumerate()
            .map(|(g, info)| {
                Message::Update(LayerUpdate {
                    k: 1,
                    group: g,
                    client: 0,
                    tensors: info
                        .params
                        .iter()
                        .map(|&pi| Payload::Dense(params[pi].data.clone()))
                        .collect(),
                })
            })
            .collect();

        // -- monolithic: one frame per message
        let mut mono_peak = 0usize;
        for m in &msgs {
            mono_peak = mono_peak.max(m.to_frame()?.len());
        }
        let mut sink: Vec<u8> = Vec::new();
        let enc_ns = time_ns(reps, || {
            sink.clear();
            for m in &msgs {
                m.write_to(&mut sink).unwrap();
            }
        });
        let bytes = sink.len();
        let dec_ns = time_ns(reps, || {
            let mut ms = MessageStream::new();
            ms.extend(&sink);
            let mut got = 0usize;
            while ms.poll().unwrap().is_some() {
                got += 1;
            }
            assert_eq!(got, msgs.len());
        });
        out.push(transport_entry(model, "monolithic", msgs.len(), bytes, mono_peak, enc_ns, dec_ns));

        // -- streamed: per-layer frames, zero-copy encode
        let mut s_peak = 0usize;
        for m in &msgs {
            s_peak = s_peak.max(streamed_staging_bytes(m)?);
        }
        let frames: usize = msgs.iter().map(streamed_frame_count).sum();
        let s_enc_ns = time_ns(reps, || {
            sink.clear();
            for m in &msgs {
                m.write_streamed(&mut sink).unwrap();
            }
        });
        let s_bytes = sink.len();
        let s_dec_ns = time_ns(reps, || {
            let mut ms = MessageStream::new();
            ms.extend(&sink);
            let mut got = 0usize;
            while ms.poll().unwrap().is_some() {
                got += 1;
            }
            assert_eq!(got, msgs.len());
        });
        out.push(transport_entry(model, "streamed", frames, s_bytes, s_peak, s_enc_ns, s_dec_ns));
    }
    Ok(Json::Arr(out))
}

fn transport_entry(
    model: &str,
    path: &str,
    frames: usize,
    bytes: usize,
    peak_staging: usize,
    enc_ns: f64,
    dec_ns: f64,
) -> Json {
    // bytes / ns == GB/s; x 1000 = MB/s keeps quick-run numbers readable
    let mb = |ns: f64| 1e3 * bytes as f64 / ns.max(1.0);
    Json::obj(vec![
        ("model", Json::str(model)),
        ("path", Json::str(path)),
        ("frames", Json::num(frames as f64)),
        ("bytes", Json::num(bytes as f64)),
        ("peak_staging_bytes", Json::num(peak_staging as f64)),
        ("encode_mb_per_s", Json::num(mb(enc_ns))),
        ("decode_mb_per_s", Json::num(mb(dec_ns))),
        ("encode_frames_per_s", Json::num(1e9 * frames as f64 / enc_ns.max(1.0))),
        ("decode_frames_per_s", Json::num(1e9 * frames as f64 / dec_ns.max(1.0))),
    ])
}

/// Peak resident set size of this process so far (VmHWM from
/// `/proc/self/status`), in bytes.  `None` off Linux.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// The `scale` section: the client-registry roster at coordinator scale.
/// Registers `registered` clients behind a spill-to-disk [`FileStore`],
/// then drives sampling rounds of `sampled` active clients each — every
/// sampled client gets its participation and Eq.9 byte counters written
/// through the store seam, and a slice of them spill SCAFFOLD-style
/// control blobs.  Reports rounds/s plus the process peak RSS against an
/// O(sampled)-shaped bound: a flat harness allowance plus a per-touched-
/// entry budget, never a function of `registered`.  An implementation
/// that materialized the roster would scale RSS with `registered` and
/// blow the bound at the million-client default.
///
/// [`FileStore`]: crate::registry::store::FileStore
fn bench_scale(opts: &BenchOpts) -> Result<Json> {
    use crate::registry::sampler::RegistrySampler;
    use crate::registry::store::FileStore;
    use crate::registry::ClientRegistry;
    use crate::runtime::HostTensor;

    let registered = match opts.registered {
        0 if opts.quick => 10_000,
        0 => 1_000_000,
        n => n,
    };
    let sampled = match opts.sampled {
        0 if opts.quick => 100,
        0 => 1_000,
        k => k,
    };
    anyhow::ensure!(
        (1..=registered).contains(&sampled),
        "bench --scale: --sampled {sampled} outside [1, {registered}] (--registered)"
    );
    let rounds = if opts.quick { 25 } else { 100 };

    let dir = std::env::temp_dir().join(format!("fedlama_scale_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let log = dir.join("registry.log");
    let _ = std::fs::remove_file(&log);
    let store = FileStore::open(&log)?;
    let mut reg = ClientRegistry::new(registered, 1, Box::new(store));
    let mut sampler = RegistrySampler::new(registered, sampled, 1);
    let control = vec![HostTensor { shape: vec![32], data: vec![0.5f32; 32] }];

    let t0 = Instant::now();
    for round in 0..rounds {
        let active = sampler.sample();
        for (slot, &id) in active.iter().enumerate() {
            reg.note_seen(id, round, 64 + id % 512)?;
            reg.note_bytes(id, 1_024, 4_096)?;
            if slot % 64 == 0 {
                reg.put_control(id, &control)?;
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);

    let touched = reg.touched();
    let spilled = reg.spilled_controls();
    let log_bytes = std::fs::metadata(&log).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&log);
    let peak = peak_rss_bytes().unwrap_or(0);
    let bound = (128u64 << 20) + (touched + spilled) as u64 * 512;
    Ok(Json::obj(vec![
        ("registered", Json::num(registered as f64)),
        ("sampled", Json::num(sampled as f64)),
        ("rounds", Json::num(rounds as f64)),
        ("rounds_per_sec", Json::num(rounds as f64 / secs)),
        ("touched_clients", Json::num(touched as f64)),
        ("spilled_controls", Json::num(spilled as f64)),
        ("spill_log_bytes", Json::num(log_bytes as f64)),
        ("peak_rss_bytes", Json::num(peak as f64)),
        ("rss_bound_bytes", Json::num(bound as f64)),
        ("rss_within_bound", Json::Bool(peak > 0 && peak <= bound)),
    ]))
}

fn bench_pool(threads: usize) -> Json {
    // 100 small fan-outs measure per-call dispatch overhead of the
    // persistent pool (the win over per-call thread spawning).
    let calls = 100usize;
    let mut items: Vec<u64> = (0..256).collect();
    let t0 = Instant::now();
    for _ in 0..calls {
        let out = pool::par_map_mut(&mut items, threads, |i, v| {
            *v = v.wrapping_add(i as u64);
            *v
        });
        std::hint::black_box(out.len());
    }
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    Json::obj(vec![
        ("threads", Json::num(threads as f64)),
        ("calls", Json::num(calls as f64)),
        ("ms_per_call", Json::num(total_ms / calls as f64)),
        ("workers_spawned_total", Json::num(pool::workers_spawned_total() as f64)),
        ("pool_size", Json::num(pool::pool_size() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_bench_produces_a_complete_parseable_doc() {
        let doc = run(&BenchOpts { quick: true, threads: 2, ..Default::default() }).unwrap();
        let text = doc.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("measured").unwrap().as_bool(), Some(true));
        let isa = parsed.get("isa").unwrap().as_str().unwrap();
        assert!(["avx2", "sse2", "scalar"].contains(&isa));
        let kernels = parsed.get("kernels").unwrap().as_arr().unwrap();
        // 3 kernels x all shapes, every entry on the active dispatch path
        assert_eq!(kernels.len(), 3 * SHAPES.len());
        for k in kernels {
            assert_eq!(k.get("dispatch").unwrap().as_str(), Some(isa));
            assert!(k.get("ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
            assert!(k.get("speedup_vs_scalar").unwrap().as_f64().unwrap() > 0.0);
        }
        assert!(!parsed.get("ops").unwrap().as_arr().unwrap().is_empty());
        assert!(!parsed.get("end_to_end").unwrap().as_arr().unwrap().is_empty());
        assert!(parsed.get("pool").unwrap().get("ms_per_call").is_some());
        // transport: both models x both wire paths, and the tentpole claim —
        // streamed peak staging is bounded by the largest layer frame, so it
        // must undercut the monolithic peak (the largest whole message)
        let transport = parsed.get("transport").unwrap().as_arr().unwrap();
        assert_eq!(transport.len(), 4);
        for model in ["mlp", "resnet20"] {
            let peak = |path: &str| {
                transport
                    .iter()
                    .find(|e| {
                        e.get("model").unwrap().as_str() == Some(model)
                            && e.get("path").unwrap().as_str() == Some(path)
                    })
                    .unwrap()
                    .get("peak_staging_bytes")
                    .unwrap()
                    .as_f64()
                    .unwrap()
            };
            assert!(
                peak("streamed") < peak("monolithic"),
                "{model}: streamed peak {} !< monolithic peak {}",
                peak("streamed"),
                peak("monolithic")
            );
        }
        for e in transport {
            assert!(e.get("encode_mb_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(e.get("decode_mb_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(e.get("frames").unwrap().as_f64().unwrap() >= 1.0);
        }
        // without --scale the section is absent — the committed artifact
        // only grows it when explicitly requested
        assert!(parsed.get("scale").is_none());
    }

    #[test]
    fn scale_bench_reports_bounded_o_of_sampled_rss() {
        let opts = BenchOpts {
            quick: true,
            threads: 2,
            scale: true,
            registered: 5_000,
            sampled: 64,
        };
        let s = bench_scale(&opts).unwrap();
        let parsed = Json::parse(&s.to_string()).unwrap();
        assert_eq!(parsed.get("registered").unwrap().as_usize(), Some(5_000));
        assert_eq!(parsed.get("sampled").unwrap().as_usize(), Some(64));
        assert!(parsed.get("rounds_per_sec").unwrap().as_f64().unwrap() > 0.0);
        // every round touches 64 clients; across 25 rounds some repeat, so
        // the resident set is bounded by sampled x rounds and well below
        // the registered roster
        let touched = parsed.get("touched_clients").unwrap().as_usize().unwrap();
        assert!(touched >= 64 && touched <= 64 * 25, "touched={touched}");
        assert!(parsed.get("spilled_controls").unwrap().as_usize().unwrap() >= 1);
        assert!(parsed.get("spill_log_bytes").unwrap().as_f64().unwrap() > 0.0);
        // on Linux VmHWM must resolve and sit inside the O(sampled) bound
        if peak_rss_bytes().is_some() {
            assert_eq!(parsed.get("rss_within_bound").unwrap().as_bool(), Some(true));
        }
        // oversampling the roster is refused loudly
        let bad = BenchOpts { scale: true, registered: 10, sampled: 11, ..Default::default() };
        assert!(bench_scale(&bad).is_err());
    }
}
