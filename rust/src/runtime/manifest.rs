//! Artifact manifest: what `python/compile/aot.py` emitted for a model.
//!
//! The manifest is the single source of truth for parameter order, shapes,
//! FedLAMA aggregation units ("groups" = the paper's layers), batch sizes,
//! and which HLO files implement which entry point.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Declaration of one layer-graph op for `Manifest::synthetic_graph`:
/// (group name, [(param suffix, shape)]).
pub type LayerSpec = (String, Vec<(String, Vec<usize>)>);

/// One parameter tensor of the model.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dim: usize,
    pub group: String,
}

/// One aggregation unit (the paper's "layer"): a set of parameter tensors
/// that are always synchronized together.
#[derive(Debug, Clone)]
pub struct GroupInfo {
    pub name: String,
    /// Indices into `Manifest::params`.
    pub params: Vec<usize>,
    /// Total number of scalars in the unit.
    pub dim: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: String,
    pub base: String,
    pub batch_size: usize,
    pub eval_batch_size: usize,
    pub chunk_k: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub num_params: usize,
    pub params: Vec<ParamInfo>,
    pub groups: Vec<GroupInfo>,
    pub entries: BTreeMap<String, String>,
    /// Pallas aggregation kernels: dim -> (m -> file name).
    pub agg_by_dim: BTreeMap<usize, BTreeMap<usize, String>>,
}

impl Manifest {
    pub fn load(model_dir: &Path) -> Result<Manifest> {
        let path = model_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j, model_dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("{k} not a usize"))
        };
        let s = |k: &str| -> Result<String> {
            Ok(j.req(k)?.as_str().ok_or_else(|| anyhow::anyhow!("{k} not a string"))?.to_string())
        };
        let params = j
            .req("params")?
            .as_arr()
            .context("params not an array")?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect(),
                    dim: p.req("dim")?.as_usize().context("dim")?,
                    group: p.req("group")?.as_str().unwrap_or_default().to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let groups = j
            .req("groups")?
            .as_arr()
            .context("groups not an array")?
            .iter()
            .map(|g| {
                Ok(GroupInfo {
                    name: g.req("name")?.as_str().unwrap_or_default().to_string(),
                    params: g
                        .req("params")?
                        .as_arr()
                        .context("group params")?
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect(),
                    dim: g.req("dim")?.as_usize().context("group dim")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let entries = j
            .req("entries")?
            .as_obj()
            .context("entries not an object")?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
            .collect();
        let mut agg_by_dim = BTreeMap::new();
        if let Some(by_dim) = j.req("agg")?.get("by_dim").and_then(|v| v.as_obj()) {
            for (dim, files) in by_dim {
                let dim: usize = dim.parse().context("agg dim key")?;
                let mut by_m = BTreeMap::new();
                for (m, f) in files.as_obj().context("agg files")? {
                    by_m.insert(m.parse::<usize>()?, f.as_str().unwrap_or_default().to_string());
                }
                agg_by_dim.insert(dim, by_m);
            }
        }
        let m = Manifest {
            dir: dir.to_path_buf(),
            model: s("model")?,
            base: s("base")?,
            batch_size: u("batch_size")?,
            eval_batch_size: u("eval_batch_size")?,
            chunk_k: u("chunk_k").unwrap_or(1),
            input_shape: j
                .req("input_shape")?
                .as_arr()
                .context("input_shape")?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            num_classes: u("num_classes")?,
            num_params: u("num_params")?,
            params,
            groups,
            entries,
            agg_by_dim,
        };
        m.validate()?;
        Ok(m)
    }

    /// Synthesize a manifest for a native layer-graph model: one
    /// aggregation group per parameterized op, params named
    /// `{group}.{suffix}`, in op order.  Ops without parameters contribute
    /// nothing.  No artifact directory, no entry points.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic_graph(
        model: &str,
        base: &str,
        input_shape: &[usize],
        num_classes: usize,
        batch_size: usize,
        eval_batch_size: usize,
        chunk_k: usize,
        layers: &[LayerSpec],
    ) -> Result<Manifest> {
        let mut params = Vec::new();
        let mut groups = Vec::new();
        for (group, specs) in layers {
            if specs.is_empty() {
                continue;
            }
            let first = params.len();
            let mut gdim = 0;
            for (suffix, shape) in specs {
                let dim: usize = shape.iter().product();
                params.push(ParamInfo {
                    name: format!("{group}.{suffix}"),
                    shape: shape.clone(),
                    dim,
                    group: group.clone(),
                });
                gdim += dim;
            }
            groups.push(GroupInfo {
                name: group.clone(),
                params: (first..params.len()).collect(),
                dim: gdim,
            });
        }
        let num_params = params.iter().map(|p| p.dim).sum();
        let m = Manifest {
            dir: PathBuf::new(),
            model: model.to_string(),
            base: base.to_string(),
            batch_size,
            eval_batch_size,
            chunk_k,
            input_shape: input_shape.to_vec(),
            num_classes,
            num_params,
            params,
            groups,
            entries: BTreeMap::new(),
            agg_by_dim: BTreeMap::new(),
        };
        m.validate()?;
        Ok(m)
    }

    /// The historical MLP manifest layout, mirroring
    /// `python/compile/model.py::make_mlp` (one `fc{i}` aggregation group
    /// per layer, each holding its weight + bias).  The live native MLP
    /// manifest now comes from `ModelGraph::from_ops` via `runtime::zoo`;
    /// this constructor survives as the layout reference the zoo's MLP is
    /// pinned against (`zoo::tests::mlp_manifest_matches_synthetic_mlp`).
    pub fn synthetic_mlp(
        input_shape: &[usize],
        hidden: &[usize],
        num_classes: usize,
        batch_size: usize,
        eval_batch_size: usize,
        chunk_k: usize,
    ) -> Manifest {
        let input_dim: usize = input_shape.iter().product();
        let mut dims = vec![input_dim];
        dims.extend_from_slice(hidden);
        dims.push(num_classes);
        let layers: Vec<LayerSpec> = (0..dims.len() - 1)
            .map(|l| {
                (
                    format!("fc{}", l + 1),
                    vec![
                        ("w".to_string(), vec![dims[l], dims[l + 1]]),
                        ("b".to_string(), vec![dims[l + 1]]),
                    ],
                )
            })
            .collect();
        Self::synthetic_graph(
            "native-mlp",
            "mlp",
            input_shape,
            num_classes,
            batch_size,
            eval_batch_size,
            chunk_k,
            &layers,
        )
        .expect("the MLP manifest is always well-formed")
    }

    /// Internal consistency: group dims match member params, indices valid.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.params.is_empty(), "no params");
        anyhow::ensure!(!self.groups.is_empty(), "no groups");
        for p in &self.params {
            let prod: usize = p.shape.iter().product();
            anyhow::ensure!(prod == p.dim, "param {} dim {} != shape product {prod}", p.name, p.dim);
        }
        let mut seen = vec![false; self.params.len()];
        for g in &self.groups {
            let mut dim = 0;
            for &i in &g.params {
                anyhow::ensure!(i < self.params.len(), "group {} bad index {i}", g.name);
                anyhow::ensure!(!seen[i], "param {i} in two groups");
                seen[i] = true;
                dim += self.params[i].dim;
            }
            anyhow::ensure!(dim == g.dim, "group {} dim mismatch", g.name);
        }
        anyhow::ensure!(seen.iter().all(|&b| b), "some params not in any group");
        let total: usize = self.params.iter().map(|p| p.dim).sum();
        anyhow::ensure!(total == self.num_params, "num_params mismatch");
        Ok(())
    }

    pub fn entry_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no entry {name:?} in manifest for {}", self.model))?;
        Ok(self.dir.join(f))
    }

    /// Path of the Pallas aggregation kernel for (group dim, m active rows),
    /// if one was AOT-compiled.
    pub fn agg_path(&self, dim: usize, m: usize) -> Option<PathBuf> {
        self.agg_by_dim.get(&dim).and_then(|by_m| by_m.get(&m)).map(|f| self.dir.join(f))
    }

    pub fn num_tensors(&self) -> usize {
        self.params.len()
    }

    /// Largest group dim (used for scratch preallocation).
    pub fn max_group_dim(&self) -> usize {
        self.groups.iter().map(|g| g.dim).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_json() -> Json {
        Json::parse(
            r#"{
              "model": "toy", "base": "mlp", "batch_size": 4, "eval_batch_size": 8,
              "chunk_k": 2,
              "input_shape": [3], "num_classes": 2, "num_param_tensors": 2,
              "num_params": 8,
              "params": [
                {"name": "fc.w", "shape": [3, 2], "dim": 6, "group": "fc"},
                {"name": "fc.b", "shape": [2], "dim": 2, "group": "fc"}
              ],
              "groups": [{"name": "fc", "params": [0, 1], "dim": 8}],
              "entries": {"init": "init.hlo.txt"},
              "agg": {"m_values": [4], "by_dim": {"8": {"4": "agg_d8_m4.hlo.txt"}}}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_validates() {
        let m = Manifest::from_json(&toy_json(), Path::new("/tmp/x")).unwrap();
        assert_eq!(m.model, "toy");
        assert_eq!(m.num_tensors(), 2);
        assert_eq!(m.groups[0].dim, 8);
        assert_eq!(m.chunk_k, 2);
        assert_eq!(m.agg_path(8, 4).unwrap(), Path::new("/tmp/x/agg_d8_m4.hlo.txt"));
        assert!(m.agg_path(8, 5).is_none());
        assert!(m.agg_path(9, 4).is_none());
        assert_eq!(m.entry_path("init").unwrap(), Path::new("/tmp/x/init.hlo.txt"));
        assert!(m.entry_path("nope").is_err());
        assert_eq!(m.max_group_dim(), 8);
    }

    #[test]
    fn synthetic_mlp_validates_and_matches_make_mlp_layout() {
        let m = Manifest::synthetic_mlp(&[64], &[128, 64], 10, 16, 64, 4);
        m.validate().unwrap();
        assert_eq!(m.model, "native-mlp");
        assert_eq!(m.num_tensors(), 6);
        assert_eq!(m.groups.len(), 3);
        assert_eq!(m.groups[0].dim, 64 * 128 + 128);
        assert_eq!(m.groups[2].dim, 64 * 10 + 10);
        assert_eq!(m.num_params, 64 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10);
        assert_eq!(m.params[0].name, "fc1.w");
        assert_eq!(m.params[5].name, "fc3.b");
        assert_eq!(m.chunk_k, 4);
        assert!(m.entries.is_empty());
        assert!(m.agg_path(m.groups[0].dim, 4).is_none());
        // multi-axis input shapes flatten into the first weight
        let m = Manifest::synthetic_mlp(&[32, 32, 3], &[128], 10, 8, 32, 1);
        assert_eq!(m.params[0].shape, vec![3072, 128]);
        assert_eq!(m.input_shape, vec![32, 32, 3]);
    }

    #[test]
    fn rejects_dim_mismatch() {
        let mut j = toy_json();
        if let Json::Obj(pairs) = &mut j {
            for (k, v) in pairs.iter_mut() {
                if k == "num_params" {
                    *v = Json::Num(9.0);
                }
            }
        }
        assert!(Manifest::from_json(&j, Path::new("/tmp/x")).is_err());
    }
}
