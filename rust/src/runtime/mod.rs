//! Model execution: the `ComputeBackend` seam, the layer-graph native
//! backend (`ops` + `graph` + the `zoo` model registry), the parallel
//! client cluster, and (behind `--features pjrt`) the PJRT engine for AOT
//! HLO artifacts.
//!
//! See rust/DESIGN.md for the execution paths and the threading model.

pub mod backend;
pub mod cluster;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod graph;
pub mod manifest;
pub mod native;
pub mod ops;
pub mod simd;
pub mod tensor;
pub mod zoo;

pub use backend::{ComputeBackend, RuntimeStats};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable, ModelRuntime};
pub use graph::ModelGraph;
pub use manifest::{GroupInfo, Manifest, ParamInfo};
pub use native::NativeBackend;
pub use ops::LayerOp;
pub use tensor::HostTensor;
