//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! See /opt/xla-example/load_hlo for the reference wiring and DESIGN.md §5
//! for the interchange format.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Engine, Executable, ModelRuntime, RuntimeStats};
pub use manifest::{GroupInfo, Manifest, ParamInfo};
pub use tensor::HostTensor;
