//! Model execution: the `ComputeBackend` seam, the hermetic native MLP
//! backend, the parallel client cluster, and (behind `--features pjrt`)
//! the PJRT engine for AOT HLO artifacts.
//!
//! See rust/DESIGN.md for the two execution paths and the threading model.

pub mod backend;
pub mod cluster;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod native;
pub mod tensor;

pub use backend::{ComputeBackend, RuntimeStats};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable, ModelRuntime};
pub use manifest::{GroupInfo, Manifest, ParamInfo};
pub use native::NativeBackend;
pub use tensor::HostTensor;
