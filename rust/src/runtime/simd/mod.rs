//! Portable wide-lane f32 kernels behind a single dispatch point.
//!
//! The matmul hot path (`runtime::ops::matmul`) is built from two lane
//! primitives — `axpy` (dst += a·src, vectorized across the output-column
//! dimension) and `dot_panel` (a column-panel dot whose lanes each own one
//! output element) — implemented three times:
//!
//!   AVX2 (8 lanes) → SSE2 (4 lanes) → unrolled scalar (always available)
//!
//! and selected once per process by runtime feature detection
//! (`active_isa`), so one binary runs the widest path the machine
//! supports.  `FEDLAMA_SIMD=scalar|sse2|avx2` forces a (supported)
//! narrower path — useful for A/B benchmarks and CI.
//!
//! **Numerics contract** (what keeps `threads = N` and every transport
//! bit-identical on the SIMD path): each output element is produced by the
//! same sequence of IEEE-754 f32 operations in the same order on every
//! path — one multiply + one add per accumulation step, never an FMA, with
//! lanes only ever spanning *independent* output elements.  The wide
//! kernels are therefore bit-identical to the scalar ones, which are in
//! turn the historical kernels restructured.  See rust/DESIGN.md
//! ("Performance") and the oracle tests in `tests/simd_kernels.rs`.

pub mod scalar;
#[cfg(target_arch = "x86_64")]
pub mod x86;

use std::sync::atomic::{AtomicU8, Ordering};

/// The instruction-set ladder. Ordering is "wider first".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// 8 f32 lanes (x86-64 AVX2).
    Avx2,
    /// 4 f32 lanes (x86-64 SSE2 — baseline on every x86-64).
    Sse2,
    /// 1 "lane": the unrolled scalar fallback, available everywhere.
    Scalar,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Sse2 => "sse2",
            Isa::Scalar => "scalar",
        }
    }

    /// f32 elements per vector register on this path.
    pub fn lane_width(self) -> usize {
        match self {
            Isa::Avx2 => 8,
            Isa::Sse2 => 4,
            Isa::Scalar => 1,
        }
    }

    fn code(self) -> u8 {
        match self {
            Isa::Scalar => 1,
            Isa::Sse2 => 2,
            Isa::Avx2 => 3,
        }
    }
}

/// Cached dispatch decision: 0 = undecided, otherwise `Isa::code`.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// The widest path the running CPU supports.
pub fn best_supported() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
        if std::is_x86_feature_detected!("sse2") {
            return Isa::Sse2;
        }
    }
    Isa::Scalar
}

/// Every path the running CPU supports (scalar first, then widening) —
/// the iteration set for bit-identity tests and A/B benches.
pub fn supported_isas() -> Vec<Isa> {
    let mut out = vec![Isa::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("sse2") {
            out.push(Isa::Sse2);
        }
        if std::is_x86_feature_detected!("avx2") {
            out.push(Isa::Avx2);
        }
    }
    out
}

fn detect() -> Isa {
    let best = best_supported();
    // Env override can only *narrow* the dispatch: an unsupported or
    // unknown request silently falls back to the detected best, so a
    // stale FEDLAMA_SIMD can never select an illegal instruction.
    match std::env::var("FEDLAMA_SIMD").ok().as_deref() {
        Some("scalar") => Isa::Scalar,
        Some("sse2") if best != Isa::Scalar => Isa::Sse2,
        Some("avx2") if best == Isa::Avx2 => Isa::Avx2,
        _ => best,
    }
}

/// The process-wide dispatch decision (detected once, then cached).
pub fn active_isa() -> Isa {
    match ACTIVE.load(Ordering::Relaxed) {
        1 => Isa::Scalar,
        2 => Isa::Sse2,
        3 => Isa::Avx2,
        _ => {
            let isa = detect();
            ACTIVE.store(isa.code(), Ordering::Relaxed);
            isa
        }
    }
}

/// `dst[j] += a * src[j]` on the given path.  Lanes span independent
/// elements j, so every path is bit-identical.
pub fn axpy(isa: Isa, dst: &mut [f32], a: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match isa {
        // SAFETY: Isa::Avx2 / Isa::Sse2 are only produced by runtime
        // feature detection (or by tests iterating `supported_isas`).
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::axpy_avx2(dst, a, src) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::axpy_sse2(dst, a, src) },
        _ => scalar::axpy(dst, a, src),
    }
}

/// `dst[i] = src[i].abs() / div * mul` on the given path — the
/// quantizer's forward map.  Lanes span independent elements and the
/// per-element op sequence (sign-bit clear, one divide, one multiply) is
/// identical everywhere, so every path is bit-identical.
pub fn abs_div_mul(isa: Isa, dst: &mut [f32], src: &[f32], div: f32, mul: f32) {
    debug_assert_eq!(dst.len(), src.len());
    match isa {
        // SAFETY: detection-gated as in `axpy`.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::abs_div_mul_avx2(dst, src, div, mul) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::abs_div_mul_sse2(dst, src, div, mul) },
        _ => scalar::abs_div_mul(dst, src, div, mul),
    }
}

/// `dst[i] = dst[i] / div * mul` in place on the given path — the
/// (de)quantizer's scale map.  Same bit-identity argument as
/// [`abs_div_mul`].
pub fn div_mul(isa: Isa, dst: &mut [f32], div: f32, mul: f32) {
    match isa {
        // SAFETY: detection-gated as in `axpy`.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::div_mul_avx2(dst, div, mul) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::div_mul_sse2(dst, div, mul) },
        _ => scalar::div_mul(dst, div, mul),
    }
}

/// Panel dot on the given path: `out[t] = Σ_j dy[j] * packed[j*w + t]`
/// with `w = out.len() = isa.lane_width()`.  Each lane element reduces
/// over j in increasing order (mul + add, no FMA), so lane t is bitwise
/// the scalar dot of `dy` with packed column t.
pub fn dot_panel(isa: Isa, dy: &[f32], packed: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), isa.lane_width());
    debug_assert_eq!(packed.len(), dy.len() * isa.lane_width());
    match isa {
        // SAFETY: detection-gated as in `axpy`.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot_panel8_avx2(dy, packed, out) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::dot_panel4_sse2(dy, packed, out) },
        _ => scalar::dot_panel(dy, packed, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn ladder_metadata() {
        assert_eq!(Isa::Avx2.lane_width(), 8);
        assert_eq!(Isa::Sse2.lane_width(), 4);
        assert_eq!(Isa::Scalar.lane_width(), 1);
        assert_eq!(Isa::Scalar.name(), "scalar");
        let isas = supported_isas();
        assert_eq!(isas[0], Isa::Scalar);
        assert!(isas.contains(&active_isa()));
        // the cached decision is stable
        assert_eq!(active_isa(), active_isa());
    }

    #[test]
    fn axpy_paths_are_bit_identical_across_remainders() {
        let mut rng = Rng::new(9);
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 100] {
            let src = randv(&mut rng, n);
            let base = randv(&mut rng, n);
            let mut want = base.clone();
            scalar::axpy(&mut want, -0.75, &src);
            for isa in supported_isas() {
                let mut got = base.clone();
                axpy(isa, &mut got, -0.75, &src);
                assert_eq!(got, want, "axpy diverged on {} at n={n}", isa.name());
            }
        }
    }

    #[test]
    fn dot_panel_paths_match_scalar_oracle() {
        let mut rng = Rng::new(10);
        for n in [0usize, 1, 2, 7, 8, 63, 64, 65] {
            let dy = randv(&mut rng, n);
            for isa in supported_isas() {
                let w = isa.lane_width();
                let packed = randv(&mut rng, n * w);
                let mut want = vec![0.0f32; w];
                scalar::dot_panel(&dy, &packed, &mut want);
                let mut got = vec![7.0f32; w]; // stale values must be overwritten
                dot_panel(isa, &dy, &packed, &mut got);
                assert_eq!(got, want, "dot_panel diverged on {} at n={n}", isa.name());
            }
        }
    }
}
