//! x86-64 wide-lane kernels (AVX2: 8 f32 lanes, SSE2: 4 f32 lanes).
//!
//! Numerics contract: every kernel uses **separate** vector multiply and
//! add instructions (`mulps`/`addps` families, never FMA), so each lane
//! element sees exactly the IEEE-754 f32 mul + add sequence of the scalar
//! reference in `super::scalar` — the wide paths are bit-identical to the
//! scalar ones on every input, not approximately equal.  Rust never
//! contracts scalar `a * b + c` into an FMA either, so the contract holds
//! in both directions.
//!
//! All loads/stores are unaligned (`loadu`/`storeu`): callers pass
//! arbitrary `Vec<f32>` slices.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

/// `dst[j] += a * src[j]` — AVX2 (8 lanes), scalar tail for `len % 8`.
///
/// # Safety
/// The caller must have verified that the running CPU supports AVX2
/// (`Isa::Avx2` is only ever produced by runtime feature detection).
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_avx2(dst: &mut [f32], a: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let va = _mm256_set1_ps(a);
    let mut j = 0;
    while j + 8 <= n {
        let s = _mm256_loadu_ps(src.as_ptr().add(j));
        let d = _mm256_loadu_ps(dst.as_ptr().add(j));
        _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_add_ps(d, _mm256_mul_ps(va, s)));
        j += 8;
    }
    while j < n {
        dst[j] += a * src[j];
        j += 1;
    }
}

/// `dst[j] += a * src[j]` — SSE2 (4 lanes), scalar tail for `len % 4`.
///
/// # Safety
/// The caller must have verified that the running CPU supports SSE2
/// (always true on x86-64, but `Isa::Sse2` is still detection-gated).
#[target_feature(enable = "sse2")]
pub unsafe fn axpy_sse2(dst: &mut [f32], a: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let va = _mm_set1_ps(a);
    let mut j = 0;
    while j + 4 <= n {
        let s = _mm_loadu_ps(src.as_ptr().add(j));
        let d = _mm_loadu_ps(dst.as_ptr().add(j));
        _mm_storeu_ps(dst.as_mut_ptr().add(j), _mm_add_ps(d, _mm_mul_ps(va, s)));
        j += 4;
    }
    while j < n {
        dst[j] += a * src[j];
        j += 1;
    }
}

/// `dst[i] = src[i].abs() / div * mul` — AVX2, scalar tail for `len % 8`.
/// `abs` is a sign-bit mask; `divps`/`mulps` are correctly-rounded IEEE
/// ops, so the result is bit-identical to `scalar::abs_div_mul`.
///
/// # Safety
/// Requires AVX2 (detection-gated, as in `axpy_avx2`).
#[target_feature(enable = "avx2")]
pub unsafe fn abs_div_mul_avx2(dst: &mut [f32], src: &[f32], div: f32, mul: f32) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
    let vd = _mm256_set1_ps(div);
    let vm = _mm256_set1_ps(mul);
    let mut j = 0;
    while j + 8 <= n {
        let s = _mm256_loadu_ps(src.as_ptr().add(j));
        let t = _mm256_mul_ps(_mm256_div_ps(_mm256_and_ps(s, mask), vd), vm);
        _mm256_storeu_ps(dst.as_mut_ptr().add(j), t);
        j += 8;
    }
    while j < n {
        dst[j] = src[j].abs() / div * mul;
        j += 1;
    }
}

/// `dst[i] = src[i].abs() / div * mul` — SSE2, scalar tail for `len % 4`.
///
/// # Safety
/// Requires SSE2 (detection-gated, as in `axpy_sse2`).
#[target_feature(enable = "sse2")]
pub unsafe fn abs_div_mul_sse2(dst: &mut [f32], src: &[f32], div: f32, mul: f32) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let mask = _mm_castsi128_ps(_mm_set1_epi32(0x7FFF_FFFF));
    let vd = _mm_set1_ps(div);
    let vm = _mm_set1_ps(mul);
    let mut j = 0;
    while j + 4 <= n {
        let s = _mm_loadu_ps(src.as_ptr().add(j));
        let t = _mm_mul_ps(_mm_div_ps(_mm_and_ps(s, mask), vd), vm);
        _mm_storeu_ps(dst.as_mut_ptr().add(j), t);
        j += 4;
    }
    while j < n {
        dst[j] = src[j].abs() / div * mul;
        j += 1;
    }
}

/// `dst[i] = dst[i] / div * mul` in place — AVX2, scalar tail.
///
/// # Safety
/// Requires AVX2 (detection-gated).
#[target_feature(enable = "avx2")]
pub unsafe fn div_mul_avx2(dst: &mut [f32], div: f32, mul: f32) {
    let n = dst.len();
    let vd = _mm256_set1_ps(div);
    let vm = _mm256_set1_ps(mul);
    let mut j = 0;
    while j + 8 <= n {
        let d = _mm256_loadu_ps(dst.as_ptr().add(j));
        _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_mul_ps(_mm256_div_ps(d, vd), vm));
        j += 8;
    }
    while j < n {
        dst[j] = dst[j] / div * mul;
        j += 1;
    }
}

/// `dst[i] = dst[i] / div * mul` in place — SSE2, scalar tail.
///
/// # Safety
/// Requires SSE2 (detection-gated).
#[target_feature(enable = "sse2")]
pub unsafe fn div_mul_sse2(dst: &mut [f32], div: f32, mul: f32) {
    let n = dst.len();
    let vd = _mm_set1_ps(div);
    let vm = _mm_set1_ps(mul);
    let mut j = 0;
    while j + 4 <= n {
        let d = _mm_loadu_ps(dst.as_ptr().add(j));
        _mm_storeu_ps(dst.as_mut_ptr().add(j), _mm_mul_ps(_mm_div_ps(d, vd), vm));
        j += 4;
    }
    while j < n {
        dst[j] = dst[j] / div * mul;
        j += 1;
    }
}

/// 8-lane panel dot: `out[t] = Σ_j dy[j] * packed[j * 8 + t]`, each lane
/// element accumulated in increasing j order with mul + add (no FMA) —
/// bit-identical to `scalar::dot_panel` with `w = 8`.
///
/// # Safety
/// Requires AVX2 (detection-gated); `out.len() == 8` and
/// `packed.len() == dy.len() * 8` (debug-asserted).
#[target_feature(enable = "avx2")]
pub unsafe fn dot_panel8_avx2(dy: &[f32], packed: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), 8);
    debug_assert_eq!(packed.len(), dy.len() * 8);
    let mut acc = _mm256_setzero_ps();
    for (j, &d) in dy.iter().enumerate() {
        let row = _mm256_loadu_ps(packed.as_ptr().add(j * 8));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(d), row));
    }
    _mm256_storeu_ps(out.as_mut_ptr(), acc);
}

/// 4-lane panel dot, the SSE2 counterpart of `dot_panel8_avx2`.
///
/// # Safety
/// Requires SSE2 (detection-gated); `out.len() == 4` and
/// `packed.len() == dy.len() * 4` (debug-asserted).
#[target_feature(enable = "sse2")]
pub unsafe fn dot_panel4_sse2(dy: &[f32], packed: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), 4);
    debug_assert_eq!(packed.len(), dy.len() * 4);
    let mut acc = _mm_setzero_ps();
    for (j, &d) in dy.iter().enumerate() {
        let row = _mm_loadu_ps(packed.as_ptr().add(j * 4));
        acc = _mm_add_ps(acc, _mm_mul_ps(_mm_set1_ps(d), row));
    }
    _mm_storeu_ps(out.as_mut_ptr(), acc);
}
