//! Always-available scalar lane kernels (unrolled).
//!
//! These are the reference implementations the wide-lane paths must match
//! **bitwise**: every element is produced by exactly one IEEE-754 f32
//! multiply followed by one add (never a fused multiply-add), in the same
//! order as the historical kernels.  The 4x unroll only restructures the
//! loop — element j is still `dst[j] + a * src[j]`, so unrolling cannot
//! change a single bit.

/// `dst[j] += a * src[j]` for every j (one mul + one add per element).
pub fn axpy(dst: &mut [f32], a: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let n4 = n - n % 4;
    let mut j = 0;
    while j < n4 {
        dst[j] += a * src[j];
        dst[j + 1] += a * src[j + 1];
        dst[j + 2] += a * src[j + 2];
        dst[j + 3] += a * src[j + 3];
        j += 4;
    }
    while j < n {
        dst[j] += a * src[j];
        j += 1;
    }
}

/// `dst[i] = src[i].abs() / div * mul` — the quantizer's forward map
/// (|v| / chunk_max * levels).  `abs` clears the sign bit; the divide and
/// multiply are single correctly-rounded IEEE-754 ops, so every dispatch
/// path produces identical bits.
pub fn abs_div_mul(dst: &mut [f32], src: &[f32], div: f32, mul: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.abs() / div * mul;
    }
}

/// `dst[i] = dst[i] / div * mul` in place — the (de)quantizer's scale map
/// (level / levels * chunk_max).  Same bit-identity argument as
/// [`abs_div_mul`].
pub fn div_mul(dst: &mut [f32], div: f32, mul: f32) {
    for d in dst.iter_mut() {
        *d = *d / div * mul;
    }
}

/// Plain dot product, accumulated in increasing index order.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Generic-width panel dot: `out[t] = Σ_j dy[j] * packed[j * w + t]` with
/// `w = out.len()`, each lane element accumulated in increasing j order.
/// This is the oracle the fixed-width SIMD panel kernels are tested
/// against.
pub fn dot_panel(dy: &[f32], packed: &[f32], out: &mut [f32]) {
    let w = out.len();
    debug_assert_eq!(packed.len(), dy.len() * w);
    out.fill(0.0);
    for (j, &d) in dy.iter().enumerate() {
        let row = &packed[j * w..(j + 1) * w];
        for (o, &p) in out.iter_mut().zip(row) {
            *o += d * p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_plain_loop_on_all_remainders() {
        for n in 0..17 {
            let src: Vec<f32> = (0..n).map(|i| 0.25 * i as f32 - 1.0).collect();
            let mut d1: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mut d2 = d1.clone();
            axpy(&mut d1, 1.5, &src);
            for (d, &s) in d2.iter_mut().zip(&src) {
                *d += 1.5 * s;
            }
            assert_eq!(d1, d2, "n={n}");
        }
    }

    #[test]
    fn dot_and_panel_agree() {
        let dy = [1.0f32, -2.0, 0.5, 3.0];
        let b = [2.0f32, 0.25, -1.0, 4.0];
        // w = 1 panel is exactly the dot product
        let packed: Vec<f32> = b.to_vec();
        let mut out = [0.0f32];
        dot_panel(&dy, &packed, &mut out);
        assert_eq!(out[0], dot(&dy, &b));
        // empty reduction is 0.0 and still fully writes out
        let mut out = [7.0f32, 7.0];
        dot_panel(&[], &[], &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }
}
