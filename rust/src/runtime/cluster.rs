//! Parallel client fan-out: the cluster runtime promised by the engine
//! docs.
//!
//! A training block ("gap" local iterations between sync points) is
//! embarrassingly parallel across the active clients: each client owns its
//! parameters and its private data-sampling RNG stream, and only reads the
//! shared backend / generator / partition state.  `advance_parallel` fans
//! the active set across `util::pool::par_map_mut`, which since the
//! persistent-pool rewrite reuses long-lived parked workers instead of
//! spawning threads per block; because chunking stays static, every
//! per-client computation is self-contained, and f32 accumulation order
//! inside a client never changes, `threads = N` is **bit-identical** to
//! `threads = 1` (asserted by `tests/determinism.rs`).
//!
//! The PJRT engine is `Rc`-based and `!Sync`, so it cannot take this path;
//! the coordinator falls back to `advance_serial` whenever
//! `ComputeBackend::as_parallel` returns `None`.

use anyhow::{Context, Result};

use super::backend::ComputeBackend;
use super::tensor::HostTensor;
use crate::clients::ClientState;
use crate::config::Algorithm;
use crate::data::{ClientData, Generator};
use crate::util::pool;

/// Shared, read-only context for one local-training block.
pub struct StepCtx<'a> {
    pub gen: &'a Generator,
    /// Per active client: its local data distribution (parallel to the
    /// `clients` slice passed to the advance functions).
    pub parts: &'a [&'a ClientData],
    pub algorithm: Algorithm,
    /// SCAFFOLD server control variate c (read-only during the block).
    pub server_control: Option<&'a [HostTensor]>,
    /// Local iterations to advance each client.
    pub gap: usize,
    pub lr: f32,
    pub use_chunk: bool,
}

/// Dispatch a block to the right execution path: parallel fan-out when the
/// backend is `Sync` and more than one thread is requested, serial
/// otherwise.  Results are bit-identical either way.
pub fn advance(
    backend: &dyn ComputeBackend,
    ctx: &StepCtx<'_>,
    clients: &mut [ClientState],
    threads: usize,
) -> Result<Vec<f64>> {
    match backend.as_parallel() {
        Some(par) if threads > 1 => advance_parallel(par, ctx, clients, threads),
        _ => advance_serial(backend, ctx, clients),
    }
}

/// Advance every client on the coordinator thread, in order.
pub fn advance_serial(
    backend: &dyn ComputeBackend,
    ctx: &StepCtx<'_>,
    clients: &mut [ClientState],
) -> Result<Vec<f64>> {
    clients
        .iter_mut()
        .enumerate()
        .map(|(i, c)| advance_one(backend, ctx, i, c))
        .collect()
}

/// Fan the active clients across `threads` workers.  Output order (and
/// every client's final state) is identical to `advance_serial`.
pub fn advance_parallel(
    backend: &(dyn ComputeBackend + Sync),
    ctx: &StepCtx<'_>,
    clients: &mut [ClientState],
    threads: usize,
) -> Result<Vec<f64>> {
    let results =
        pool::par_map_mut(clients, threads, |i, c| advance_one(backend, ctx, i, c));
    results.into_iter().collect()
}

/// Advance one client by `ctx.gap` local steps; returns the mean loss
/// (NaN when the client's heterogeneous budget is already exhausted).
fn advance_one(
    backend: &dyn ComputeBackend,
    ctx: &StepCtx<'_>,
    idx: usize,
    client: &mut ClientState,
) -> Result<f64> {
    let b = backend.manifest().batch_size;
    let d: usize = backend.manifest().input_shape.iter().product();
    let chunk_k = backend.chunk_k();
    let budget = client.local_budget;
    let mut remaining = ctx.gap.min(budget.saturating_sub(client.steps_in_round));
    if remaining == 0 {
        return Ok(f64::NAN);
    }
    let data = ctx.parts[idx];
    let mut xbuf: Vec<f32> = Vec::new();
    let mut ybuf: Vec<i32> = Vec::new();
    let mut loss_sum = 0.0f64;
    let mut loss_n = 0usize;
    let use_chunk = ctx.use_chunk && ctx.algorithm == Algorithm::Sgd && chunk_k > 1;
    while remaining > 0 {
        if use_chunk && remaining >= chunk_k {
            fill_batch(ctx.gen, data, client, chunk_k * b, d, &mut xbuf, &mut ybuf);
            let losses = backend.train_chunk(&mut client.params, &xbuf, &ybuf, ctx.lr)?;
            loss_sum += losses.iter().map(|&v| v as f64).sum::<f64>();
            loss_n += losses.len();
            client.steps_in_round += chunk_k;
            remaining -= chunk_k;
        } else {
            fill_batch(ctx.gen, data, client, b, d, &mut xbuf, &mut ybuf);
            let loss = match ctx.algorithm {
                Algorithm::Sgd | Algorithm::Nova => {
                    backend.train_step(&mut client.params, &xbuf, &ybuf, ctx.lr)?
                }
                Algorithm::Prox { mu } => {
                    let reference = client
                        .round_start
                        .take()
                        .context("FedProx requires round_start snapshot")?;
                    let r = backend.train_step_prox(
                        &mut client.params,
                        &reference,
                        &xbuf,
                        &ybuf,
                        ctx.lr,
                        mu,
                    );
                    client.round_start = Some(reference);
                    r?
                }
                Algorithm::Scaffold => {
                    let control = client.control.take().context("SCAFFOLD control missing")?;
                    let server = ctx.server_control.context("server control missing")?;
                    let r = backend.train_step_scaffold(
                        &mut client.params,
                        &control,
                        server,
                        &xbuf,
                        &ybuf,
                        ctx.lr,
                    );
                    client.control = Some(control);
                    r?
                }
            };
            loss_sum += loss as f64;
            loss_n += 1;
            client.steps_in_round += 1;
            remaining -= 1;
        }
    }
    Ok(loss_sum / loss_n.max(1) as f64)
}

/// Fill `n` examples from the client's local distribution into the batch
/// buffers (deterministic per-client stream, identical to the historical
/// serial coordinator path).
fn fill_batch(
    gen: &Generator,
    data: &ClientData,
    client: &mut ClientState,
    n: usize,
    d: usize,
    xs: &mut Vec<f32>,
    ys: &mut Vec<i32>,
) {
    xs.resize(n * d, 0.0);
    ys.resize(n, 0);
    for i in 0..n {
        let class = data.sample_class(&mut client.rng);
        let writer = data.sample_writer(&mut client.rng);
        ys[i] = class as i32;
        gen.gen_example(class, writer, &mut client.rng, &mut xs[i * d..(i + 1) * d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{iid_partition, DatasetKind};
    use crate::runtime::NativeBackend;

    fn fleet(backend: &NativeBackend, n: usize, seed: u64) -> Vec<ClientState> {
        let global = backend.init_params(seed as u32).unwrap();
        (0..n).map(|i| ClientState::new(i, global.clone(), seed)).collect()
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let backend = NativeBackend::for_dataset(DatasetKind::Toy);
        let part = iid_partition(6, 10, 128);
        let parts: Vec<&ClientData> = part.clients.iter().collect();
        let gen = Generator::new(DatasetKind::Toy, 3);
        let ctx = StepCtx {
            gen: &gen,
            parts: &parts,
            algorithm: Algorithm::Sgd,
            server_control: None,
            gap: 6,
            lr: 0.05,
            use_chunk: true,
        };
        let mut serial = fleet(&backend, 6, 11);
        let l1 = advance_serial(&backend, &ctx, &mut serial).unwrap();
        for threads in [2, 4, 8] {
            let mut par = fleet(&backend, 6, 11);
            let l2 = advance_parallel(&backend, &ctx, &mut par, threads).unwrap();
            assert_eq!(l1, l2, "losses diverged at threads={threads}");
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.steps_in_round, b.steps_in_round);
                for (ta, tb) in a.params.iter().zip(&b.params) {
                    assert_eq!(ta.data, tb.data, "params diverged at threads={threads}");
                }
            }
        }
    }

    #[test]
    fn budget_exhausted_client_reports_nan() {
        let backend = NativeBackend::for_dataset(DatasetKind::Toy);
        let part = iid_partition(1, 10, 64);
        let parts: Vec<&ClientData> = part.clients.iter().collect();
        let gen = Generator::new(DatasetKind::Toy, 1);
        let ctx = StepCtx {
            gen: &gen,
            parts: &parts,
            algorithm: Algorithm::Sgd,
            server_control: None,
            gap: 4,
            lr: 0.05,
            use_chunk: false,
        };
        let mut clients = fleet(&backend, 1, 2);
        clients[0].local_budget = 0;
        let losses = advance_serial(&backend, &ctx, &mut clients).unwrap();
        assert!(losses[0].is_nan());
        assert_eq!(clients[0].steps_in_round, 0);
    }
}
