//! The blocked matmul kernels shared by `Dense` and `Conv2d` (im2col).
//!
//! All three kernels fix the f32 accumulation order per output element —
//! `matmul_acc` tiles the k dimension for cache locality, but within one
//! output element the additions still happen in strictly increasing k
//! order, so tiling is bit-identical to the untiled triple loop.  Zero
//! multiplicands are skipped where that is value-preserving (x + 0·w = x),
//! which turns post-ReLU sparsity into real savings.

/// k-dimension tile: big enough to amortize loop overhead, small enough
/// that the touched B rows stay cache-resident between row passes.
const KC: usize = 256;

/// `c[m,n] += a[m,k] · b[k,n]` (all row-major).
pub fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k1];
            let crow = &mut c[i * n..(i + 1) * n];
            for (dk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[(k0 + dk) * n..(k0 + dk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }
}

/// `gw[k,n] += aᵀ · dy` for `a[m,k]`, `dy[m,n]` — the weight-gradient
/// kernel.  Per gw element the accumulation runs over m in increasing
/// order.
pub fn matmul_at_acc(a: &[f32], dy: &[f32], gw: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(gw.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let dyrow = &dy[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let grow = &mut gw[l * n..(l + 1) * n];
            for (g, &dv) in grow.iter_mut().zip(dyrow) {
                *g += av * dv;
            }
        }
    }
}

/// `dx[m,k] = dy[m,n] · bᵀ` for row-major `b[k,n]` — the input-gradient
/// kernel.  Fully writes `dx`; per element the dot product runs over n in
/// increasing order.
pub fn matmul_bt(dy: &[f32], b: &[f32], dx: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        let dxrow = &mut dx[i * k..(i + 1) * k];
        for (l, xv) in dxrow.iter_mut().enumerate() {
            let brow = &b[l * n..(l + 1) * n];
            let mut acc = 0.0f32;
            for (&dv, &bv) in dyrow.iter().zip(brow) {
                acc += dv * bv;
            }
            *xv = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        for i in 0..m {
            for l in 0..k {
                let av = a[i * k + l];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    c[i * n + j] += av * b[l * n + j];
                }
            }
        }
    }

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn tiled_matmul_is_bit_identical_to_naive() {
        // k = 600 spans three KC tiles; results must match the untiled
        // loop exactly, not approximately.
        let (m, k, n) = (3, 600, 5);
        let mut rng = Rng::new(1);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c1 = randv(&mut rng, m * n);
        let mut c2 = c1.clone();
        matmul_acc(&a, &b, &mut c1, m, k, n);
        naive_acc(&a, &b, &mut c2, m, k, n);
        assert_eq!(c1, c2);
    }

    #[test]
    fn at_and_bt_match_references() {
        let (m, k, n) = (4, 7, 3);
        let mut rng = Rng::new(2);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let dy = randv(&mut rng, m * n);

        let mut gw = vec![0.0f32; k * n];
        matmul_at_acc(&a, &dy, &mut gw, m, k, n);
        for l in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| a[i * k + l] * dy[i * n + j]).sum();
                assert!((gw[l * n + j] - want).abs() < 1e-5);
            }
        }

        let mut dx = vec![9.0f32; m * k]; // stale values must be overwritten
        matmul_bt(&dy, &b, &mut dx, m, n, k);
        for i in 0..m {
            for l in 0..k {
                let want: f32 = (0..n).map(|j| dy[i * n + j] * b[l * n + j]).sum();
                assert!((dx[i * k + l] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn zero_rows_are_skipped_without_changing_results() {
        let (m, k, n) = (2, 4, 3);
        let a = vec![0.0, 1.0, 0.0, 2.0, 0.5, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(3);
        let b = randv(&mut rng, k * n);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        matmul_acc(&a, &b, &mut c1, m, k, n);
        naive_acc(&a, &b, &mut c2, m, k, n);
        assert_eq!(c1, c2);
    }
}
