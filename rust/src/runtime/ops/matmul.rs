//! The blocked matmul kernels shared by `Dense` and `Conv2d` (im2col),
//! vectorized through `runtime::simd` with a single runtime dispatch
//! point (AVX2 → SSE2 → unrolled scalar).
//!
//! All three kernels fix the f32 accumulation order per output element —
//! `matmul_acc` tiles the k dimension for cache locality, but within one
//! output element the additions still happen in strictly increasing k
//! order, so tiling is bit-identical to the untiled triple loop.  The
//! SIMD paths vectorize **across independent output elements** (the n
//! dimension for `matmul_acc`/`matmul_at_acc`; a k-panel of output
//! columns for `matmul_bt`) with one IEEE mul + add per step and no FMA,
//! so every dispatch path produces bit-identical results on every machine
//! and thread count — asserted shape-by-shape in `tests/simd_kernels.rs`.
//!
//! Zero multiplicands are skipped where that is value-preserving
//! (x + 0·w = x), which turns post-ReLU sparsity into real savings.  The
//! zero test is hoisted to one per-row-tile scan, so the dense fast path
//! runs without a per-k-element branch.

use crate::runtime::simd::{self, Isa};
use std::cell::RefCell;

/// k-dimension tile: big enough to amortize loop overhead, small enough
/// that the touched B rows stay cache-resident between row passes.
const KC: usize = 256;

thread_local! {
    /// Per-thread scratch for `matmul_bt`'s packed B column-panels (the
    /// buffer is fully rewritten per panel before any read, so reuse
    /// cannot change results).
    static BT_PANEL: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// `c[m,n] += a[m,k] · b[k,n]` (all row-major), on the detected path.
pub fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_acc_with(simd::active_isa(), a, b, c, m, k, n)
}

/// `matmul_acc` on an explicit dispatch path (benches and oracle tests).
pub fn matmul_acc_with(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k1];
            let crow = &mut c[i * n..(i + 1) * n];
            if arow.iter().any(|&v| v == 0.0) {
                // sparse row-tile: keep the value-preserving skip
                for (dk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    simd::axpy(isa, crow, av, &b[(k0 + dk) * n..(k0 + dk + 1) * n]);
                }
            } else {
                // dense row-tile: branch-free accumulation
                for (dk, &av) in arow.iter().enumerate() {
                    simd::axpy(isa, crow, av, &b[(k0 + dk) * n..(k0 + dk + 1) * n]);
                }
            }
        }
    }
}

/// `gw[k,n] += aᵀ · dy` for `a[m,k]`, `dy[m,n]` — the weight-gradient
/// kernel.  Per gw element the accumulation runs over m in increasing
/// order.
pub fn matmul_at_acc(a: &[f32], dy: &[f32], gw: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_at_acc_with(simd::active_isa(), a, dy, gw, m, k, n)
}

/// `matmul_at_acc` on an explicit dispatch path.
pub fn matmul_at_acc_with(
    isa: Isa,
    a: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(gw.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let dyrow = &dy[i * n..(i + 1) * n];
        if arow.iter().any(|&v| v == 0.0) {
            for (l, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                simd::axpy(isa, &mut gw[l * n..(l + 1) * n], av, dyrow);
            }
        } else {
            for (l, &av) in arow.iter().enumerate() {
                simd::axpy(isa, &mut gw[l * n..(l + 1) * n], av, dyrow);
            }
        }
    }
}

/// `dx[m,k] = dy[m,n] · bᵀ` for row-major `b[k,n]` — the input-gradient
/// kernel.  Fully writes `dx`; per element the dot product runs over n in
/// increasing order.
pub fn matmul_bt(dy: &[f32], b: &[f32], dx: &mut [f32], m: usize, n: usize, k: usize) {
    matmul_bt_with(simd::active_isa(), dy, b, dx, m, n, k)
}

/// `matmul_bt` on an explicit dispatch path.
///
/// The wide paths pack B into column-panels of `lane_width` rows —
/// `packed[j*w + t] = b[(l0+t)*n + j]` — so lane t accumulates output
/// element `dx[i, l0+t]` over j in increasing order, exactly the scalar
/// reduction order per element.
pub fn matmul_bt_with(
    isa: Isa,
    dy: &[f32],
    b: &[f32],
    dx: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    let w = isa.lane_width();
    let k_panels = if w > 1 { k - k % w } else { 0 };
    if k_panels > 0 {
        BT_PANEL.with(|p| {
            let mut packed = p.borrow_mut();
            packed.resize(n * w, 0.0);
            let mut l0 = 0;
            while l0 < k_panels {
                for t in 0..w {
                    let brow = &b[(l0 + t) * n..(l0 + t + 1) * n];
                    for (j, &bv) in brow.iter().enumerate() {
                        packed[j * w + t] = bv;
                    }
                }
                for i in 0..m {
                    let dyrow = &dy[i * n..(i + 1) * n];
                    simd::dot_panel(isa, dyrow, &packed[..], &mut dx[i * k + l0..i * k + l0 + w]);
                }
                l0 += w;
            }
        });
    }
    // remainder columns (and the whole matrix on the scalar path)
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for l in k_panels..k {
            dx[i * k + l] = simd::scalar::dot(dyrow, &b[l * n..(l + 1) * n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::simd::supported_isas;
    use crate::util::rng::Rng;

    fn naive_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        for i in 0..m {
            for l in 0..k {
                let av = a[i * k + l];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    c[i * n + j] += av * b[l * n + j];
                }
            }
        }
    }

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn tiled_matmul_is_bit_identical_to_naive() {
        // k = 600 spans three KC tiles; results must match the untiled
        // loop exactly, not approximately — on every dispatch path.
        let (m, k, n) = (3, 600, 5);
        let mut rng = Rng::new(1);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let c0 = randv(&mut rng, m * n);
        let mut want = c0.clone();
        naive_acc(&a, &b, &mut want, m, k, n);
        for isa in supported_isas() {
            let mut c = c0.clone();
            matmul_acc_with(isa, &a, &b, &mut c, m, k, n);
            assert_eq!(c, want, "matmul_acc diverged on {}", isa.name());
        }
    }

    #[test]
    fn at_and_bt_match_references() {
        let (m, k, n) = (4, 7, 3);
        let mut rng = Rng::new(2);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let dy = randv(&mut rng, m * n);

        let mut gw = vec![0.0f32; k * n];
        matmul_at_acc(&a, &dy, &mut gw, m, k, n);
        for l in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| a[i * k + l] * dy[i * n + j]).sum();
                assert!((gw[l * n + j] - want).abs() < 1e-5);
            }
        }

        let mut dx = vec![9.0f32; m * k]; // stale values must be overwritten
        matmul_bt(&dy, &b, &mut dx, m, n, k);
        for i in 0..m {
            for l in 0..k {
                let want: f32 = (0..n).map(|j| dy[i * n + j] * b[l * n + j]).sum();
                assert!((dx[i * k + l] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn zero_rows_are_skipped_without_changing_results() {
        let (m, k, n) = (2, 4, 3);
        let a = vec![0.0, 1.0, 0.0, 2.0, 0.5, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(3);
        let b = randv(&mut rng, k * n);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        matmul_acc(&a, &b, &mut c1, m, k, n);
        naive_acc(&a, &b, &mut c2, m, k, n);
        assert_eq!(c1, c2);
    }

    #[test]
    fn mixed_sparse_and_dense_rows_agree_on_every_path() {
        // Row 0 fully dense (hits the branch-free fast path), row 1 with
        // scattered zeros (hits the skip path), row 2 all-zero: the
        // hoisted per-row-tile sparsity check must not change a bit.
        let (m, k, n) = (3, 40, 13);
        let mut rng = Rng::new(4);
        let mut a = randv(&mut rng, m * k);
        for l in 0..k {
            if l % 3 == 0 {
                a[k + l] = 0.0; // row 1: every third element zero
            }
            a[2 * k + l] = 0.0; // row 2: all zero
        }
        let b = randv(&mut rng, k * n);
        let dy = randv(&mut rng, m * n);
        let c0 = randv(&mut rng, m * n);

        let mut c_want = c0.clone();
        naive_acc(&a, &b, &mut c_want, m, k, n);
        let mut gw_want = vec![0.0f32; k * n];
        matmul_at_acc_with(Isa::Scalar, &a, &dy, &mut gw_want, m, k, n);
        for isa in supported_isas() {
            let mut c = c0.clone();
            matmul_acc_with(isa, &a, &b, &mut c, m, k, n);
            assert_eq!(c, c_want, "matmul_acc sparse/dense diverged on {}", isa.name());
            let mut gw = vec![0.0f32; k * n];
            matmul_at_acc_with(isa, &a, &dy, &mut gw, m, k, n);
            assert_eq!(gw, gw_want, "matmul_at_acc sparse/dense diverged on {}", isa.name());
        }
    }

    #[test]
    fn bt_panel_path_is_bit_identical_to_scalar() {
        // k values around the 8- and 4-lane panel boundaries, incl. m=1.
        let mut rng = Rng::new(5);
        for &(m, n, k) in &[(1usize, 5usize, 8usize), (3, 7, 9), (2, 16, 12), (4, 1, 17)] {
            let dy = randv(&mut rng, m * n);
            let b = randv(&mut rng, k * n);
            let mut want = vec![0.0f32; m * k];
            matmul_bt_with(Isa::Scalar, &dy, &b, &mut want, m, n, k);
            for isa in supported_isas() {
                let mut dx = vec![-3.0f32; m * k];
                matmul_bt_with(isa, &dy, &b, &mut dx, m, n, k);
                assert_eq!(dx, want, "matmul_bt diverged on {} (m={m} n={n} k={k})", isa.name());
            }
        }
    }
}
