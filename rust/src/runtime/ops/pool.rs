//! Spatial pooling over `[h, w, c]` activations.  Window == stride
//! (non-overlapping), which is all the zoo models need; `win == h == w`
//! gives global pooling.

use anyhow::Result;

use super::{LayerOp, Scratch};
use crate::runtime::tensor::HostTensor;

fn pool_geometry(name: &str, in_shape: [usize; 3], win: usize) -> (usize, usize) {
    let [h, w, _] = in_shape;
    assert!(win >= 1 && h % win == 0 && w % win == 0, "pool {name}: {h}x{w} not divisible by {win}");
    (h / win, w / win)
}

fn check_shape(kind: &str, name: &str, input: &[usize], expect: [usize; 3]) -> Result<()> {
    anyhow::ensure!(
        input == expect,
        "{kind} {name}: input {input:?} != expected {expect:?}"
    );
    Ok(())
}

/// Max pooling.  Backward routes the gradient to the first maximum of
/// each window (fixed scan order -> deterministic tie-breaking).
pub struct MaxPool2d {
    name: String,
    h: usize,
    w: usize,
    c: usize,
    win: usize,
    oh: usize,
    ow: usize,
}

impl MaxPool2d {
    pub fn new(name: &str, in_shape: [usize; 3], win: usize) -> MaxPool2d {
        let (oh, ow) = pool_geometry(name, in_shape, win);
        let [h, w, c] = in_shape;
        MaxPool2d { name: name.to_string(), h, w, c, win, oh, ow }
    }
}

impl LayerOp for MaxPool2d {
    fn name(&self) -> &str {
        &self.name
    }

    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        check_shape("maxpool", &self.name, input, [self.h, self.w, self.c])?;
        Ok(vec![self.oh, self.ow, self.c])
    }

    fn forward(&self, _ps: &[HostTensor], x: &[f32], y: &mut [f32], b: usize, _s: &mut Scratch) {
        let in_dim = self.h * self.w * self.c;
        let out_dim = self.oh * self.ow * self.c;
        for bi in 0..b {
            let xe = &x[bi * in_dim..(bi + 1) * in_dim];
            let ye = &mut y[bi * out_dim..(bi + 1) * out_dim];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    for ch in 0..self.c {
                        let mut m = f32::NEG_INFINITY;
                        for ky in 0..self.win {
                            for kx in 0..self.win {
                                let iy = oy * self.win + ky;
                                let ix = ox * self.win + kx;
                                let v = xe[(iy * self.w + ix) * self.c + ch];
                                if v > m {
                                    m = v;
                                }
                            }
                        }
                        ye[(oy * self.ow + ox) * self.c + ch] = m;
                    }
                }
            }
        }
    }

    fn backward(
        &self,
        _ps: &[HostTensor],
        x: &[f32],
        _y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        _grads: &mut [HostTensor],
        b: usize,
        _s: &mut Scratch,
    ) {
        if dx.is_empty() {
            return; // stateless: nothing to do without an input gradient
        }
        let in_dim = self.h * self.w * self.c;
        let out_dim = self.oh * self.ow * self.c;
        dx.fill(0.0);
        for bi in 0..b {
            let xe = &x[bi * in_dim..(bi + 1) * in_dim];
            let dxe = &mut dx[bi * in_dim..(bi + 1) * in_dim];
            let dye = &dy[bi * out_dim..(bi + 1) * out_dim];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    for ch in 0..self.c {
                        let mut m = f32::NEG_INFINITY;
                        let mut arg = 0usize;
                        for ky in 0..self.win {
                            for kx in 0..self.win {
                                let iy = oy * self.win + ky;
                                let ix = ox * self.win + kx;
                                let idx = (iy * self.w + ix) * self.c + ch;
                                if xe[idx] > m {
                                    m = xe[idx];
                                    arg = idx;
                                }
                            }
                        }
                        dxe[arg] += dye[(oy * self.ow + ox) * self.c + ch];
                    }
                }
            }
        }
    }
}

/// Average pooling.  Backward spreads the gradient uniformly.
pub struct AvgPool2d {
    name: String,
    h: usize,
    w: usize,
    c: usize,
    win: usize,
    oh: usize,
    ow: usize,
}

impl AvgPool2d {
    pub fn new(name: &str, in_shape: [usize; 3], win: usize) -> AvgPool2d {
        let (oh, ow) = pool_geometry(name, in_shape, win);
        let [h, w, c] = in_shape;
        AvgPool2d { name: name.to_string(), h, w, c, win, oh, ow }
    }
}

impl LayerOp for AvgPool2d {
    fn name(&self) -> &str {
        &self.name
    }

    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        check_shape("avgpool", &self.name, input, [self.h, self.w, self.c])?;
        Ok(vec![self.oh, self.ow, self.c])
    }

    fn forward(&self, _ps: &[HostTensor], x: &[f32], y: &mut [f32], b: usize, _s: &mut Scratch) {
        let in_dim = self.h * self.w * self.c;
        let out_dim = self.oh * self.ow * self.c;
        let inv = 1.0 / (self.win * self.win) as f32;
        for bi in 0..b {
            let xe = &x[bi * in_dim..(bi + 1) * in_dim];
            let ye = &mut y[bi * out_dim..(bi + 1) * out_dim];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    for ch in 0..self.c {
                        let mut acc = 0.0f32;
                        for ky in 0..self.win {
                            for kx in 0..self.win {
                                let iy = oy * self.win + ky;
                                let ix = ox * self.win + kx;
                                acc += xe[(iy * self.w + ix) * self.c + ch];
                            }
                        }
                        ye[(oy * self.ow + ox) * self.c + ch] = acc * inv;
                    }
                }
            }
        }
    }

    fn backward(
        &self,
        _ps: &[HostTensor],
        _x: &[f32],
        _y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        _grads: &mut [HostTensor],
        b: usize,
        _s: &mut Scratch,
    ) {
        if dx.is_empty() {
            return; // stateless: nothing to do without an input gradient
        }
        let in_dim = self.h * self.w * self.c;
        let out_dim = self.oh * self.ow * self.c;
        let inv = 1.0 / (self.win * self.win) as f32;
        for bi in 0..b {
            let dxe = &mut dx[bi * in_dim..(bi + 1) * in_dim];
            let dye = &dy[bi * out_dim..(bi + 1) * out_dim];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    for ch in 0..self.c {
                        let g = dye[(oy * self.ow + ox) * self.c + ch] * inv;
                        for ky in 0..self.win {
                            for kx in 0..self.win {
                                let iy = oy * self.win + ky;
                                let ix = ox * self.win + kx;
                                dxe[(iy * self.w + ix) * self.c + ch] = g;
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check;
    use super::*;

    #[test]
    fn max_pool_picks_window_maxima() {
        let p = MaxPool2d::new("p", [2, 2, 1], 2);
        assert_eq!(p.out_shape(&[2, 2, 1]).unwrap(), vec![1, 1, 1]);
        let x = [1.0f32, 4.0, 3.0, 2.0];
        let mut y = [0.0f32];
        let mut s = Scratch::default();
        p.forward(&[], &x, &mut y, 1, &mut s);
        assert_eq!(y, [4.0]);
        let mut dx = [9.0f32; 4];
        p.backward(&[], &x, &y, &[2.0], &mut dx, &mut [], 1, &mut s);
        assert_eq!(dx, [0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn avg_pool_means_and_spreads() {
        let p = AvgPool2d::new("p", [2, 2, 2], 2);
        // channels interleaved: [c0 c1] per pixel
        let x = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let mut y = [0.0f32; 2];
        let mut s = Scratch::default();
        p.forward(&[], &x, &mut y, 1, &mut s);
        assert_eq!(y, [2.5, 25.0]);
        let mut dx = [9.0f32; 8];
        p.backward(&[], &x, &y, &[4.0, 8.0], &mut dx, &mut [], 1, &mut s);
        assert_eq!(dx, [1.0, 2.0, 1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn max_pool_gradients_match_finite_differences() {
        // smaller eps: keeps the perturbation away from argmax flips
        let p = MaxPool2d::new("p", [4, 4, 3], 2);
        check::finite_diff(&p, &[4, 4, 3], 2, 9, 1e-3);
    }

    #[test]
    fn avg_pool_gradients_match_finite_differences() {
        let p = AvgPool2d::new("p", [4, 4, 2], 2);
        check::finite_diff(&p, &[4, 4, 2], 2, 10, 1e-2);
    }
}
