//! Fully-connected layer: `y = x · W + b` over the flattened input.

use anyhow::Result;

use super::matmul::{matmul_acc, matmul_at_acc, matmul_bt};
use super::{Init, LayerOp, ParamSpec, Scratch};
use crate::runtime::tensor::HostTensor;

pub struct Dense {
    name: String,
    din: usize,
    dout: usize,
}

impl Dense {
    pub fn new(name: &str, din: usize, dout: usize) -> Dense {
        Dense { name: name.to_string(), din, dout }
    }
}

impl LayerOp for Dense {
    fn name(&self) -> &str {
        &self.name
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("w", &[self.din, self.dout], Init::He { fan_in: self.din }),
            ParamSpec::new("b", &[self.dout], Init::Zeros),
        ]
    }

    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        let d: usize = input.iter().product();
        anyhow::ensure!(
            d == self.din,
            "dense {}: input {input:?} has {d} elements, expected {}",
            self.name,
            self.din
        );
        Ok(vec![self.dout])
    }

    fn forward(&self, ps: &[HostTensor], x: &[f32], y: &mut [f32], b: usize, _s: &mut Scratch) {
        let (w, bias) = (&ps[0].data, &ps[1].data);
        for bi in 0..b {
            y[bi * self.dout..(bi + 1) * self.dout].copy_from_slice(bias);
        }
        matmul_acc(x, w, y, b, self.din, self.dout);
    }

    fn backward(
        &self,
        ps: &[HostTensor],
        x: &[f32],
        _y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        grads: &mut [HostTensor],
        b: usize,
        _s: &mut Scratch,
    ) {
        {
            let gb = &mut grads[1].data;
            for bi in 0..b {
                let drow = &dy[bi * self.dout..(bi + 1) * self.dout];
                for (g, &dv) in gb.iter_mut().zip(drow) {
                    *g += dv;
                }
            }
        }
        matmul_at_acc(x, dy, &mut grads[0].data, b, self.din, self.dout);
        if !dx.is_empty() {
            matmul_bt(dy, &ps[0].data, dx, b, self.dout, self.din);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check;
    use super::*;

    #[test]
    fn shapes_and_params() {
        let d = Dense::new("fc1", 6, 4);
        assert_eq!(d.out_shape(&[6]).unwrap(), vec![4]);
        assert_eq!(d.out_shape(&[2, 3]).unwrap(), vec![4], "input flattens");
        assert!(d.out_shape(&[5]).is_err());
        let ps = d.params();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].suffix, "w");
        assert_eq!(ps[0].shape, vec![6, 4]);
        assert_eq!(ps[1].shape, vec![4]);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let d = Dense::new("fc", 5, 3);
        check::finite_diff(&d, &[5], 4, 7, 1e-2);
    }
}
