//! Composable layer ops for the native layer-graph backend.
//!
//! A `LayerOp` is one node of a `runtime::graph::ModelGraph` sequence:
//! it declares its parameter tensors (`params`), infers its output shape
//! (`out_shape`), and implements batched `forward`/`backward`.  Concrete
//! ops: `Dense`, `Conv2d` (im2col + the blocked matmul shared with
//! `Dense`), `MaxPool2d`/`AvgPool2d`, `Relu`, `GroupNorm` (GroupNorm-lite)
//! and the `Residual` block combinator.
//!
//! Numeric contract (the backend's determinism guarantee lives here):
//! every op uses a **fixed f32 accumulation order** — independent of
//! scratch-buffer history and of which worker thread runs the op — so the
//! cluster's `threads = N` stays bit-identical to `threads = 1`.
//!
//! Buffer contract: `forward` fully writes `y`; `backward` fully writes
//! `dx` and **accumulates** (`+=`) into `grads` (the graph zeroes them
//! once per backward pass).  Temporaries come from the caller's `Scratch`
//! pool so steady-state training allocates nothing per batch.
#![allow(clippy::too_many_arguments)]

pub mod activation;
pub mod conv2d;
pub mod dense;
pub mod matmul;
pub mod norm;
pub mod pool;
pub mod residual;

pub use activation::Relu;
pub use conv2d::Conv2d;
pub use dense::Dense;
pub use norm::GroupNorm;
pub use pool::{AvgPool2d, MaxPool2d};
pub use residual::Residual;

use anyhow::Result;

use super::tensor::HostTensor;
use crate::util::rng::Rng;

/// How one parameter tensor is initialized (deterministically, from a
/// per-tensor forked RNG stream).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    /// He-normal: std = sqrt(2 / fan_in).  Weights.
    He { fan_in: usize },
    /// All zeros.  Biases, GroupNorm shifts.
    Zeros,
    /// All ones.  GroupNorm gains.
    Ones,
}

impl Init {
    /// Materialize this initializer into a fresh tensor.  `rng` is the
    /// tensor's private stream; initializers that draw nothing leave it
    /// untouched (streams are independent, so that is harmless).
    pub fn materialize(&self, shape: &[usize], rng: &mut Rng) -> HostTensor {
        let mut t = HostTensor::zeros(shape);
        match *self {
            Init::He { fan_in } => {
                let std = (2.0 / fan_in.max(1) as f32).sqrt();
                for v in t.data.iter_mut() {
                    *v = rng.normal_f32(0.0, std);
                }
            }
            Init::Zeros => {}
            Init::Ones => t.data.fill(1.0),
        }
        t
    }
}

/// Declaration of one parameter tensor owned by an op.  The graph names
/// the tensor `{op.name()}.{suffix}` and groups all of an op's tensors
/// into one aggregation unit (the paper's "layer").
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub suffix: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn new(suffix: &str, shape: &[usize], init: Init) -> ParamSpec {
        ParamSpec { suffix: suffix.to_string(), shape: shape.to_vec(), init }
    }
}

/// A small free-list of f32 buffers so the hot path reuses capacity
/// instead of reallocating per batch.  Pooling stays bit-identical to
/// fresh allocation because checked-out contents are never *read* before
/// being written: `take` returns a zeroed buffer, and `take_full` (no
/// memset) is reserved for buffers the caller fully overwrites — the
/// contract every op upholds for its `y`/`dx` outputs.
#[derive(Debug, Default)]
pub struct Scratch {
    free: Vec<Vec<f32>>,
}

impl Scratch {
    /// A zeroed buffer of exactly `len`.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.take_full(len);
        buf.fill(0.0);
        buf
    }

    /// A buffer of exactly `len` with **unspecified** contents (stale
    /// pool data) — callers must write every element before reading any.
    /// Skips the memset that dominates `take` for the conv-sized buffers.
    pub fn take_full(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.free.pop().unwrap_or_default();
        if buf.len() < len {
            buf.resize(len, 0.0);
        } else {
            buf.truncate(len);
        }
        buf
    }

    pub fn put(&mut self, buf: Vec<f32>) {
        self.free.push(buf);
    }
}

/// One node of the model graph.  Activations are row-major `[b, dim]`
/// batches where `dim` is the product of the per-example shape (images
/// are `[h, w, c]`).
pub trait LayerOp: Send + Sync {
    /// Aggregation-group name; must be unique among parameterized ops of
    /// one graph.
    fn name(&self) -> &str;

    /// Parameter tensors owned by this op, in positional order.  Empty
    /// for stateless ops (ReLU, pooling).
    fn params(&self) -> Vec<ParamSpec> {
        Vec::new()
    }

    /// Per-example output shape for the given input shape; errors when
    /// the input is incompatible (shape inference = graph validation).
    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>>;

    /// Batched forward: read `x` (`[b, in_dim]`), fully write `y`
    /// (`[b, out_dim]`).  `ps` is exactly this op's tensors.
    fn forward(&self, ps: &[HostTensor], x: &[f32], y: &mut [f32], b: usize, s: &mut Scratch);

    /// Batched backward: given this op's forward input `x`, output `y`,
    /// and upstream gradient `dy`, fully write `dx` and accumulate
    /// parameter gradients into `grads` (same layout as `ps`).
    ///
    /// An **empty** `dx` means the caller does not need the input
    /// gradient (the graph's first op): ops must still accumulate their
    /// parameter gradients but may skip the input-gradient compute.
    fn backward(
        &self,
        ps: &[HostTensor],
        x: &[f32],
        y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        grads: &mut [HostTensor],
        b: usize,
        s: &mut Scratch,
    );
}

#[cfg(test)]
pub(crate) mod check {
    //! Shared finite-difference harness for op unit tests: checks the
    //! analytic gradients of J = sum(forward(x) ⊙ r) for a fixed random
    //! `r` against central differences, on both inputs and parameters.

    use super::*;

    pub fn random_params(op: &dyn LayerOp, rng: &mut Rng) -> Vec<HostTensor> {
        op.params()
            .iter()
            .map(|spec| {
                let mut t = HostTensor::zeros(&spec.shape);
                match spec.init {
                    Init::He { fan_in } => {
                        let std = (2.0 / fan_in.max(1) as f32).sqrt();
                        for v in t.data.iter_mut() {
                            *v = rng.normal_f32(0.0, std);
                        }
                    }
                    // perturb around the rest point so every gradient
                    // path carries signal
                    Init::Zeros => {
                        for v in t.data.iter_mut() {
                            *v = rng.normal_f32(0.0, 0.1);
                        }
                    }
                    Init::Ones => {
                        for v in t.data.iter_mut() {
                            *v = rng.normal_f32(1.0, 0.1);
                        }
                    }
                }
                t
            })
            .collect()
    }

    fn objective(
        op: &dyn LayerOp,
        ps: &[HostTensor],
        x: &[f32],
        r: &[f32],
        b: usize,
        out_dim: usize,
    ) -> f64 {
        let mut s = Scratch::default();
        let mut y = vec![0.0f32; b * out_dim];
        op.forward(ps, x, &mut y, b, &mut s);
        y.iter().zip(r).map(|(&yv, &rv)| yv as f64 * rv as f64).sum()
    }

    fn probe_coords(len: usize) -> [usize; 4] {
        [0, len / 3, len / 2, len - 1]
    }

    /// Central-difference check on a few coordinates of the input and of
    /// every parameter tensor.  `eps` trades truncation error against
    /// kink sensitivity (use a smaller eps for ops with hard maxes).
    pub fn finite_diff(op: &dyn LayerOp, in_shape: &[usize], b: usize, seed: u64, eps: f32) {
        let in_dim: usize = in_shape.iter().product();
        let out_dim: usize = op.out_shape(in_shape).unwrap().iter().product();
        let mut rng = Rng::new(seed);
        let ps = random_params(op, &mut rng);
        let x: Vec<f32> = (0..b * in_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let r: Vec<f32> = (0..b * out_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let mut s = Scratch::default();
        let mut y = vec![0.0f32; b * out_dim];
        op.forward(&ps, &x, &mut y, b, &mut s);
        let mut dx = vec![0.0f32; b * in_dim];
        let mut grads: Vec<HostTensor> = ps.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        op.backward(&ps, &x, &y, &r, &mut dx, &mut grads, b, &mut s);

        let tol = |an: f64| 2e-2 * (1.0 + an.abs());
        for j in probe_coords(x.len()) {
            let mut xp = x.clone();
            xp[j] += eps;
            let mut xm = x.clone();
            xm[j] -= eps;
            let fp = objective(op, &ps, &xp, &r, b, out_dim);
            let fm = objective(op, &ps, &xm, &r, b, out_dim);
            let fd = (fp - fm) / (2.0 * eps as f64);
            let an = dx[j] as f64;
            assert!(
                (fd - an).abs() < tol(an),
                "{}: d/dx[{j}] finite-diff {fd} vs analytic {an}",
                op.name()
            );
        }
        for t in 0..ps.len() {
            for j in probe_coords(ps[t].data.len()) {
                let mut pp = ps.clone();
                pp[t].data[j] += eps;
                let mut pm = ps.clone();
                pm[t].data[j] -= eps;
                let fp = objective(op, &pp, &x, &r, b, out_dim);
                let fm = objective(op, &pm, &x, &r, b, out_dim);
                let fd = (fp - fm) / (2.0 * eps as f64);
                let an = grads[t].data[j] as f64;
                assert!(
                    (fd - an).abs() < tol(an),
                    "{}: tensor {t} coord {j} finite-diff {fd} vs analytic {an}",
                    op.name()
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_materialize_modes() {
        let root = Rng::new(1);
        let mut r1 = root.fork(0);
        let he = Init::He { fan_in: 4 }.materialize(&[4, 2], &mut r1);
        assert!(he.data.iter().any(|&v| v != 0.0));
        let mut r2 = root.fork(0);
        let he2 = Init::He { fan_in: 4 }.materialize(&[4, 2], &mut r2);
        assert_eq!(he.data, he2.data, "same stream -> same draw");
        let z = Init::Zeros.materialize(&[3], &mut r1);
        assert!(z.data.iter().all(|&v| v == 0.0));
        let o = Init::Ones.materialize(&[3], &mut r1);
        assert!(o.data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn scratch_take_is_always_zeroed() {
        let mut s = Scratch::default();
        let mut buf = s.take(4);
        buf.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        s.put(buf);
        let again = s.take(6);
        assert_eq!(again, vec![0.0; 6], "pooled buffer must come back zeroed");
        s.put(again);
        let shorter = s.take(2);
        assert_eq!(shorter.len(), 2);
    }

    #[test]
    fn scratch_take_full_has_exact_length_without_memset_guarantee() {
        let mut s = Scratch::default();
        let mut buf = s.take_full(3);
        assert_eq!(buf.len(), 3);
        buf.copy_from_slice(&[7.0, 8.0, 9.0]);
        s.put(buf);
        // contents are unspecified — only the length is guaranteed
        assert_eq!(s.take_full(2).len(), 2);
        assert_eq!(s.take_full(5).len(), 5);
        assert_eq!(s.take_full(0).len(), 0);
    }
}
