//! Residual block combinator: `y = body(x) + skip(x)`, where `body` is an
//! inner op sequence and `skip` is the identity or a projection conv
//! (1x1, strided) when the geometry changes.
//!
//! The combinator stores no activations: `backward` recomputes the body
//! forward (deterministically, so gradients match a stored-activation
//! implementation bit-for-bit) and chains the inner backwards in reverse.
//! Parameter tensors of all inner ops concatenate into ONE aggregation
//! group — a residual block is one of the paper's "layers".

use anyhow::Result;

use super::conv2d::Conv2d;
use super::{LayerOp, ParamSpec, Scratch};
use crate::runtime::tensor::HostTensor;

pub struct Residual {
    name: String,
    body: Vec<Box<dyn LayerOp>>,
    proj: Option<Conv2d>,
    /// Parameter-tensor count per body op, and its start offset into this
    /// block's parameter slice.
    body_counts: Vec<usize>,
    body_starts: Vec<usize>,
    /// Offset of the projection's tensors (== total body tensor count).
    proj_start: usize,
    /// Per-example element counts along the body: dims[0] = input,
    /// dims[i+1] = body op i output.
    dims: Vec<usize>,
    in_shape: Vec<usize>,
    out_shape_v: Vec<usize>,
}

impl Residual {
    pub fn new(
        name: &str,
        in_shape: &[usize],
        body: Vec<Box<dyn LayerOp>>,
        proj: Option<Conv2d>,
    ) -> Result<Residual> {
        anyhow::ensure!(!body.is_empty(), "residual {name}: empty body");
        let mut dims = vec![in_shape.iter().product::<usize>()];
        let mut cur = in_shape.to_vec();
        let mut body_counts = Vec::with_capacity(body.len());
        let mut body_starts = Vec::with_capacity(body.len());
        let mut next = 0usize;
        for op in &body {
            cur = op.out_shape(&cur)?;
            dims.push(cur.iter().product());
            let cnt = op.params().len();
            body_starts.push(next);
            body_counts.push(cnt);
            next += cnt;
        }
        let skip_shape = match &proj {
            Some(p) => p.out_shape(in_shape)?,
            None => in_shape.to_vec(),
        };
        anyhow::ensure!(
            skip_shape == cur,
            "residual {name}: skip path produces {skip_shape:?} but body produces {cur:?}"
        );
        Ok(Residual {
            name: name.to_string(),
            body,
            proj,
            body_counts,
            body_starts,
            proj_start: next,
            dims,
            in_shape: in_shape.to_vec(),
            out_shape_v: cur,
        })
    }

    /// Run the body chain, returning every intermediate activation
    /// (bufs[i] = body op i output) borrowed from the scratch pool.
    fn body_forward(
        &self,
        ps: &[HostTensor],
        x: &[f32],
        b: usize,
        s: &mut Scratch,
    ) -> Vec<Vec<f32>> {
        let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(self.body.len());
        for (i, op) in self.body.iter().enumerate() {
            let mut out = s.take_full(b * self.dims[i + 1]);
            let (start, cnt) = (self.body_starts[i], self.body_counts[i]);
            let input: &[f32] = if i == 0 { x } else { &bufs[i - 1] };
            op.forward(&ps[start..start + cnt], input, &mut out, b, s);
            bufs.push(out);
        }
        bufs
    }
}

impl LayerOp for Residual {
    fn name(&self) -> &str {
        &self.name
    }

    fn params(&self) -> Vec<ParamSpec> {
        let mut specs = Vec::new();
        for op in &self.body {
            for spec in op.params() {
                specs.push(ParamSpec {
                    suffix: format!("{}.{}", op.name(), spec.suffix),
                    shape: spec.shape,
                    init: spec.init,
                });
            }
        }
        if let Some(p) = &self.proj {
            for spec in p.params() {
                specs.push(ParamSpec {
                    suffix: format!("{}.{}", p.name(), spec.suffix),
                    shape: spec.shape,
                    init: spec.init,
                });
            }
        }
        specs
    }

    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        anyhow::ensure!(
            input == self.in_shape.as_slice(),
            "residual {}: input {input:?} != expected {:?}",
            self.name,
            self.in_shape
        );
        Ok(self.out_shape_v.clone())
    }

    fn forward(&self, ps: &[HostTensor], x: &[f32], y: &mut [f32], b: usize, s: &mut Scratch) {
        let bufs = self.body_forward(ps, x, b, s);
        let body_out = bufs.last().expect("non-empty body");
        match &self.proj {
            Some(p) => {
                p.forward(&ps[self.proj_start..], x, y, b, s);
                for (yv, &bv) in y.iter_mut().zip(body_out) {
                    *yv += bv;
                }
            }
            None => {
                for ((yv, &bv), &xv) in y.iter_mut().zip(body_out).zip(x) {
                    *yv = xv + bv;
                }
            }
        }
        for buf in bufs {
            s.put(buf);
        }
    }

    fn backward(
        &self,
        ps: &[HostTensor],
        x: &[f32],
        _y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        grads: &mut [HostTensor],
        b: usize,
        s: &mut Scratch,
    ) {
        // recompute body activations, then chain inner backwards
        let bufs = self.body_forward(ps, x, b, s);
        let mut dcur = s.take_full(dy.len());
        dcur.copy_from_slice(dy);
        for i in (0..self.body.len()).rev() {
            let (start, cnt) = (self.body_starts[i], self.body_counts[i]);
            // when the caller doesn't need dx, the first body op doesn't
            // need its input gradient either — propagate the empty-slice
            // convention down
            let mut dprev = if i == 0 && dx.is_empty() {
                s.take_full(0)
            } else {
                s.take_full(b * self.dims[i])
            };
            let input: &[f32] = if i == 0 { x } else { &bufs[i - 1] };
            self.body[i].backward(
                &ps[start..start + cnt],
                input,
                &bufs[i],
                &dcur,
                &mut dprev,
                &mut grads[start..start + cnt],
                b,
                s,
            );
            s.put(std::mem::replace(&mut dcur, dprev));
        }
        // dcur is now d(x) through the body; add the skip path
        match &self.proj {
            Some(p) => {
                // Conv2d::backward never reads its `y` argument, so the
                // projection's forward output is not recomputed for it.
                let pp = &ps[self.proj_start..];
                let mut dskip = s.take_full(dx.len());
                p.backward(pp, x, &[], dy, &mut dskip, &mut grads[self.proj_start..], b, s);
                for ((dv, &bv), &sv) in dx.iter_mut().zip(&dcur).zip(&dskip) {
                    *dv = bv + sv;
                }
                s.put(dskip);
            }
            None => {
                for ((dv, &bv), &dyv) in dx.iter_mut().zip(&dcur).zip(dy) {
                    *dv = bv + dyv;
                }
            }
        }
        s.put(dcur);
        for buf in bufs {
            s.put(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check;
    use super::super::norm::GroupNorm;
    use super::super::Relu;
    use super::*;

    fn block(stride: usize, cin: usize, cout: usize) -> Residual {
        let (h, w) = (4usize, 4usize);
        let (oh, ow) = (h / stride, w / stride);
        let body: Vec<Box<dyn LayerOp>> = vec![
            Box::new(Conv2d::new("c1", [h, w, cin], cout, 3, stride, 1)),
            Box::new(GroupNorm::new("gn1", [oh, ow, cout], 1)),
            Box::new(Relu::new("relu")),
            Box::new(Conv2d::new("c2", [oh, ow, cout], cout, 3, 1, 1)),
        ];
        let proj = if stride != 1 || cin != cout {
            Some(Conv2d::new("proj", [h, w, cin], cout, 1, stride, 0))
        } else {
            None
        };
        Residual::new("blk", &[h, w, cin], body, proj).unwrap()
    }

    #[test]
    fn params_concatenate_with_prefixes() {
        let r = block(2, 2, 3);
        let names: Vec<String> = r.params().iter().map(|p| p.suffix.clone()).collect();
        assert_eq!(names, vec!["c1.w", "c1.b", "gn1.g", "gn1.b", "c2.w", "c2.b", "proj.w", "proj.b"]);
        assert_eq!(r.out_shape(&[4, 4, 2]).unwrap(), vec![2, 2, 3]);
        assert!(r.out_shape(&[4, 4, 3]).is_err());
        // identity-skip variant has no proj tensors
        let id = block(1, 3, 3);
        assert_eq!(id.params().len(), 6);
    }

    #[test]
    fn shape_mismatch_is_rejected_at_construction() {
        let body: Vec<Box<dyn LayerOp>> =
            vec![Box::new(Conv2d::new("c", [4, 4, 2], 3, 3, 2, 1))];
        // body halves the spatial dims but the skip is identity
        assert!(Residual::new("bad", &[4, 4, 2], body, None).is_err());
    }

    #[test]
    fn identity_skip_gradients_match_finite_differences() {
        let r = block(1, 3, 3);
        check::finite_diff(&r, &[4, 4, 3], 2, 21, 5e-3);
    }

    #[test]
    fn projection_skip_gradients_match_finite_differences() {
        let r = block(2, 2, 3);
        check::finite_diff(&r, &[4, 4, 2], 2, 22, 5e-3);
    }
}
