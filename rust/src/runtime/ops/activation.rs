//! Elementwise activations.

use anyhow::Result;

use super::{LayerOp, Scratch};
use crate::runtime::tensor::HostTensor;

/// Rectified linear unit.  Shape-preserving, stateless.
///
/// Forward keeps non-negative values unchanged (including the sign of
/// zero, matching the historical fused-MLP backend bit-for-bit); backward
/// blocks the gradient wherever the output is not strictly positive.
pub struct Relu {
    name: String,
}

impl Relu {
    pub fn new(name: &str) -> Relu {
        Relu { name: name.to_string() }
    }
}

impl LayerOp for Relu {
    fn name(&self) -> &str {
        &self.name
    }

    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        Ok(input.to_vec())
    }

    fn forward(&self, _ps: &[HostTensor], x: &[f32], y: &mut [f32], _b: usize, _s: &mut Scratch) {
        for (yv, &xv) in y.iter_mut().zip(x) {
            *yv = if xv < 0.0 { 0.0 } else { xv };
        }
    }

    fn backward(
        &self,
        _ps: &[HostTensor],
        _x: &[f32],
        y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        _grads: &mut [HostTensor],
        _b: usize,
        _s: &mut Scratch,
    ) {
        for ((dv, &yv), &dyv) in dx.iter_mut().zip(y).zip(dy) {
            *dv = if yv > 0.0 { dyv } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_and_backward_mask() {
        let r = Relu::new("r");
        assert_eq!(r.out_shape(&[2, 3]).unwrap(), vec![2, 3]);
        let x = [-1.0f32, 0.0, 2.5, -0.5];
        let mut y = [9.0f32; 4];
        let mut s = Scratch::default();
        r.forward(&[], &x, &mut y, 1, &mut s);
        assert_eq!(y, [0.0, 0.0, 2.5, 0.0]);
        let dy = [1.0f32, 1.0, 1.0, 1.0];
        let mut dx = [9.0f32; 4];
        r.backward(&[], &x, &y, &dy, &mut dx, &mut [], 1, &mut s);
        assert_eq!(dx, [0.0, 0.0, 1.0, 0.0]);
    }
}
