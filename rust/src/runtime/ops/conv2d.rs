//! 2-D convolution over `[h, w, c]` activations, via im2col + the blocked
//! matmul shared with `Dense`.
//!
//! The weight is stored im2col-ready as `[k·k·cin, cout]` (a 2-D tensor:
//! He init sees fan_in = k·k·cin, exactly the conv fan-in).  One batch
//! lowers to a single `[b·oh·ow, k·k·cin] × [k·k·cin, cout]` matmul, so
//! dense and conv share one deterministic hot-path kernel.

use anyhow::Result;

use super::matmul::{matmul_acc, matmul_at_acc, matmul_bt};
use super::{Init, LayerOp, ParamSpec, Scratch};
use crate::runtime::tensor::HostTensor;

pub struct Conv2d {
    name: String,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
}

impl Conv2d {
    /// A conv layer for a fixed input geometry `[h, w, cin]` (the graph's
    /// shape inference validates it).  `k` is the square kernel size.
    pub fn new(
        name: &str,
        in_shape: [usize; 3],
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Conv2d {
        let [h, w, cin] = in_shape;
        assert!(stride >= 1 && k >= 1, "conv {name}: bad kernel/stride");
        assert!(h + 2 * pad >= k && w + 2 * pad >= k, "conv {name}: kernel larger than input");
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        Conv2d { name: name.to_string(), h, w, cin, cout, k, stride, pad, oh, ow }
    }

    fn kdim(&self) -> usize {
        self.k * self.k * self.cin
    }

    fn in_dim(&self) -> usize {
        self.h * self.w * self.cin
    }

    /// Lower the batch to the column matrix `[b·oh·ow, k·k·cin]`
    /// (zero-filled where the kernel overhangs the padding border).
    fn im2col(&self, x: &[f32], cols: &mut [f32], b: usize) {
        let kdim = self.kdim();
        let in_dim = self.in_dim();
        for bi in 0..b {
            let xe = &x[bi * in_dim..(bi + 1) * in_dim];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    let row = ((bi * self.oh + oy) * self.ow + ox) * kdim;
                    let col = &mut cols[row..row + kdim];
                    let mut o = 0;
                    for ky in 0..self.k {
                        let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                        for kx in 0..self.k {
                            let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                            if iy < 0
                                || iy >= self.h as isize
                                || ix < 0
                                || ix >= self.w as isize
                            {
                                col[o..o + self.cin].fill(0.0);
                            } else {
                                let src = ((iy as usize) * self.w + ix as usize) * self.cin;
                                col[o..o + self.cin].copy_from_slice(&xe[src..src + self.cin]);
                            }
                            o += self.cin;
                        }
                    }
                }
            }
        }
    }

    /// Scatter-add the column-matrix gradient back onto the input image
    /// (the im2col adjoint).  Iterates in the same fixed order as
    /// `im2col`, so overlapping windows accumulate deterministically.
    fn col2im_add(&self, dcols: &[f32], dx: &mut [f32], b: usize) {
        let kdim = self.kdim();
        let in_dim = self.in_dim();
        for bi in 0..b {
            let xe = &mut dx[bi * in_dim..(bi + 1) * in_dim];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    let row = ((bi * self.oh + oy) * self.ow + ox) * kdim;
                    let col = &dcols[row..row + kdim];
                    let mut o = 0;
                    for ky in 0..self.k {
                        let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                        for kx in 0..self.k {
                            let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                            if iy >= 0
                                && iy < self.h as isize
                                && ix >= 0
                                && ix < self.w as isize
                            {
                                let dst = ((iy as usize) * self.w + ix as usize) * self.cin;
                                for (dv, &cv) in
                                    xe[dst..dst + self.cin].iter_mut().zip(&col[o..o + self.cin])
                                {
                                    *dv += cv;
                                }
                            }
                            o += self.cin;
                        }
                    }
                }
            }
        }
    }
}

impl LayerOp for Conv2d {
    fn name(&self) -> &str {
        &self.name
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("w", &[self.kdim(), self.cout], Init::He { fan_in: self.kdim() }),
            ParamSpec::new("b", &[self.cout], Init::Zeros),
        ]
    }

    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        anyhow::ensure!(
            input == [self.h, self.w, self.cin],
            "conv {}: input {input:?} != expected [{}, {}, {}]",
            self.name,
            self.h,
            self.w,
            self.cin
        );
        Ok(vec![self.oh, self.ow, self.cout])
    }

    fn forward(&self, ps: &[HostTensor], x: &[f32], y: &mut [f32], b: usize, s: &mut Scratch) {
        let kdim = self.kdim();
        let rows = b * self.oh * self.ow;
        let (w, bias) = (&ps[0].data, &ps[1].data);
        let mut cols = s.take_full(rows * kdim);
        self.im2col(x, &mut cols, b);
        for r in 0..rows {
            y[r * self.cout..(r + 1) * self.cout].copy_from_slice(bias);
        }
        matmul_acc(&cols, w, y, rows, kdim, self.cout);
        s.put(cols);
    }

    fn backward(
        &self,
        ps: &[HostTensor],
        x: &[f32],
        _y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        grads: &mut [HostTensor],
        b: usize,
        s: &mut Scratch,
    ) {
        let kdim = self.kdim();
        let rows = b * self.oh * self.ow;
        // weight gradient: recompute the column matrix (activation
        // recomputation keeps per-call memory flat)
        let mut cols = s.take_full(rows * kdim);
        self.im2col(x, &mut cols, b);
        matmul_at_acc(&cols, dy, &mut grads[0].data, rows, kdim, self.cout);
        s.put(cols);
        {
            let gb = &mut grads[1].data;
            for r in 0..rows {
                let drow = &dy[r * self.cout..(r + 1) * self.cout];
                for (g, &dv) in gb.iter_mut().zip(drow) {
                    *g += dv;
                }
            }
        }
        // input gradient: dcols = dy · wᵀ, then the im2col adjoint
        // (skipped entirely when the caller passed an empty dx)
        if !dx.is_empty() {
            let mut dcols = s.take_full(rows * kdim);
            matmul_bt(dy, &ps[0].data, &mut dcols, rows, self.cout, kdim);
            dx.fill(0.0);
            self.col2im_add(&dcols, dx, b);
            s.put(dcols);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check;
    use super::*;

    #[test]
    fn output_geometry() {
        let c = Conv2d::new("c", [32, 32, 3], 16, 3, 1, 1);
        assert_eq!(c.out_shape(&[32, 32, 3]).unwrap(), vec![32, 32, 16]);
        assert!(c.out_shape(&[32, 32, 4]).is_err());
        let s2 = Conv2d::new("s", [32, 32, 16], 32, 3, 2, 1);
        assert_eq!(s2.out_shape(&[32, 32, 16]).unwrap(), vec![16, 16, 32]);
        let p = Conv2d::new("p", [32, 32, 16], 32, 1, 2, 0);
        assert_eq!(p.out_shape(&[32, 32, 16]).unwrap(), vec![16, 16, 32]);
        assert_eq!(p.params()[0].shape, vec![16, 32]);
    }

    #[test]
    fn identity_kernel_passes_input_through() {
        // 1x1 conv with the identity weight must reproduce the input.
        let c = Conv2d::new("id", [3, 3, 2], 2, 1, 1, 0);
        let mut ps = vec![HostTensor::zeros(&[2, 2]), HostTensor::zeros(&[2])];
        ps[0].data.copy_from_slice(&[1.0, 0.0, 0.0, 1.0]);
        let x: Vec<f32> = (0..18).map(|i| i as f32 * 0.5).collect();
        let mut y = vec![0.0f32; 18];
        let mut s = Scratch::default();
        c.forward(&ps, &x, &mut y, 1, &mut s);
        assert_eq!(y, x);
    }

    #[test]
    fn gradients_match_finite_differences_padded() {
        let c = Conv2d::new("c", [4, 4, 2], 3, 3, 1, 1);
        check::finite_diff(&c, &[4, 4, 2], 2, 5, 1e-2);
    }

    #[test]
    fn gradients_match_finite_differences_strided() {
        let c = Conv2d::new("c", [5, 5, 2], 3, 3, 2, 1);
        check::finite_diff(&c, &[5, 5, 2], 2, 6, 1e-2);
    }

    #[test]
    fn gradients_match_finite_differences_1x1() {
        let c = Conv2d::new("c", [4, 4, 3], 2, 1, 2, 0);
        check::finite_diff(&c, &[4, 4, 3], 2, 8, 1e-2);
    }
}
