//! GroupNorm-lite: per-example group normalization with a learned
//! per-channel gain/shift — the batch-independent normalizer (BatchNorm
//! would couple examples and break the per-client determinism story).

use anyhow::Result;

use super::{Init, LayerOp, ParamSpec, Scratch};
use crate::runtime::tensor::HostTensor;

pub struct GroupNorm {
    name: String,
    h: usize,
    w: usize,
    c: usize,
    groups: usize,
    eps: f32,
}

impl GroupNorm {
    pub fn new(name: &str, in_shape: [usize; 3], groups: usize) -> GroupNorm {
        let [h, w, c] = in_shape;
        assert!(groups >= 1 && c % groups == 0, "groupnorm {name}: {c} channels not divisible into {groups} groups");
        GroupNorm { name: name.to_string(), h, w, c, groups, eps: 1e-5 }
    }

    /// (mean, 1/sqrt(var + eps)) of one example's group `g`, two fixed
    /// passes in memory order.
    fn stats(&self, xe: &[f32], g: usize) -> (f32, f32) {
        let gs = self.c / self.groups;
        let c0 = g * gs;
        let n = (self.h * self.w * gs) as f32;
        let mut sum = 0.0f32;
        for p in 0..self.h * self.w {
            for ch in c0..c0 + gs {
                sum += xe[p * self.c + ch];
            }
        }
        let mean = sum / n;
        let mut var = 0.0f32;
        for p in 0..self.h * self.w {
            for ch in c0..c0 + gs {
                let d = xe[p * self.c + ch] - mean;
                var += d * d;
            }
        }
        (mean, 1.0 / (var / n + self.eps).sqrt())
    }
}

impl LayerOp for GroupNorm {
    fn name(&self) -> &str {
        &self.name
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("g", &[self.c], Init::Ones),
            ParamSpec::new("b", &[self.c], Init::Zeros),
        ]
    }

    fn out_shape(&self, input: &[usize]) -> Result<Vec<usize>> {
        anyhow::ensure!(
            input == [self.h, self.w, self.c],
            "groupnorm {}: input {input:?} != expected [{}, {}, {}]",
            self.name,
            self.h,
            self.w,
            self.c
        );
        Ok(input.to_vec())
    }

    fn forward(&self, ps: &[HostTensor], x: &[f32], y: &mut [f32], b: usize, _s: &mut Scratch) {
        let (gamma, beta) = (&ps[0].data, &ps[1].data);
        let gs = self.c / self.groups;
        let dim = self.h * self.w * self.c;
        for bi in 0..b {
            let xe = &x[bi * dim..(bi + 1) * dim];
            let ye = &mut y[bi * dim..(bi + 1) * dim];
            for g in 0..self.groups {
                let (mean, inv) = self.stats(xe, g);
                let c0 = g * gs;
                for p in 0..self.h * self.w {
                    for ch in c0..c0 + gs {
                        let i = p * self.c + ch;
                        ye[i] = gamma[ch] * (xe[i] - mean) * inv + beta[ch];
                    }
                }
            }
        }
    }

    fn backward(
        &self,
        ps: &[HostTensor],
        x: &[f32],
        _y: &[f32],
        dy: &[f32],
        dx: &mut [f32],
        grads: &mut [HostTensor],
        b: usize,
        _s: &mut Scratch,
    ) {
        let gamma = &ps[0].data;
        let gs = self.c / self.groups;
        let dim = self.h * self.w * self.c;
        let n = (self.h * self.w * gs) as f32;
        let need_dx = !dx.is_empty();
        for bi in 0..b {
            let xe = &x[bi * dim..(bi + 1) * dim];
            let dye = &dy[bi * dim..(bi + 1) * dim];
            for g in 0..self.groups {
                let (mean, inv) = self.stats(xe, g);
                let c0 = g * gs;
                // s1 = sum(dy*gamma), s2 = sum(dy*gamma*xhat); the
                // gain/shift gradients ride along in the same pass.
                let mut s1 = 0.0f32;
                let mut s2 = 0.0f32;
                for p in 0..self.h * self.w {
                    for ch in c0..c0 + gs {
                        let i = p * self.c + ch;
                        let xhat = (xe[i] - mean) * inv;
                        let gup = dye[i] * gamma[ch];
                        s1 += gup;
                        s2 += gup * xhat;
                        grads[0].data[ch] += dye[i] * xhat;
                        grads[1].data[ch] += dye[i];
                    }
                }
                if need_dx {
                    let dxe = &mut dx[bi * dim..(bi + 1) * dim];
                    let m1 = s1 / n;
                    let m2 = s2 / n;
                    for p in 0..self.h * self.w {
                        for ch in c0..c0 + gs {
                            let i = p * self.c + ch;
                            let xhat = (xe[i] - mean) * inv;
                            dxe[i] = inv * (dye[i] * gamma[ch] - m1 - xhat * m2);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::check;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn normalizes_each_group_per_example() {
        let gn = GroupNorm::new("gn", [2, 2, 4], 2);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..2 * 16).map(|_| rng.normal_f32(3.0, 2.0)).collect();
        let ps = vec![
            Init::Ones.materialize(&[4], &mut rng),
            Init::Zeros.materialize(&[4], &mut rng),
        ];
        let mut y = vec![0.0f32; 2 * 16];
        let mut s = Scratch::default();
        gn.forward(&ps, &x, &mut y, 2, &mut s);
        // with unit gain / zero shift every group is ~zero-mean, unit-var
        for bi in 0..2 {
            for g in 0..2 {
                let vals: Vec<f32> = (0..4)
                    .flat_map(|p| (0..2).map(move |dc| y[bi * 16 + p * 4 + g * 2 + dc]))
                    .collect();
                let mean: f32 = vals.iter().sum::<f32>() / 8.0;
                let var: f32 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 8.0;
                assert!(mean.abs() < 1e-4, "group mean {mean}");
                assert!((var - 1.0).abs() < 1e-2, "group var {var}");
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let gn = GroupNorm::new("gn", [3, 3, 4], 2);
        check::finite_diff(&gn, &[3, 3, 4], 2, 12, 1e-2);
    }

    #[test]
    fn single_group_is_layernorm() {
        let gn = GroupNorm::new("ln", [2, 2, 3], 1);
        check::finite_diff(&gn, &[2, 2, 3], 3, 13, 1e-2);
    }
}
