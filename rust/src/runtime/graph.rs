//! The layer-graph compute backend: a sequence of `LayerOp`s executed as
//! one `ComputeBackend`.
//!
//! `ModelGraph` owns the op sequence, synthesizes its `Manifest` from the
//! ops' parameter declarations (one aggregation group per parameterized
//! op — the paper's "layer"), and implements init / the local-step family
//! / eval generically over the graph.  Losses are mean softmax
//! cross-entropy, optimizers mirror the python oracles — identical to the
//! historical fused-MLP backend, which is now just the `mlp` entry of
//! `runtime::zoo`.
//!
//! Determinism: every op fixes its f32 accumulation order, and all
//! methods take `&self` — per-call state lives in a pooled `GraphScratch`
//! whose buffers are zeroed on checkout, so results never depend on pool
//! history or on which cluster worker runs the step.  The pool is what
//! makes the hot path allocation-free in steady state (the perf win is
//! measured by the `micro-scratch` bench section).

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::backend::{ComputeBackend, RuntimeStats};
use super::manifest::{LayerSpec, Manifest};
use super::ops::{Init, LayerOp, Scratch};
use super::tensor::HostTensor;
use crate::util::rng::Rng;

pub struct ModelGraph {
    ops: Vec<Box<dyn LayerOp>>,
    /// Per op: (first tensor index, tensor count) into the flat param vec.
    param_ranges: Vec<(usize, usize)>,
    /// Per tensor: its initializer (graph init = fork-per-tensor streams).
    param_inits: Vec<Init>,
    /// Per-example element counts: io_dims[0] = input, io_dims[i+1] =
    /// op i output.
    io_dims: Vec<usize>,
    manifest: Manifest,
    /// When false, checked-out scratch is dropped instead of pooled
    /// (bench A/B only — results are identical either way).
    reuse_scratch: bool,
    pool: Mutex<Vec<GraphScratch>>,
    stats: Mutex<RuntimeStats>,
}

/// Reusable per-call state: activations, gradient tensors, the two
/// ping-pong d-activation buffers, and the ops' temporary pool.
#[derive(Default)]
struct GraphScratch {
    acts: Vec<Vec<f32>>,
    grads: Vec<HostTensor>,
    da: Vec<f32>,
    db: Vec<f32>,
    ops_scratch: Scratch,
}

impl ModelGraph {
    /// Build a graph backend; validates shape inference end-to-end and
    /// synthesizes the manifest.
    #[allow(clippy::too_many_arguments)]
    pub fn from_ops(
        model: &str,
        base: &str,
        input_shape: &[usize],
        num_classes: usize,
        batch_size: usize,
        eval_batch_size: usize,
        chunk_k: usize,
        ops: Vec<Box<dyn LayerOp>>,
    ) -> Result<ModelGraph> {
        anyhow::ensure!(!ops.is_empty(), "model {model}: graph needs at least one op");
        let mut io_dims = vec![input_shape.iter().product::<usize>()];
        let mut cur = input_shape.to_vec();
        let mut layers: Vec<LayerSpec> = Vec::new();
        let mut param_ranges = Vec::with_capacity(ops.len());
        let mut param_inits = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        let mut next = 0usize;
        for op in &ops {
            cur = op.out_shape(&cur)?;
            io_dims.push(cur.iter().product());
            let specs = op.params();
            if !specs.is_empty() {
                anyhow::ensure!(
                    seen.insert(op.name().to_string()),
                    "model {model}: duplicate group name {:?}",
                    op.name()
                );
            }
            param_ranges.push((next, specs.len()));
            next += specs.len();
            for spec in &specs {
                param_inits.push(spec.init);
            }
            layers.push((
                op.name().to_string(),
                specs.into_iter().map(|s| (s.suffix, s.shape)).collect(),
            ));
        }
        let out = *io_dims.last().unwrap();
        anyhow::ensure!(
            out == num_classes,
            "model {model}: final op produces {out} values, expected {num_classes} class logits"
        );
        let manifest = Manifest::synthetic_graph(
            model,
            base,
            input_shape,
            num_classes,
            batch_size,
            eval_batch_size,
            chunk_k,
            &layers,
        )?;
        Ok(ModelGraph {
            ops,
            param_ranges,
            param_inits,
            io_dims,
            manifest,
            reuse_scratch: true,
            pool: Mutex::new(Vec::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Disable cross-call scratch reuse (bench A/B only).
    pub fn set_scratch_reuse(&mut self, on: bool) {
        self.reuse_scratch = on;
    }

    fn take_scratch(&self) -> GraphScratch {
        self.pool.lock().unwrap().pop().unwrap_or_default()
    }

    fn put_scratch(&self, sc: GraphScratch) {
        if self.reuse_scratch {
            self.pool.lock().unwrap().push(sc);
        }
    }

    fn record(&self, entry: &str, t0: Instant) {
        self.stats.lock().unwrap().record(entry, t0.elapsed().as_secs_f64());
    }

    fn check_params(&self, params: &[HostTensor]) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.manifest.params.len(),
            "expected {} param tensors, got {}",
            self.manifest.params.len(),
            params.len()
        );
        Ok(())
    }

    fn batch_dims(&self, eval: bool, x: &[f32], y: &[i32]) -> Result<(usize, usize)> {
        let b = if eval { self.manifest.eval_batch_size } else { self.manifest.batch_size };
        let d: usize = self.manifest.input_shape.iter().product();
        anyhow::ensure!(x.len() == b * d, "x len {} != {}x{}", x.len(), b, d);
        anyhow::ensure!(y.len() == b, "y len {} != batch {b}", y.len());
        Ok((b, d))
    }

    /// Forward the whole graph into `sc.acts` (acts[i] = op i output).
    fn run_forward(&self, sc: &mut GraphScratch, params: &[HostTensor], x: &[f32], b: usize) {
        if sc.acts.len() != self.ops.len() {
            sc.acts.resize_with(self.ops.len(), Vec::new);
        }
        for i in 0..self.ops.len() {
            let dim = self.io_dims[i + 1];
            let (head, tail) = sc.acts.split_at_mut(i);
            let out = &mut tail[0];
            out.clear();
            out.resize(b * dim, 0.0);
            let input: &[f32] = if i == 0 { x } else { &head[i - 1] };
            let (start, cnt) = self.param_ranges[i];
            self.ops[i].forward(&params[start..start + cnt], input, out, b, &mut sc.ops_scratch);
        }
    }

    /// Backward from the logits in `sc.acts`; leaves the parameter
    /// gradients in `sc.grads` and returns the mean batch loss.
    fn run_backward(
        &self,
        sc: &mut GraphScratch,
        params: &[HostTensor],
        x: &[f32],
        ys: &[i32],
        b: usize,
    ) -> f32 {
        if sc.grads.len() != params.len() {
            sc.grads = params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        } else {
            for g in sc.grads.iter_mut() {
                g.data.fill(0.0);
            }
        }
        let nl = self.ops.len();
        let c = self.manifest.num_classes;
        let loss = loss_and_dlogits(&sc.acts[nl - 1], ys, b, c, &mut sc.da);
        for i in (0..nl).rev() {
            sc.db.clear();
            if i > 0 {
                // the first op's input gradient is never consumed; an
                // empty dx tells the op to skip computing it
                sc.db.resize(b * self.io_dims[i], 0.0);
            }
            let input: &[f32] = if i == 0 { x } else { &sc.acts[i - 1] };
            let (start, cnt) = self.param_ranges[i];
            self.ops[i].backward(
                &params[start..start + cnt],
                input,
                &sc.acts[i],
                &sc.da,
                &mut sc.db,
                &mut sc.grads[start..start + cnt],
                b,
                &mut sc.ops_scratch,
            );
            std::mem::swap(&mut sc.da, &mut sc.db);
        }
        loss
    }

    fn sgd_apply(params: &mut [HostTensor], grads: &[HostTensor], lr: f32) {
        for (p, g) in params.iter_mut().zip(grads) {
            for (pv, &gv) in p.data.iter_mut().zip(&g.data) {
                *pv -= lr * gv;
            }
        }
    }
}

/// Mean cross-entropy loss; writes d(loss)/d(logits) into `dl`.
fn loss_and_dlogits(logits: &[f32], ys: &[i32], b: usize, c: usize, dl: &mut Vec<f32>) -> f32 {
    dl.clear();
    dl.resize(b * c, 0.0);
    let mut loss = 0.0f32;
    let inv_b = 1.0 / b as f32;
    for bi in 0..b {
        let row = &logits[bi * c..(bi + 1) * c];
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            if v > mx {
                mx = v;
            }
        }
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let ln_sum = sum.ln();
        let y = ys[bi] as usize;
        loss += mx + ln_sum - row[y];
        let drow = &mut dl[bi * c..(bi + 1) * c];
        for (dv, &v) in drow.iter_mut().zip(row) {
            *dv = (v - mx).exp() / sum * inv_b;
        }
        drow[y] -= inv_b;
    }
    loss * inv_b
}

impl ComputeBackend for ModelGraph {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Per-spec init (He / zeros / ones), one independent RNG stream per
    /// tensor — adding layers never shifts earlier tensors' draws, and the
    /// MLP zoo entry reproduces the historical backend bit-for-bit.
    fn init_params(&self, seed: u32) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let root = Rng::new(seed as u64 ^ 0x11A7_17E0);
        let mut out = Vec::with_capacity(self.manifest.params.len());
        for (t, (info, init)) in self.manifest.params.iter().zip(&self.param_inits).enumerate() {
            let mut rng = root.fork(t as u64);
            out.push(init.materialize(&info.shape, &mut rng));
        }
        self.record("init", t0);
        Ok(out)
    }

    fn train_step(
        &self,
        params: &mut [HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let t0 = Instant::now();
        self.check_params(params)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let mut sc = self.take_scratch();
        self.run_forward(&mut sc, params, x, b);
        let loss = self.run_backward(&mut sc, params, x, y, b);
        Self::sgd_apply(params, &sc.grads, lr);
        self.put_scratch(sc);
        self.record("train_step", t0);
        Ok(loss)
    }

    fn train_step_prox(
        &self,
        params: &mut [HostTensor],
        global: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<f32> {
        let t0 = Instant::now();
        self.check_params(params)?;
        self.check_params(global)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let mut sc = self.take_scratch();
        self.run_forward(&mut sc, params, x, b);
        let mut loss = self.run_backward(&mut sc, params, x, y, b);
        // + mu/2 * ||p - global||^2 (loss term and gradient).
        let mut prox = 0.0f32;
        for ((g, p), gl) in sc.grads.iter_mut().zip(params.iter()).zip(global) {
            for ((gv, &pv), &rv) in g.data.iter_mut().zip(&p.data).zip(&gl.data) {
                let diff = pv - rv;
                *gv += mu * diff;
                prox += diff * diff;
            }
        }
        loss += 0.5 * mu * prox;
        Self::sgd_apply(params, &sc.grads, lr);
        self.put_scratch(sc);
        self.record("train_step_prox", t0);
        Ok(loss)
    }

    fn train_step_scaffold(
        &self,
        params: &mut [HostTensor],
        ci: &[HostTensor],
        c: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let t0 = Instant::now();
        self.check_params(params)?;
        self.check_params(ci)?;
        self.check_params(c)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let mut sc = self.take_scratch();
        self.run_forward(&mut sc, params, x, b);
        let loss = self.run_backward(&mut sc, params, x, y, b);
        for (((p, g), cit), ct) in params.iter_mut().zip(&sc.grads).zip(ci).zip(c) {
            for (((pv, &gv), &civ), &cv) in
                p.data.iter_mut().zip(&g.data).zip(&cit.data).zip(&ct.data)
            {
                *pv -= lr * (gv - civ + cv);
            }
        }
        self.put_scratch(sc);
        self.record("train_step_scaffold", t0);
        Ok(loss)
    }

    fn grad_step(
        &self,
        params: &[HostTensor],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let t0 = Instant::now();
        self.check_params(params)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let mut sc = self.take_scratch();
        self.run_forward(&mut sc, params, x, b);
        let loss = self.run_backward(&mut sc, params, x, y, b);
        let grads = sc.grads.clone();
        self.put_scratch(sc);
        self.record("grad_step", t0);
        Ok((grads, loss))
    }

    fn eval_step(&self, params: &[HostTensor], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        self.check_params(params)?;
        let (b, _) = self.batch_dims(true, x, y)?;
        let mut sc = self.take_scratch();
        self.run_forward(&mut sc, params, x, b);
        let logits = &sc.acts[self.ops.len() - 1];
        let c = self.manifest.num_classes;
        let mut correct = 0.0f32;
        let mut loss_sum = 0.0f32;
        for bi in 0..b {
            let row = &logits[bi * c..(bi + 1) * c];
            let mut best = 0usize;
            let mut mx = f32::NEG_INFINITY;
            for (j, &v) in row.iter().enumerate() {
                if v > mx {
                    mx = v;
                    best = j;
                }
            }
            let y_bi = y[bi] as usize;
            if best == y_bi {
                correct += 1.0;
            }
            let mut sum = 0.0f32;
            for &v in row {
                sum += (v - mx).exp();
            }
            loss_sum += mx + sum.ln() - row[y_bi];
        }
        self.put_scratch(sc);
        self.record("eval_step", t0);
        Ok((correct, loss_sum))
    }

    fn stats_total_secs(&self) -> f64 {
        self.stats.lock().unwrap().total_secs()
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    fn as_parallel(&self) -> Option<&(dyn ComputeBackend + Sync)> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::super::ops::{Conv2d, Dense, MaxPool2d, Relu};
    use super::*;

    fn tiny_conv_graph() -> ModelGraph {
        let ops: Vec<Box<dyn LayerOp>> = vec![
            Box::new(Conv2d::new("c1", [4, 4, 1], 2, 3, 1, 1)),
            Box::new(Relu::new("r1")),
            Box::new(MaxPool2d::new("p1", [4, 4, 2], 2)),
            Box::new(Dense::new("fc", 8, 3)),
        ];
        ModelGraph::from_ops("tiny-conv", "test", &[4, 4, 1], 3, 2, 2, 1, ops).unwrap()
    }

    fn batch(g: &ModelGraph, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let m = g.manifest();
        let d: usize = m.input_shape.iter().product();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m.batch_size * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..m.batch_size).map(|i| (i % m.num_classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn manifest_synthesis_groups_parameterized_ops_only() {
        let g = tiny_conv_graph();
        let m = g.manifest();
        m.validate().unwrap();
        assert_eq!(m.model, "tiny-conv");
        assert_eq!(m.groups.len(), 2, "relu/pool own no groups");
        assert_eq!(m.params[0].name, "c1.w");
        assert_eq!(m.params[0].shape, vec![9, 2]);
        assert_eq!(m.params[2].name, "fc.w");
        assert_eq!(m.num_params, 9 * 2 + 2 + 8 * 3 + 3);
    }

    #[test]
    fn bad_graphs_are_rejected() {
        // wrong logit count
        let ops: Vec<Box<dyn LayerOp>> = vec![Box::new(Dense::new("fc", 4, 5))];
        assert!(ModelGraph::from_ops("bad", "test", &[4], 3, 2, 2, 1, ops).is_err());
        // shape break mid-graph
        let ops: Vec<Box<dyn LayerOp>> = vec![
            Box::new(Dense::new("fc1", 4, 5)),
            Box::new(Dense::new("fc2", 6, 3)),
        ];
        assert!(ModelGraph::from_ops("bad", "test", &[4], 3, 2, 2, 1, ops).is_err());
        // duplicate group names
        let ops: Vec<Box<dyn LayerOp>> = vec![
            Box::new(Dense::new("fc", 4, 4)),
            Box::new(Dense::new("fc", 4, 3)),
        ];
        assert!(ModelGraph::from_ops("bad", "test", &[4], 3, 2, 2, 1, ops).is_err());
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_fresh_allocation() {
        let mut fresh = tiny_conv_graph();
        fresh.set_scratch_reuse(false);
        let pooled = tiny_conv_graph();
        let mut p1 = pooled.init_params(3).unwrap();
        let mut p2 = fresh.init_params(3).unwrap();
        for step in 0..4 {
            let (x, y) = batch(&pooled, 100 + step);
            let l1 = pooled.train_step(&mut p1, &x, &y, 0.1).unwrap();
            let l2 = fresh.train_step(&mut p2, &x, &y, 0.1).unwrap();
            assert_eq!(l1, l2, "step {step} loss diverged");
        }
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn train_and_eval_batch_shapes_differ() {
        let ops: Vec<Box<dyn LayerOp>> = vec![Box::new(Dense::new("fc", 4, 3))];
        let g = ModelGraph::from_ops("t", "test", &[4], 3, 2, 6, 1, ops).unwrap();
        let mut params = g.init_params(0).unwrap();
        let (x, y) = batch(&g, 1);
        g.train_step(&mut params, &x, &y, 0.1).unwrap();
        // eval uses the eval batch size
        let mut rng = Rng::new(2);
        let ex: Vec<f32> = (0..6 * 4).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let ey: Vec<i32> = (0..6).map(|i| (i % 3) as i32).collect();
        let (correct, loss) = g.eval_step(&params, &ex, &ey).unwrap();
        assert!((0.0..=6.0).contains(&correct));
        assert!(loss.is_finite());
        // and the train-sized batch is rejected by eval
        assert!(g.eval_step(&params, &x, &y).is_err());
    }
}
