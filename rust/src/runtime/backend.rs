//! The compute-backend seam between the coordinator and model execution.
//!
//! `ComputeBackend` is everything Algorithm 1 needs from a model runtime:
//! deterministic init, the local-step family (SGD / FedProx / SCAFFOLD),
//! full-batch gradients, evaluation, and an optional fused aggregation
//! kernel.  Two implementations exist:
//!
//!   - `runtime::native::NativeBackend` — pure-rust MLP compute with an
//!     in-memory synthesized manifest.  Hermetic (no artifacts, no foreign
//!     deps), `Sync`, and therefore fan-out-able across worker threads by
//!     `runtime::cluster`.  The default.
//!   - `runtime::engine::ModelRuntime` (`--features pjrt`) — PJRT execution
//!     of AOT HLO artifacts.  `Rc`-based, thread-confined, serial.
//!
//! The trait is object-safe; the coordinator holds a `Box<dyn
//! ComputeBackend>` and upgrades to parallel execution via `as_parallel`
//! only when the backend is `Sync`.

use std::collections::HashMap;

use anyhow::Result;

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// Cumulative per-entry execution stats (count + wall seconds), used by the
/// perf harness and the coordinator's overhead report.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub by_entry: HashMap<String, (u64, f64)>,
}

impl RuntimeStats {
    pub fn record(&mut self, entry: &str, secs: f64) {
        let e = self.by_entry.entry(entry.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }
    pub fn total_secs(&self) -> f64 {
        self.by_entry.values().map(|(_, s)| s).sum()
    }
    pub fn count(&self, entry: &str) -> u64 {
        self.by_entry.get(entry).map(|(c, _)| *c).unwrap_or(0)
    }
    pub fn secs(&self, entry: &str) -> f64 {
        self.by_entry.get(entry).map(|(_, s)| *s).unwrap_or(0.0)
    }
}

/// Model compute: the L2 entry points of DESIGN.md plus the optional L1
/// fused aggregation kernel.  All methods take `&self`; implementations
/// that keep scratch state guard it internally so a `Sync` backend can be
/// shared by the cluster's worker threads.
pub trait ComputeBackend {
    /// Parameter order, shapes, aggregation groups, batch sizes.
    fn manifest(&self) -> &Manifest;

    /// Deterministic parameter init from a seed.
    fn init_params(&self, seed: u32) -> Result<Vec<HostTensor>>;

    /// One local SGD step in place; returns the batch loss.
    fn train_step(&self, params: &mut [HostTensor], x: &[f32], y: &[i32], lr: f32)
        -> Result<f32>;

    /// FedProx local step: adds the mu/2 * ||p - global||^2 term.
    fn train_step_prox(
        &self,
        params: &mut [HostTensor],
        global: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<f32>;

    /// SCAFFOLD local step: p <- p - lr * (g - c_i + c).
    fn train_step_scaffold(
        &self,
        params: &mut [HostTensor],
        ci: &[HostTensor],
        c: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32>;

    /// Full-batch gradients (FedNova + tests).
    fn grad_step(&self, params: &[HostTensor], x: &[f32], y: &[i32])
        -> Result<(Vec<HostTensor>, f32)>;

    /// Evaluate one batch of `manifest().eval_batch_size` examples:
    /// returns (correct_count, loss_sum).
    fn eval_step(&self, params: &[HostTensor], x: &[f32], y: &[i32]) -> Result<(f32, f32)>;

    /// K fused local SGD steps; xs is [K*B*inp], ys is [K*B].  Returns the
    /// K per-step losses.  The default loops `train_step`, which is exactly
    /// what chunking must be bit-equivalent to.
    fn train_chunk(
        &self,
        params: &mut [HostTensor],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        let b = self.manifest().batch_size;
        let d: usize = self.manifest().input_shape.iter().product();
        anyhow::ensure!(b > 0 && ys.len() % b == 0, "train_chunk batch alignment");
        let k = ys.len() / b;
        anyhow::ensure!(
            xs.len() == k * b * d,
            "train_chunk xs len {} != {k}x{b}x{d}",
            xs.len()
        );
        let mut losses = Vec::with_capacity(k);
        for s in 0..k {
            let x = &xs[s * b * d..(s + 1) * b * d];
            let y = &ys[s * b..(s + 1) * b];
            losses.push(self.train_step(params, x, y, lr)?);
        }
        Ok(losses)
    }

    /// Steps per `train_chunk` call (1 = chunking unavailable/pointless).
    fn chunk_k(&self) -> usize {
        self.manifest().chunk_k.max(1)
    }

    /// Fused aggregation of an [m, dim] row-major `stack` with weights of
    /// length m: returns (u, discrepancy), or `None` when this backend has
    /// no fused kernel for the configuration (callers fall back to
    /// `aggregation::aggregate_native`).
    fn fused_agg(
        &self,
        stack: &[f32],
        weights: &[f32],
        dim: usize,
    ) -> Result<Option<(Vec<f32>, f32)>> {
        let _ = (stack, weights, dim);
        Ok(None)
    }

    /// Whether `fused_agg` would return Some for (dim, m active rows).
    fn has_fused_agg(&self, dim: usize, m: usize) -> bool {
        let _ = (dim, m);
        false
    }

    /// Total wall seconds spent inside compute entry points.
    fn stats_total_secs(&self) -> f64 {
        0.0
    }

    /// Snapshot of the per-entry stats (for the perf harness).
    fn stats(&self) -> RuntimeStats {
        RuntimeStats::default()
    }

    /// A `Sync` view of this backend, if it supports being shared across
    /// the cluster's worker threads.  `None` (the default) confines
    /// execution to the coordinator thread — the PJRT engine is `Rc`-based
    /// and must stay serial.
    fn as_parallel(&self) -> Option<&(dyn ComputeBackend + Sync)> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let mut s = RuntimeStats::default();
        s.record("train_step", 0.5);
        s.record("train_step", 0.25);
        s.record("eval_step", 1.0);
        assert_eq!(s.count("train_step"), 2);
        assert!((s.secs("train_step") - 0.75).abs() < 1e-12);
        assert!((s.total_secs() - 1.75).abs() < 1e-12);
        assert_eq!(s.count("missing"), 0);
        assert_eq!(s.secs("missing"), 0.0);
    }
}
