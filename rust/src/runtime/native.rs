//! Back-compat surface of the historical monolithic MLP backend.
//!
//! PR 2 refactored the hand-fused MLP forward/backward into the
//! composable layer-graph subsystem (`runtime::ops` + `runtime::graph`):
//! `NativeBackend` is now `ModelGraph`, and the MLP is just the `mlp`
//! entry of `runtime::zoo`.  The constructors below keep the original
//! call sites (tests, benches, coordinator defaults) working unchanged,
//! and the numerics are bit-identical to the pre-graph implementation —
//! same per-tensor init streams, same f32 accumulation order (asserted by
//! the seed-era tests kept in this file).

use super::graph::ModelGraph;
use super::zoo;
use crate::data::DatasetKind;

/// Default hidden widths (as `make_mlp` in the python model zoo).
pub const DEFAULT_HIDDEN: [usize; 2] = [128, 64];
/// Default batch sizes of the synthesized manifest.
pub const DEFAULT_BATCH: usize = 16;
pub const DEFAULT_EVAL_BATCH: usize = 64;
/// Default fused-chunk length (amortizes per-step dispatch bookkeeping and
/// keeps the coordinator's chunked path exercised).
pub const DEFAULT_CHUNK_K: usize = 4;

/// The hermetic pure-rust backend — since the layer-graph refactor, an
/// alias of `ModelGraph`.
pub use super::graph::ModelGraph as NativeBackend;

impl ModelGraph {
    /// An MLP backend for an explicit topology (the historical
    /// `NativeBackend::new`).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        input_shape: &[usize],
        hidden: &[usize],
        num_classes: usize,
        batch_size: usize,
        eval_batch_size: usize,
        chunk_k: usize,
    ) -> NativeBackend {
        zoo::mlp(input_shape, hidden, num_classes, batch_size, eval_batch_size, chunk_k)
    }

    /// The default model for a dataset: MLP over the flattened input.
    pub fn for_dataset(kind: DatasetKind) -> NativeBackend {
        NativeBackend::new(
            &kind.input_shape(),
            &DEFAULT_HIDDEN,
            kind.num_classes(),
            DEFAULT_BATCH,
            DEFAULT_EVAL_BATCH,
            DEFAULT_CHUNK_K,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ComputeBackend;
    use crate::runtime::tensor::HostTensor;
    use crate::util::rng::Rng;

    fn toy_backend() -> NativeBackend {
        NativeBackend::for_dataset(DatasetKind::Toy)
    }

    fn fixed_batch(b: &NativeBackend, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let m = b.manifest();
        let d: usize = m.input_shape.iter().product();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m.batch_size * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..m.batch_size).map(|i| (i % m.num_classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn manifest_is_consistent() {
        let b = toy_backend();
        b.manifest().validate().unwrap();
        assert_eq!(b.manifest().groups.len(), 3);
        assert_eq!(b.manifest().input_shape, vec![64]);
        assert_eq!(b.manifest().num_classes, 10);
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let b = toy_backend();
        let p1 = b.init_params(3).unwrap();
        let p2 = b.init_params(3).unwrap();
        for (a, c) in p1.iter().zip(&p2) {
            assert_eq!(a.data, c.data);
        }
        let p3 = b.init_params(4).unwrap();
        assert!(p1.iter().zip(&p3).any(|(a, c)| a.data != c.data));
        // biases are zero, weights are not
        for (t, info) in p1.iter().zip(&b.manifest().params) {
            assert_eq!(t.shape, info.shape);
            if info.shape.len() == 1 {
                assert!(t.data.iter().all(|&v| v == 0.0), "{} not zero", info.name);
            } else {
                assert!(t.data.iter().any(|&v| v != 0.0), "{} all zero", info.name);
            }
        }
    }

    #[test]
    fn grad_step_matches_train_step() {
        let b = toy_backend();
        let (x, y) = fixed_batch(&b, 9);
        let p0 = b.init_params(1).unwrap();
        let (grads, gloss) = b.grad_step(&p0, &x, &y).unwrap();
        let mut p1 = p0.clone();
        let tloss = b.train_step(&mut p1, &x, &y, 0.1).unwrap();
        assert_eq!(gloss, tloss);
        for ((p_new, p_old), g) in p1.iter().zip(&p0).zip(&grads) {
            for ((&pn, &po), &gv) in p_new.data.iter().zip(&p_old.data).zip(&g.data) {
                assert_eq!(pn, po - 0.1 * gv);
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Spot-check d(loss)/d(param) against central differences on a few
        // coordinates of every tensor.
        let b = NativeBackend::new(&[6], &[5], 3, 4, 4, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..4 * 6).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y = vec![0, 1, 2, 1];
        let params = b.init_params(0).unwrap();
        let (grads, _) = b.grad_step(&params, &x, &y).unwrap();
        let eps = 1e-2f32;
        for t in 0..params.len() {
            for j in [0, params[t].data.len() / 2] {
                let mut plus = params.clone();
                plus[t].data[j] += eps;
                let mut minus = params.clone();
                minus[t].data[j] -= eps;
                let (_, lp) = b.grad_step(&plus, &x, &y).unwrap();
                let (_, lm) = b.grad_step(&minus, &x, &y).unwrap();
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[t].data[j];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "tensor {t} coord {j}: finite-diff {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn scaffold_zero_controls_equal_sgd() {
        let b = toy_backend();
        let (x, y) = fixed_batch(&b, 5);
        let zeros: Vec<HostTensor> = b
            .manifest()
            .params
            .iter()
            .map(|p| HostTensor::zeros(&p.shape))
            .collect();
        let mut p_sgd = b.init_params(7).unwrap();
        let mut p_sca = p_sgd.clone();
        let l1 = b.train_step(&mut p_sgd, &x, &y, 0.05).unwrap();
        let l2 = b.train_step_scaffold(&mut p_sca, &zeros, &zeros, &x, &y, 0.05).unwrap();
        assert_eq!(l1, l2);
        for (a, c) in p_sgd.iter().zip(&p_sca) {
            assert_eq!(a.data, c.data);
        }
    }

    #[test]
    fn prox_mu_zero_equals_sgd() {
        let b = toy_backend();
        let (x, y) = fixed_batch(&b, 6);
        let global = b.init_params(8).unwrap();
        let mut p_sgd = b.init_params(7).unwrap();
        let mut p_prox = p_sgd.clone();
        let l1 = b.train_step(&mut p_sgd, &x, &y, 0.05).unwrap();
        let l2 = b.train_step_prox(&mut p_prox, &global, &x, &y, 0.05, 0.0).unwrap();
        assert_eq!(l1, l2);
        for (a, c) in p_sgd.iter().zip(&p_prox) {
            assert_eq!(a.data, c.data);
        }
    }

    #[test]
    fn bad_shapes_are_rejected() {
        let b = toy_backend();
        let mut params = b.init_params(0).unwrap();
        assert!(b.train_step(&mut params, &[0.0; 3], &[0], 0.1).is_err());
        let (x, y) = fixed_batch(&b, 1);
        let mut short = params[..2].to_vec();
        assert!(b.train_step(&mut short, &x, &y, 0.1).is_err());
    }
}
