//! Native (pure-rust) MLP compute backend — the hermetic execution path.
//!
//! Mirrors `python/compile/model.py::make_mlp` and the pure-jnp oracles in
//! `python/compile/kernels/ref.py`: an L-layer ReLU MLP over the flattened
//! input with mean softmax cross-entropy, He-normal init, and plain SGD
//! (`ref_sgd`).  The manifest is synthesized in memory — no `manifest.json`
//! or HLO artifacts — so the default build trains end-to-end with zero
//! external files.
//!
//! Numerics are deterministic: fixed f32 accumulation order everywhere, so
//! results are bit-identical across runs and across the cluster's thread
//! counts.  All methods take `&self` (scratch is per-call) which makes the
//! backend `Sync` — the property `runtime::cluster` needs to fan clients
//! across worker threads.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::backend::{ComputeBackend, RuntimeStats};
use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::data::DatasetKind;
use crate::util::rng::Rng;

/// Default hidden widths (as `make_mlp` in the python model zoo).
pub const DEFAULT_HIDDEN: [usize; 2] = [128, 64];
/// Default batch sizes of the synthesized manifest.
pub const DEFAULT_BATCH: usize = 16;
pub const DEFAULT_EVAL_BATCH: usize = 64;
/// Default fused-chunk length (amortizes per-step dispatch bookkeeping and
/// keeps the coordinator's chunked path exercised).
pub const DEFAULT_CHUNK_K: usize = 4;

pub struct NativeBackend {
    manifest: Manifest,
    /// Layer widths [d_in, hidden.., num_classes].
    dims: Vec<usize>,
    stats: Mutex<RuntimeStats>,
}

impl NativeBackend {
    /// An MLP backend for an explicit topology.
    pub fn new(
        input_shape: &[usize],
        hidden: &[usize],
        num_classes: usize,
        batch_size: usize,
        eval_batch_size: usize,
        chunk_k: usize,
    ) -> NativeBackend {
        let input_dim: usize = input_shape.iter().product();
        let mut dims = vec![input_dim];
        dims.extend_from_slice(hidden);
        dims.push(num_classes);
        let manifest = Manifest::synthetic_mlp(
            input_shape,
            hidden,
            num_classes,
            batch_size,
            eval_batch_size,
            chunk_k,
        );
        NativeBackend { manifest, dims, stats: Mutex::new(RuntimeStats::default()) }
    }

    /// The default backend for a dataset: MLP over the flattened input.
    pub fn for_dataset(kind: DatasetKind) -> NativeBackend {
        NativeBackend::new(
            &kind.input_shape(),
            &DEFAULT_HIDDEN,
            kind.num_classes(),
            DEFAULT_BATCH,
            DEFAULT_EVAL_BATCH,
            DEFAULT_CHUNK_K,
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    fn record(&self, entry: &str, t0: Instant) {
        self.stats.lock().unwrap().record(entry, t0.elapsed().as_secs_f64());
    }

    fn check_params(&self, params: &[HostTensor]) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.manifest.params.len(),
            "expected {} param tensors, got {}",
            self.manifest.params.len(),
            params.len()
        );
        Ok(())
    }

    /// Forward pass over a batch of `b` rows; returns per-layer activations
    /// (post-ReLU for hidden layers; raw logits for the last).
    fn forward(&self, params: &[HostTensor], x: &[f32], b: usize) -> Vec<Vec<f32>> {
        let nl = self.n_layers();
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(nl);
        for l in 0..nl {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let w = &params[2 * l].data;
            let bias = &params[2 * l + 1].data;
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let mut out = vec![0.0f32; b * dout];
            for bi in 0..b {
                let orow = &mut out[bi * dout..(bi + 1) * dout];
                orow.copy_from_slice(bias);
                let xrow = &input[bi * din..(bi + 1) * din];
                for (i, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &w[i * dout..(i + 1) * dout];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += xv * wv;
                    }
                }
            }
            if l + 1 < nl {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(out);
        }
        acts
    }

    /// Mean cross-entropy loss + d(loss)/d(logits) for one batch.
    fn loss_and_dlogits(logits: &[f32], ys: &[i32], b: usize, c: usize) -> (f32, Vec<f32>) {
        let mut dl = vec![0.0f32; b * c];
        let mut loss = 0.0f32;
        let inv_b = 1.0 / b as f32;
        for bi in 0..b {
            let row = &logits[bi * c..(bi + 1) * c];
            let mut mx = f32::NEG_INFINITY;
            for &v in row {
                if v > mx {
                    mx = v;
                }
            }
            let mut sum = 0.0f32;
            for &v in row {
                sum += (v - mx).exp();
            }
            let ln_sum = sum.ln();
            let y = ys[bi] as usize;
            loss += mx + ln_sum - row[y];
            let drow = &mut dl[bi * c..(bi + 1) * c];
            for (dv, &v) in drow.iter_mut().zip(row) {
                *dv = (v - mx).exp() / sum * inv_b;
            }
            drow[y] -= inv_b;
        }
        (loss * inv_b, dl)
    }

    /// Backward pass; returns (grads in param order, mean batch loss).
    fn backward(
        &self,
        params: &[HostTensor],
        x: &[f32],
        acts: &[Vec<f32>],
        ys: &[i32],
        b: usize,
    ) -> (Vec<HostTensor>, f32) {
        let nl = self.n_layers();
        let c = self.dims[nl];
        let (loss, mut dz) = Self::loss_and_dlogits(&acts[nl - 1], ys, b, c);
        let mut grads: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros(&p.shape)).collect();
        for l in (0..nl).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            {
                let gb = &mut grads[2 * l + 1].data;
                for bi in 0..b {
                    let drow = &dz[bi * dout..(bi + 1) * dout];
                    for (g, &dv) in gb.iter_mut().zip(drow) {
                        *g += dv;
                    }
                }
            }
            {
                let gw = &mut grads[2 * l].data;
                for bi in 0..b {
                    let xrow = &input[bi * din..(bi + 1) * din];
                    let drow = &dz[bi * dout..(bi + 1) * dout];
                    for (i, &xv) in xrow.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let grow = &mut gw[i * dout..(i + 1) * dout];
                        for (g, &dv) in grow.iter_mut().zip(drow) {
                            *g += xv * dv;
                        }
                    }
                }
            }
            if l > 0 {
                let w = &params[2 * l].data;
                let prev = &acts[l - 1];
                let mut ndz = vec![0.0f32; b * din];
                for bi in 0..b {
                    let drow = &dz[bi * dout..(bi + 1) * dout];
                    let nrow = &mut ndz[bi * din..(bi + 1) * din];
                    for (i, nv) in nrow.iter_mut().enumerate() {
                        // ReLU mask: a == 0 means z <= 0, gradient blocked.
                        if prev[bi * din + i] <= 0.0 {
                            continue;
                        }
                        let wrow = &w[i * dout..(i + 1) * dout];
                        let mut s = 0.0f32;
                        for (&dv, &wv) in drow.iter().zip(wrow) {
                            s += dv * wv;
                        }
                        *nv = s;
                    }
                }
                dz = ndz;
            }
        }
        (grads, loss)
    }

    fn sgd_apply(params: &mut [HostTensor], grads: &[HostTensor], lr: f32) {
        for (p, g) in params.iter_mut().zip(grads) {
            for (pv, &gv) in p.data.iter_mut().zip(&g.data) {
                *pv -= lr * gv;
            }
        }
    }

    fn batch_dims(&self, eval: bool, x: &[f32], y: &[i32]) -> Result<(usize, usize)> {
        let b = if eval { self.manifest.eval_batch_size } else { self.manifest.batch_size };
        let d: usize = self.manifest.input_shape.iter().product();
        anyhow::ensure!(x.len() == b * d, "x len {} != {}x{}", x.len(), b, d);
        anyhow::ensure!(y.len() == b, "y len {} != batch {b}", y.len());
        Ok((b, d))
    }
}

impl ComputeBackend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// He-normal weights / zero biases, one independent RNG stream per
    /// tensor (adding layers never shifts earlier tensors' draws).
    fn init_params(&self, seed: u32) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let root = Rng::new(seed as u64 ^ 0x11A7_17E0);
        let mut out = Vec::with_capacity(self.manifest.params.len());
        for (t, info) in self.manifest.params.iter().enumerate() {
            let mut ten = HostTensor::zeros(&info.shape);
            if info.shape.len() == 2 {
                let fan_in = info.shape[0].max(1);
                let std = (2.0 / fan_in as f32).sqrt();
                let mut rng = root.fork(t as u64);
                for v in ten.data.iter_mut() {
                    *v = rng.normal_f32(0.0, std);
                }
            }
            out.push(ten);
        }
        self.record("init", t0);
        Ok(out)
    }

    fn train_step(
        &self,
        params: &mut [HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let t0 = Instant::now();
        self.check_params(params)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let acts = self.forward(params, x, b);
        let (grads, loss) = self.backward(params, x, &acts, y, b);
        Self::sgd_apply(params, &grads, lr);
        self.record("train_step", t0);
        Ok(loss)
    }

    fn train_step_prox(
        &self,
        params: &mut [HostTensor],
        global: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<f32> {
        let t0 = Instant::now();
        self.check_params(params)?;
        self.check_params(global)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let acts = self.forward(params, x, b);
        let (mut grads, mut loss) = self.backward(params, x, &acts, y, b);
        // + mu/2 * ||p - global||^2 (loss term and gradient).
        let mut prox = 0.0f32;
        for ((g, p), gl) in grads.iter_mut().zip(params.iter()).zip(global) {
            for ((gv, &pv), &rv) in g.data.iter_mut().zip(&p.data).zip(&gl.data) {
                let diff = pv - rv;
                *gv += mu * diff;
                prox += diff * diff;
            }
        }
        loss += 0.5 * mu * prox;
        Self::sgd_apply(params, &grads, lr);
        self.record("train_step_prox", t0);
        Ok(loss)
    }

    fn train_step_scaffold(
        &self,
        params: &mut [HostTensor],
        ci: &[HostTensor],
        c: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let t0 = Instant::now();
        self.check_params(params)?;
        self.check_params(ci)?;
        self.check_params(c)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let acts = self.forward(params, x, b);
        let (grads, loss) = self.backward(params, x, &acts, y, b);
        for (((p, g), cit), ct) in params.iter_mut().zip(&grads).zip(ci).zip(c) {
            for (((pv, &gv), &civ), &cv) in
                p.data.iter_mut().zip(&g.data).zip(&cit.data).zip(&ct.data)
            {
                *pv -= lr * (gv - civ + cv);
            }
        }
        self.record("train_step_scaffold", t0);
        Ok(loss)
    }

    fn grad_step(
        &self,
        params: &[HostTensor],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let t0 = Instant::now();
        self.check_params(params)?;
        let (b, _) = self.batch_dims(false, x, y)?;
        let acts = self.forward(params, x, b);
        let res = self.backward(params, x, &acts, y, b);
        self.record("grad_step", t0);
        Ok(res)
    }

    fn eval_step(&self, params: &[HostTensor], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        self.check_params(params)?;
        let (b, _) = self.batch_dims(true, x, y)?;
        let acts = self.forward(params, x, b);
        let logits = &acts[self.n_layers() - 1];
        let c = *self.dims.last().unwrap();
        let mut correct = 0.0f32;
        let mut loss_sum = 0.0f32;
        for bi in 0..b {
            let row = &logits[bi * c..(bi + 1) * c];
            let mut best = 0usize;
            let mut mx = f32::NEG_INFINITY;
            for (j, &v) in row.iter().enumerate() {
                if v > mx {
                    mx = v;
                    best = j;
                }
            }
            let y_bi = y[bi] as usize;
            if best == y_bi {
                correct += 1.0;
            }
            let mut sum = 0.0f32;
            for &v in row {
                sum += (v - mx).exp();
            }
            loss_sum += mx + sum.ln() - row[y_bi];
        }
        self.record("eval_step", t0);
        Ok((correct, loss_sum))
    }

    fn stats_total_secs(&self) -> f64 {
        self.stats.lock().unwrap().total_secs()
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    fn as_parallel(&self) -> Option<&(dyn ComputeBackend + Sync)> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_backend() -> NativeBackend {
        NativeBackend::for_dataset(DatasetKind::Toy)
    }

    fn fixed_batch(b: &NativeBackend, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let m = b.manifest();
        let d: usize = m.input_shape.iter().product();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..m.batch_size * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y: Vec<i32> = (0..m.batch_size).map(|i| (i % m.num_classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn manifest_is_consistent() {
        let b = toy_backend();
        b.manifest().validate().unwrap();
        assert_eq!(b.manifest().groups.len(), 3);
        assert_eq!(b.manifest().input_shape, vec![64]);
        assert_eq!(b.manifest().num_classes, 10);
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let b = toy_backend();
        let p1 = b.init_params(3).unwrap();
        let p2 = b.init_params(3).unwrap();
        for (a, c) in p1.iter().zip(&p2) {
            assert_eq!(a.data, c.data);
        }
        let p3 = b.init_params(4).unwrap();
        assert!(p1.iter().zip(&p3).any(|(a, c)| a.data != c.data));
        // biases are zero, weights are not
        for (t, info) in p1.iter().zip(&b.manifest().params) {
            assert_eq!(t.shape, info.shape);
            if info.shape.len() == 1 {
                assert!(t.data.iter().all(|&v| v == 0.0), "{} not zero", info.name);
            } else {
                assert!(t.data.iter().any(|&v| v != 0.0), "{} all zero", info.name);
            }
        }
    }

    #[test]
    fn grad_step_matches_train_step() {
        let b = toy_backend();
        let (x, y) = fixed_batch(&b, 9);
        let p0 = b.init_params(1).unwrap();
        let (grads, gloss) = b.grad_step(&p0, &x, &y).unwrap();
        let mut p1 = p0.clone();
        let tloss = b.train_step(&mut p1, &x, &y, 0.1).unwrap();
        assert_eq!(gloss, tloss);
        for ((p_new, p_old), g) in p1.iter().zip(&p0).zip(&grads) {
            for ((&pn, &po), &gv) in p_new.data.iter().zip(&p_old.data).zip(&g.data) {
                assert_eq!(pn, po - 0.1 * gv);
            }
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Spot-check d(loss)/d(param) against central differences on a few
        // coordinates of every tensor.
        let b = NativeBackend::new(&[6], &[5], 3, 4, 4, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..4 * 6).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y = vec![0, 1, 2, 1];
        let params = b.init_params(0).unwrap();
        let (grads, _) = b.grad_step(&params, &x, &y).unwrap();
        let eps = 1e-2f32;
        for t in 0..params.len() {
            for j in [0, params[t].data.len() / 2] {
                let mut plus = params.clone();
                plus[t].data[j] += eps;
                let mut minus = params.clone();
                minus[t].data[j] -= eps;
                let (_, lp) = b.grad_step(&plus, &x, &y).unwrap();
                let (_, lm) = b.grad_step(&minus, &x, &y).unwrap();
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[t].data[j];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "tensor {t} coord {j}: finite-diff {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn scaffold_zero_controls_equal_sgd() {
        let b = toy_backend();
        let (x, y) = fixed_batch(&b, 5);
        let zeros: Vec<HostTensor> = b
            .manifest()
            .params
            .iter()
            .map(|p| HostTensor::zeros(&p.shape))
            .collect();
        let mut p_sgd = b.init_params(7).unwrap();
        let mut p_sca = p_sgd.clone();
        let l1 = b.train_step(&mut p_sgd, &x, &y, 0.05).unwrap();
        let l2 = b.train_step_scaffold(&mut p_sca, &zeros, &zeros, &x, &y, 0.05).unwrap();
        assert_eq!(l1, l2);
        for (a, c) in p_sgd.iter().zip(&p_sca) {
            assert_eq!(a.data, c.data);
        }
    }

    #[test]
    fn prox_mu_zero_equals_sgd() {
        let b = toy_backend();
        let (x, y) = fixed_batch(&b, 6);
        let global = b.init_params(8).unwrap();
        let mut p_sgd = b.init_params(7).unwrap();
        let mut p_prox = p_sgd.clone();
        let l1 = b.train_step(&mut p_sgd, &x, &y, 0.05).unwrap();
        let l2 = b.train_step_prox(&mut p_prox, &global, &x, &y, 0.05, 0.0).unwrap();
        assert_eq!(l1, l2);
        for (a, c) in p_sgd.iter().zip(&p_prox) {
            assert_eq!(a.data, c.data);
        }
    }

    #[test]
    fn bad_shapes_are_rejected() {
        let b = toy_backend();
        let mut params = b.init_params(0).unwrap();
        assert!(b.train_step(&mut params, &[0.0; 3], &[0], 0.1).is_err());
        let (x, y) = fixed_batch(&b, 1);
        let mut short = params[..2].to_vec();
        assert!(b.train_step(&mut short, &x, &y, 0.1).is_err());
    }
}
