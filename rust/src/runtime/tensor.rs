//! Host-side tensors and conversion to/from XLA literals.

use anyhow::Result;
use xla::{ElementType, Literal};

/// A dense f32 host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Copy into an XLA literal of the same shape (f32).
    pub fn to_literal(&self) -> Result<Literal> {
        f32_literal(&self.shape, &self.data)
    }

    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(HostTensor { shape: dims, data: lit.to_vec::<f32>()? })
    }
}

/// Build an f32 literal from raw data without intermediate reshape copies.
pub fn f32_literal(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)?)
}

/// Build an i32 literal (labels).
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)?)
}

pub fn f32_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn u32_scalar(v: u32) -> Literal {
    Literal::scalar(v)
}

/// Read a scalar f32 out of a literal (accepts rank-0 or single-element).
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let t = HostTensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn i32_and_scalars() {
        let lit = i32_literal(&[4], &[1, 2, 3, 4]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        let s = f32_scalar(2.5);
        assert_eq!(scalar_f32(&s).unwrap(), 2.5);
        let u = u32_scalar(7);
        assert_eq!(u.get_first_element::<u32>().unwrap(), 7);
    }

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }
}
