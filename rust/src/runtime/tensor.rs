//! Host-side tensors, and (under `--features pjrt`) conversion to/from XLA
//! literals.

#[cfg(feature = "pjrt")]
use anyhow::Result;
#[cfg(feature = "pjrt")]
use xla::{ElementType, Literal};

/// A dense f32 host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Copy into an XLA literal of the same shape (f32).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<Literal> {
        f32_literal(&self.shape, &self.data)
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(HostTensor { shape: dims, data: lit.to_vec::<f32>()? })
    }
}

/// Build an f32 literal from raw data without intermediate reshape copies.
#[cfg(feature = "pjrt")]
pub fn f32_literal(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)?)
}

/// Build an i32 literal (labels).
#[cfg(feature = "pjrt")]
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)?)
}

#[cfg(feature = "pjrt")]
pub fn f32_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

#[cfg(feature = "pjrt")]
pub fn u32_scalar(v: u32) -> Literal {
    Literal::scalar(v)
}

/// Read a scalar f32 out of a literal (accepts rank-0 or single-element).
#[cfg(feature = "pjrt")]
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.data.iter().all(|&v| v == 0.0));
        assert!(!t.is_empty());
        assert!(HostTensor::zeros(&[0]).is_empty());
    }

    #[test]
    fn from_vec_round_trip() {
        let t = HostTensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.len(), 6);
        let u = t.clone();
        assert_eq!(t, u);
    }
}
