//! The native model zoo: every architecture the presets reference, built
//! as a `ModelGraph` — no artifacts, no silent MLP fallback.
//!
//! `build(model, dataset)` is the single resolution point used by the
//! coordinator, the CLI, and `RunConfig::validate`: unknown model names
//! and model/dataset geometry mismatches are hard errors, never quiet
//! substitutions (the registry exists so layer-wise scheduling always
//! runs over the architecture the experiment names).

use anyhow::Result;

use super::graph::ModelGraph;
use super::native::{DEFAULT_BATCH, DEFAULT_CHUNK_K, DEFAULT_EVAL_BATCH};
use super::ops::{AvgPool2d, Conv2d, Dense, GroupNorm, LayerOp, MaxPool2d, Relu, Residual};
use crate::data::DatasetKind;

/// Every model name the native engine can build.
pub const MODELS: &[&str] = &["mlp", "femnist_cnn", "cifar_cnn100", "resnet20"];

pub fn is_known(model: &str) -> bool {
    MODELS.contains(&model)
}

/// The dataset a model was designed for (used by `inspect` when the user
/// names only the model).
pub fn default_dataset(model: &str) -> Option<DatasetKind> {
    match model {
        "mlp" => Some(DatasetKind::Toy),
        "femnist_cnn" => Some(DatasetKind::Femnist),
        "cifar_cnn100" => Some(DatasetKind::Cifar100),
        "resnet20" => Some(DatasetKind::Cifar10),
        _ => None,
    }
}

/// Resolve a model name to a ready backend for `dataset`.
pub fn build(model: &str, kind: DatasetKind) -> Result<ModelGraph> {
    match model {
        "mlp" => Ok(ModelGraph::for_dataset(kind)),
        "femnist_cnn" => femnist_cnn(kind),
        "cifar_cnn100" => cifar_cnn100(kind),
        "resnet20" => resnet20(kind),
        other => anyhow::bail!(
            "unknown model {other:?}: native models are {MODELS:?} (the engine never \
             substitutes a different architecture silently)"
        ),
    }
}

fn require_input(model: &str, kind: DatasetKind, want: [usize; 3]) -> Result<()> {
    anyhow::ensure!(
        kind.input_shape() == want,
        "model {model} requires a {}x{}x{} input, but dataset {kind:?} provides {:?}",
        want[0],
        want[1],
        want[2],
        kind.input_shape()
    );
    Ok(())
}

/// ReLU MLP over the flattened input — the historical native backend,
/// bit-identical to the pre-graph implementation (same init streams, same
/// accumulation order).
pub fn mlp(
    input_shape: &[usize],
    hidden: &[usize],
    num_classes: usize,
    batch_size: usize,
    eval_batch_size: usize,
    chunk_k: usize,
) -> ModelGraph {
    let input_dim: usize = input_shape.iter().product();
    let mut dims = vec![input_dim];
    dims.extend_from_slice(hidden);
    dims.push(num_classes);
    let mut ops: Vec<Box<dyn LayerOp>> = Vec::new();
    for l in 0..dims.len() - 1 {
        ops.push(Box::new(Dense::new(&format!("fc{}", l + 1), dims[l], dims[l + 1])));
        if l + 2 < dims.len() {
            ops.push(Box::new(Relu::new(&format!("relu{}", l + 1))));
        }
    }
    ModelGraph::from_ops(
        "native-mlp",
        "mlp",
        input_shape,
        num_classes,
        batch_size,
        eval_batch_size,
        chunk_k,
        ops,
    )
    .expect("the MLP graph is always well-formed")
}

/// Small LeNet-style CNN for 28x28x1 FEMNIST: two conv+pool stages and a
/// dense head.
pub fn femnist_cnn(kind: DatasetKind) -> Result<ModelGraph> {
    require_input("femnist_cnn", kind, [28, 28, 1])?;
    let classes = kind.num_classes();
    let ops: Vec<Box<dyn LayerOp>> = vec![
        Box::new(Conv2d::new("conv1", [28, 28, 1], 8, 3, 1, 1)),
        Box::new(Relu::new("relu1")),
        Box::new(MaxPool2d::new("pool1", [28, 28, 8], 2)),
        Box::new(Conv2d::new("conv2", [14, 14, 8], 16, 3, 1, 1)),
        Box::new(Relu::new("relu2")),
        Box::new(MaxPool2d::new("pool2", [14, 14, 16], 2)),
        Box::new(Dense::new("fc1", 7 * 7 * 16, 64)),
        Box::new(Relu::new("relu3")),
        Box::new(Dense::new("fc2", 64, classes)),
    ];
    ModelGraph::from_ops(
        "native-femnist-cnn",
        "cnn",
        &[28, 28, 1],
        classes,
        DEFAULT_BATCH,
        DEFAULT_EVAL_BATCH,
        DEFAULT_CHUNK_K,
        ops,
    )
}

/// VGG-style CNN for 32x32x3 inputs (the paper's CIFAR-100 stand-in):
/// three conv stages with group-normed stem, then a dense head.
pub fn cifar_cnn100(kind: DatasetKind) -> Result<ModelGraph> {
    require_input("cifar_cnn100", kind, [32, 32, 3])?;
    let classes = kind.num_classes();
    let ops: Vec<Box<dyn LayerOp>> = vec![
        Box::new(Conv2d::new("conv1", [32, 32, 3], 16, 3, 1, 1)),
        Box::new(GroupNorm::new("gn1", [32, 32, 16], 4)),
        Box::new(Relu::new("relu1")),
        Box::new(MaxPool2d::new("pool1", [32, 32, 16], 2)),
        Box::new(Conv2d::new("conv2", [16, 16, 16], 32, 3, 1, 1)),
        Box::new(Relu::new("relu2")),
        Box::new(MaxPool2d::new("pool2", [16, 16, 32], 2)),
        Box::new(Conv2d::new("conv3", [8, 8, 32], 32, 3, 1, 1)),
        Box::new(Relu::new("relu3")),
        Box::new(AvgPool2d::new("pool3", [8, 8, 32], 2)),
        Box::new(Dense::new("fc1", 4 * 4 * 32, 128)),
        Box::new(Relu::new("relu4")),
        Box::new(Dense::new("fc2", 128, classes)),
    ];
    ModelGraph::from_ops(
        "native-cifar-cnn",
        "cnn",
        &[32, 32, 3],
        classes,
        DEFAULT_BATCH,
        DEFAULT_EVAL_BATCH,
        DEFAULT_CHUNK_K,
        ops,
    )
}

/// ResNet-20 (CIFAR variant, GroupNorm instead of BatchNorm): 3x3 stem,
/// three stages of three residual blocks at widths 16/32/64 with strided
/// projection transitions, global average pooling, dense head.  Uses a
/// smaller batch than the MLPs — each step is ~50x the compute.
pub fn resnet20(kind: DatasetKind) -> Result<ModelGraph> {
    require_input("resnet20", kind, [32, 32, 3])?;
    let classes = kind.num_classes();
    let mut ops: Vec<Box<dyn LayerOp>> = vec![
        Box::new(Conv2d::new("stem", [32, 32, 3], 16, 3, 1, 1)),
        Box::new(GroupNorm::new("stem_gn", [32, 32, 16], 4)),
        Box::new(Relu::new("stem_relu")),
    ];
    let widths = [16usize, 32, 64];
    let mut shape = [32usize, 32, 16];
    for (si, &cout) in widths.iter().enumerate() {
        for bi in 0..3 {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let name = format!("s{}b{}", si + 1, bi + 1);
            ops.push(Box::new(res_block(&name, shape, cout, stride)?));
            ops.push(Box::new(Relu::new(&format!("{name}_relu"))));
            shape = [shape[0] / stride, shape[1] / stride, cout];
        }
    }
    ops.push(Box::new(AvgPool2d::new("gap", [8, 8, 64], 8)));
    ops.push(Box::new(Dense::new("fc", 64, classes)));
    ModelGraph::from_ops("native-resnet20", "resnet", &[32, 32, 3], classes, 8, 16, 2, ops)
}

/// One pre-head ResNet basic block: conv-gn-relu-conv-gn plus an
/// identity or 1x1-projection skip (the graph adds the post-add ReLU).
fn res_block(name: &str, in_shape: [usize; 3], cout: usize, stride: usize) -> Result<Residual> {
    let [h, w, cin] = in_shape;
    let (oh, ow) = (h / stride, w / stride);
    let body: Vec<Box<dyn LayerOp>> = vec![
        Box::new(Conv2d::new("c1", in_shape, cout, 3, stride, 1)),
        Box::new(GroupNorm::new("gn1", [oh, ow, cout], 4)),
        Box::new(Relu::new("relu")),
        Box::new(Conv2d::new("c2", [oh, ow, cout], cout, 3, 1, 1)),
        Box::new(GroupNorm::new("gn2", [oh, ow, cout], 4)),
    ];
    let proj = if stride != 1 || cin != cout {
        Some(Conv2d::new("proj", in_shape, cout, 1, stride, 0))
    } else {
        None
    };
    Residual::new(name, &[h, w, cin], body, proj)
}

#[cfg(test)]
mod tests {
    use super::super::native::DEFAULT_HIDDEN;
    use super::*;

    #[test]
    fn registry_knows_every_preset_model() {
        for m in ["mlp", "femnist_cnn", "cifar_cnn100", "resnet20"] {
            assert!(is_known(m), "{m} missing from registry");
            let kind = default_dataset(m).unwrap();
            let g = build(m, kind).unwrap();
            g.manifest().validate().unwrap();
        }
        assert!(!is_known("vgg16"));
        assert!(default_dataset("vgg16").is_none());
    }

    #[test]
    fn unknown_model_errors_loudly() {
        let err = build("resnet999", DatasetKind::Cifar10).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown model"), "{msg}");
        assert!(msg.contains("resnet20"), "should list known models: {msg}");
    }

    #[test]
    fn geometry_mismatches_are_rejected() {
        assert!(build("femnist_cnn", DatasetKind::Toy).is_err());
        assert!(build("resnet20", DatasetKind::Femnist).is_err());
        assert!(build("cifar_cnn100", DatasetKind::Cifar10).is_ok(), "any 32x32x3 dataset works");
    }

    #[test]
    fn femnist_cnn_manifest() {
        let g = femnist_cnn(DatasetKind::Femnist).unwrap();
        let m = g.manifest();
        assert_eq!(m.model, "native-femnist-cnn");
        assert_eq!(m.input_shape, vec![28, 28, 1]);
        assert_eq!(m.num_classes, 62);
        assert_eq!(m.groups.len(), 4); // conv1 conv2 fc1 fc2
        assert_eq!(m.params[0].shape, vec![9, 8]);
    }

    #[test]
    fn resnet20_manifest_has_real_layers() {
        let g = resnet20(DatasetKind::Cifar10).unwrap();
        let m = g.manifest();
        assert_eq!(m.model, "native-resnet20");
        // stem + stem_gn + 9 residual blocks + fc
        assert_eq!(m.groups.len(), 12);
        assert!(m.num_tensors() >= 20, "only {} tensors", m.num_tensors());
        // stage-transition blocks carry projection tensors
        assert!(m.params.iter().any(|p| p.name == "s2b1.proj.w"));
        assert!(m.params.iter().any(|p| p.name == "s3b1.gn2.b"));
        // heterogeneous group dims — the signal layer-wise scheduling needs
        let dims: std::collections::BTreeSet<usize> = m.groups.iter().map(|g| g.dim).collect();
        assert!(dims.len() >= 5, "group dims too uniform: {dims:?}");
        // classes follow the dataset
        let g100 = resnet20(DatasetKind::Cifar100).unwrap();
        assert_eq!(g100.manifest().num_classes, 100);
    }

    #[test]
    fn mlp_matches_historical_layout() {
        let g = mlp(&[64], &DEFAULT_HIDDEN, 10, DEFAULT_BATCH, DEFAULT_EVAL_BATCH, DEFAULT_CHUNK_K);
        let m = g.manifest();
        assert_eq!(m.model, "native-mlp");
        assert_eq!(m.groups.len(), 3);
        assert_eq!(m.params[0].name, "fc1.w");
        assert_eq!(m.params[5].name, "fc3.b");
        assert_eq!(m.num_params, 64 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10);
    }

    #[test]
    fn mlp_manifest_matches_synthetic_mlp() {
        // Pin the graph-derived MLP manifest to the historical
        // `Manifest::synthetic_mlp` layout reference so the two can never
        // silently drift.
        use crate::runtime::manifest::Manifest;
        let g = mlp(&[32, 32, 3], &DEFAULT_HIDDEN, 10, 8, 32, 2);
        let reference = Manifest::synthetic_mlp(&[32, 32, 3], &DEFAULT_HIDDEN, 10, 8, 32, 2);
        let m = g.manifest();
        assert_eq!(m.num_params, reference.num_params);
        assert_eq!(m.input_shape, reference.input_shape);
        assert_eq!(m.params.len(), reference.params.len());
        for (a, b) in m.params.iter().zip(&reference.params) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.group, b.group);
        }
        for (a, b) in m.groups.iter().zip(&reference.groups) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.params, b.params);
            assert_eq!(a.dim, b.dim);
        }
    }
}
