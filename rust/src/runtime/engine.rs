//! PJRT execution engine: load AOT HLO-text artifacts, compile once, run.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT).  All entry points
//! were lowered with `return_tuple=True`, so every execution returns one
//! tuple literal which is decomposed into the per-output literals here.
//!
//! NOTE: `PjRtClient` is `Rc`-based (not `Send`), so an `Engine` and
//! everything compiled from it must stay on one thread.  Accordingly
//! `ComputeBackend::as_parallel` returns `None` for `ModelRuntime` and the
//! cluster runtime (`runtime::cluster`) keeps this backend serial; only the
//! `Sync` native backend fans out across worker threads.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::{ComputeBackend, RuntimeStats};
use super::manifest::Manifest;
use super::tensor::{f32_literal, f32_scalar, i32_literal, scalar_f32, u32_scalar, HostTensor};

#[derive(Clone)]
pub struct Engine {
    client: PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it for this device.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        log::debug!("compiled {} in {:.2}s", path.display(), t0.elapsed().as_secs_f64());
        Ok(Executable { exe, name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned() })
    }
}

pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute and decompose the tuple result into per-output literals.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let bufs = self.exe.execute::<Literal>(inputs)?;
        let mut lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }

    /// Execute with borrowed inputs.
    pub fn run_ref(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let bufs = self.exe.execute::<&Literal>(inputs)?;
        let mut lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }
}

/// A model's complete compiled runtime: every AOT entry point + the Pallas
/// aggregation kernels, plus parameter-shape knowledge from the manifest.
pub struct ModelRuntime {
    pub engine: Engine,
    pub manifest: Rc<Manifest>,
    init: Executable,
    train_step: Executable,
    train_chunk: Option<Executable>,
    eval_step: Executable,
    /// Lazily compiled: train_step_prox, train_step_scaffold, grad_step.
    lazy: RefCell<HashMap<&'static str, Rc<Executable>>>,
    /// Pallas fused aggregation kernels, compiled on first use per (dim, m).
    agg: RefCell<HashMap<(usize, usize), Option<Rc<Executable>>>>,
    pub stats: RefCell<RuntimeStats>,
}

impl ModelRuntime {
    /// Compile the core entry points for the model artifacts in `model_dir`.
    pub fn load(model_dir: &Path) -> Result<ModelRuntime> {
        let engine = Engine::cpu()?;
        Self::load_with_engine(engine, model_dir)
    }

    pub fn load_with_engine(engine: Engine, model_dir: &Path) -> Result<ModelRuntime> {
        let manifest = Rc::new(Manifest::load(model_dir)?);
        let init = engine.load_hlo(&manifest.entry_path("init")?)?;
        let train_step = engine.load_hlo(&manifest.entry_path("train_step")?)?;
        let train_chunk = match manifest.entry_path("train_chunk") {
            Ok(p) if p.exists() => Some(engine.load_hlo(&p)?),
            _ => None,
        };
        let eval_step = engine.load_hlo(&manifest.entry_path("eval_step")?)?;
        Ok(ModelRuntime {
            engine,
            manifest,
            init,
            train_step,
            train_chunk,
            eval_step,
            lazy: RefCell::new(HashMap::new()),
            agg: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn chunk_k(&self) -> usize {
        if self.train_chunk.is_some() {
            self.manifest.chunk_k
        } else {
            1
        }
    }

    fn lazy_entry(&self, name: &'static str) -> Result<Rc<Executable>> {
        if let Some(e) = self.lazy.borrow().get(name) {
            return Ok(e.clone());
        }
        let exe = Rc::new(self.engine.load_hlo(&self.manifest.entry_path(name)?)?);
        self.lazy.borrow_mut().insert(name, exe.clone());
        Ok(exe)
    }

    /// Deterministic parameter init from a seed.
    pub fn init_params(&self, seed: u32) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let outs = self.init.run(&[u32_scalar(seed)])?;
        self.stats.borrow_mut().record("init", t0.elapsed().as_secs_f64());
        anyhow::ensure!(outs.len() == self.manifest.num_tensors(), "init arity");
        outs.iter().map(HostTensor::from_literal).collect()
    }

    /// One local SGD step in-place; returns the batch loss.
    pub fn train_step(
        &self,
        params: &mut [HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let t0 = Instant::now();
        let m = &self.manifest;
        let b = m.batch_size;
        let mut inputs = Vec::with_capacity(params.len() + 3);
        for p in params.iter() {
            inputs.push(p.to_literal()?);
        }
        let mut xshape = vec![b];
        xshape.extend_from_slice(&m.input_shape);
        inputs.push(f32_literal(&xshape, x)?);
        inputs.push(i32_literal(&[b], y)?);
        inputs.push(f32_scalar(lr));
        let outs = self.train_step.run(&inputs)?;
        anyhow::ensure!(outs.len() == params.len() + 1, "train_step arity");
        for (p, lit) in params.iter_mut().zip(&outs) {
            lit.copy_raw_to(&mut p.data)?;
        }
        let loss = scalar_f32(&outs[params.len()])?;
        self.stats.borrow_mut().record("train_step", t0.elapsed().as_secs_f64());
        Ok(loss)
    }

    /// K fused local SGD steps (K = manifest.chunk_k); xs is [K*B*inp],
    /// ys is [K*B].  Returns the K per-step losses.
    pub fn train_chunk(
        &self,
        params: &mut [HostTensor],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        let chunk = self.train_chunk.as_ref().context("no train_chunk artifact")?;
        let t0 = Instant::now();
        let m = &self.manifest;
        let (k, b) = (m.chunk_k, m.batch_size);
        let mut inputs = Vec::with_capacity(params.len() + 3);
        for p in params.iter() {
            inputs.push(p.to_literal()?);
        }
        let mut xshape = vec![k, b];
        xshape.extend_from_slice(&m.input_shape);
        inputs.push(f32_literal(&xshape, xs)?);
        inputs.push(i32_literal(&[k, b], ys)?);
        inputs.push(f32_scalar(lr));
        let outs = chunk.run(&inputs)?;
        anyhow::ensure!(outs.len() == params.len() + 1, "train_chunk arity");
        for (p, lit) in params.iter_mut().zip(&outs) {
            lit.copy_raw_to(&mut p.data)?;
        }
        let losses = outs[params.len()].to_vec::<f32>()?;
        self.stats.borrow_mut().record("train_chunk", t0.elapsed().as_secs_f64());
        Ok(losses)
    }

    /// FedProx local step: adds the mu/2 * ||p - global||^2 term.
    pub fn train_step_prox(
        &self,
        params: &mut [HostTensor],
        global: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<f32> {
        let exe = self.lazy_entry("train_step_prox")?;
        let t0 = Instant::now();
        let m = &self.manifest;
        let b = m.batch_size;
        let mut inputs = Vec::with_capacity(2 * params.len() + 4);
        for p in params.iter() {
            inputs.push(p.to_literal()?);
        }
        for g in global.iter() {
            inputs.push(g.to_literal()?);
        }
        let mut xshape = vec![b];
        xshape.extend_from_slice(&m.input_shape);
        inputs.push(f32_literal(&xshape, x)?);
        inputs.push(i32_literal(&[b], y)?);
        inputs.push(f32_scalar(lr));
        inputs.push(f32_scalar(mu));
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == params.len() + 1, "train_step_prox arity");
        for (p, lit) in params.iter_mut().zip(&outs) {
            lit.copy_raw_to(&mut p.data)?;
        }
        let loss = scalar_f32(&outs[params.len()])?;
        self.stats.borrow_mut().record("train_step_prox", t0.elapsed().as_secs_f64());
        Ok(loss)
    }

    /// SCAFFOLD local step: p <- p - lr*(g - c_i + c).
    pub fn train_step_scaffold(
        &self,
        params: &mut [HostTensor],
        ci: &[HostTensor],
        c: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let exe = self.lazy_entry("train_step_scaffold")?;
        let t0 = Instant::now();
        let m = &self.manifest;
        let b = m.batch_size;
        let mut inputs = Vec::with_capacity(3 * params.len() + 3);
        for set in [&params[..], ci, c] {
            for p in set.iter() {
                inputs.push(p.to_literal()?);
            }
        }
        let mut xshape = vec![b];
        xshape.extend_from_slice(&m.input_shape);
        inputs.push(f32_literal(&xshape, x)?);
        inputs.push(i32_literal(&[b], y)?);
        inputs.push(f32_scalar(lr));
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == params.len() + 1, "train_step_scaffold arity");
        for (p, lit) in params.iter_mut().zip(&outs) {
            lit.copy_raw_to(&mut p.data)?;
        }
        let loss = scalar_f32(&outs[params.len()])?;
        self.stats.borrow_mut().record("train_step_scaffold", t0.elapsed().as_secs_f64());
        Ok(loss)
    }

    /// Full-batch gradients (FedNova + gradient tests).
    pub fn grad_step(
        &self,
        params: &[HostTensor],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        let exe = self.lazy_entry("grad_step")?;
        let t0 = Instant::now();
        let m = &self.manifest;
        let b = m.batch_size;
        let mut inputs = Vec::with_capacity(params.len() + 2);
        for p in params.iter() {
            inputs.push(p.to_literal()?);
        }
        let mut xshape = vec![b];
        xshape.extend_from_slice(&m.input_shape);
        inputs.push(f32_literal(&xshape, x)?);
        inputs.push(i32_literal(&[b], y)?);
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == params.len() + 1, "grad_step arity");
        let grads =
            outs[..params.len()].iter().map(HostTensor::from_literal).collect::<Result<Vec<_>>>()?;
        let loss = scalar_f32(&outs[params.len()])?;
        self.stats.borrow_mut().record("grad_step", t0.elapsed().as_secs_f64());
        Ok((grads, loss))
    }

    /// Evaluate one batch: returns (correct_count, loss_sum).
    pub fn eval_step(&self, params: &[HostTensor], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        let m = &self.manifest;
        let b = m.eval_batch_size;
        let mut inputs = Vec::with_capacity(params.len() + 2);
        for p in params.iter() {
            inputs.push(p.to_literal()?);
        }
        let mut xshape = vec![b];
        xshape.extend_from_slice(&m.input_shape);
        inputs.push(f32_literal(&xshape, x)?);
        inputs.push(i32_literal(&[b], y)?);
        let outs = self.eval_step.run(&inputs)?;
        anyhow::ensure!(outs.len() == 2, "eval_step arity");
        let res = (scalar_f32(&outs[0])?, scalar_f32(&outs[1])?);
        self.stats.borrow_mut().record("eval_step", t0.elapsed().as_secs_f64());
        Ok(res)
    }

    /// The Pallas fused aggregation kernel for (dim, m) if AOT-compiled;
    /// compiled once on first use, then cached.  Returns None when the
    /// artifact set has no kernel for this configuration (callers fall
    /// back to the native backend).
    pub fn agg_kernel(&self, dim: usize, m: usize) -> Option<Rc<Executable>> {
        if let Some(cached) = self.agg.borrow().get(&(dim, m)) {
            return cached.clone();
        }
        let compiled = self.manifest.agg_path(dim, m).and_then(|p| {
            if !p.exists() {
                return None;
            }
            match self.engine.load_hlo(&p) {
                Ok(e) => Some(Rc::new(e)),
                Err(e) => {
                    log::warn!("agg kernel {} failed to compile: {e:#}", p.display());
                    None
                }
            }
        });
        self.agg.borrow_mut().insert((dim, m), compiled.clone());
        compiled
    }

    /// Run the fused Pallas aggregation: stack is m*dim (row-major),
    /// weights is length m.  Returns (u[dim], discrepancy).
    pub fn run_agg(
        &self,
        exe: &Executable,
        stack: &[f32],
        weights: &[f32],
        dim: usize,
    ) -> Result<(Vec<f32>, f32)> {
        let t0 = Instant::now();
        let m = weights.len();
        debug_assert_eq!(stack.len(), m * dim);
        let xs = f32_literal(&[m, dim], stack)?;
        let ws = f32_literal(&[m], weights)?;
        let outs = exe.run(&[xs, ws])?;
        anyhow::ensure!(outs.len() == 2, "agg arity");
        let u = outs[0].to_vec::<f32>()?;
        let disc = scalar_f32(&outs[1])?;
        self.stats.borrow_mut().record("agg", t0.elapsed().as_secs_f64());
        Ok((u, disc))
    }
}

/// The PJRT engine as a coordinator compute backend.  `Rc`-based and
/// therefore thread-confined: `as_parallel` stays `None` and the
/// coordinator runs clients serially on this backend.
impl ComputeBackend for ModelRuntime {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init_params(&self, seed: u32) -> Result<Vec<HostTensor>> {
        ModelRuntime::init_params(self, seed)
    }

    fn train_step(
        &self,
        params: &mut [HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        ModelRuntime::train_step(self, params, x, y, lr)
    }

    fn train_step_prox(
        &self,
        params: &mut [HostTensor],
        global: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<f32> {
        ModelRuntime::train_step_prox(self, params, global, x, y, lr, mu)
    }

    fn train_step_scaffold(
        &self,
        params: &mut [HostTensor],
        ci: &[HostTensor],
        c: &[HostTensor],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        ModelRuntime::train_step_scaffold(self, params, ci, c, x, y, lr)
    }

    fn grad_step(
        &self,
        params: &[HostTensor],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<HostTensor>, f32)> {
        ModelRuntime::grad_step(self, params, x, y)
    }

    fn eval_step(&self, params: &[HostTensor], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        ModelRuntime::eval_step(self, params, x, y)
    }

    fn train_chunk(
        &self,
        params: &mut [HostTensor],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        ModelRuntime::train_chunk(self, params, xs, ys, lr)
    }

    fn chunk_k(&self) -> usize {
        ModelRuntime::chunk_k(self)
    }

    fn fused_agg(
        &self,
        stack: &[f32],
        weights: &[f32],
        dim: usize,
    ) -> Result<Option<(Vec<f32>, f32)>> {
        match self.agg_kernel(dim, weights.len()) {
            Some(exe) => self.run_agg(&exe, stack, weights, dim).map(Some),
            None => Ok(None),
        }
    }

    fn has_fused_agg(&self, dim: usize, m: usize) -> bool {
        self.agg_kernel(dim, m).is_some()
    }

    fn stats_total_secs(&self) -> f64 {
        self.stats.borrow().total_secs()
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}
