//! FedLAMA's core: layer-wise discrepancy, Algorithm 2 interval
//! adjustment, schedule state, and the aggregation compute backends.

pub mod backend;
pub mod discrepancy;
pub mod interval;
pub mod policy;

pub use backend::{aggregate_group, AggBackend, AggScratch};
pub use discrepancy::{aggregate_native, aggregate_native_with, unit_discrepancy};
pub use interval::{adjust_intervals, adjust_intervals_accelerate, Adjustment};
pub use policy::{Policy, Schedule};
