//! FedLAMA's core: layer-wise discrepancy, Algorithm 2 interval
//! adjustment, schedule state, the aggregation compute backends, and the
//! Byzantine-robust reducers screening each group's fold.

pub mod backend;
pub mod discrepancy;
pub mod interval;
pub mod policy;
pub mod robust;

pub use backend::{aggregate_group, AggBackend, AggScratch};
pub use discrepancy::{aggregate_native, aggregate_native_with, unit_discrepancy};
pub use interval::{adjust_intervals, adjust_intervals_accelerate, Adjustment};
pub use policy::{Policy, Schedule};
pub use robust::RobustSpec;
