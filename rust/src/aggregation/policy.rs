//! Aggregation policies: which groups sync at iteration k, and how
//! intervals evolve (Algorithm 1's schedule state machine).

use super::interval::{adjust_intervals, adjust_intervals_accelerate, Adjustment};

/// Aggregation scheduling policy.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// Periodic full aggregation with a fixed interval (FedAvg & friends).
    FullSync { interval: usize },
    /// FedLAMA (Algorithm 1): per-group intervals in {tau, phi*tau},
    /// re-adjusted every phi*tau iterations from observed discrepancies.
    FedLama { tau: usize, phi: usize, accelerate: bool },
}

impl Policy {
    pub fn fedavg(interval: usize) -> Policy {
        Policy::FullSync { interval }
    }
    pub fn fedlama(tau: usize, phi: usize) -> Policy {
        Policy::FedLama { tau, phi, accelerate: false }
    }

    /// The period after which the whole model is guaranteed synchronized
    /// (round boundary: client re-sampling + eval happen here).
    pub fn round_len(&self) -> usize {
        match self {
            Policy::FullSync { interval } => *interval,
            Policy::FedLama { tau, phi, .. } => tau * phi,
        }
    }

    pub fn base_interval(&self) -> usize {
        match self {
            Policy::FullSync { interval } => *interval,
            Policy::FedLama { tau, .. } => *tau,
        }
    }
}

/// Live schedule state for one training run.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub policy: Policy,
    /// Current per-group intervals tau_l.
    pub intervals: Vec<usize>,
    /// Latest observed unit discrepancy per group (Eq. 2), refreshed at
    /// each group sync.
    pub last_unit_disc: Vec<f64>,
    /// Group dims (for Algorithm 2).
    dims: Vec<usize>,
    /// History of adjustments (for Figure 1 and diagnostics).
    pub adjustments: Vec<Adjustment>,
}

impl Schedule {
    pub fn new(policy: Policy, dims: Vec<usize>) -> Schedule {
        let l = dims.len();
        let tau = policy.base_interval();
        Schedule {
            policy,
            intervals: vec![tau; l],
            last_unit_disc: vec![0.0; l],
            dims,
            adjustments: Vec::new(),
        }
    }

    /// Groups due for aggregation at iteration k (1-based, as Algorithm 1).
    pub fn due_groups(&self, k: usize) -> Vec<usize> {
        (0..self.intervals.len()).filter(|&g| k % self.intervals[g] == 0).collect()
    }

    /// Is iteration k a round boundary (full model synchronized)?
    pub fn is_round_boundary(&self, k: usize) -> bool {
        k % self.policy.round_len() == 0
    }

    /// Record the discrepancy observed when group g synced at interval
    /// tau_g (Algorithm 1 line 7): d_l = disc / (tau_l * dim_l).
    pub fn observe(&mut self, g: usize, disc: f64) {
        self.last_unit_disc[g] =
            super::discrepancy::unit_discrepancy(disc, self.intervals[g], self.dims[g]);
    }

    /// Algorithm 1 line 8-9: at round boundaries, re-run Algorithm 2.
    /// No-op for FullSync and for phi == 1.
    pub fn maybe_adjust(&mut self, k: usize) {
        let Policy::FedLama { tau, phi, accelerate } = self.policy else {
            return;
        };
        if phi == 1 || k % (tau * phi) != 0 {
            return;
        }
        let adj = if accelerate {
            adjust_intervals_accelerate(&self.last_unit_disc, &self.dims, tau, phi)
        } else {
            adjust_intervals(&self.last_unit_disc, &self.dims, tau, phi)
        };
        self.intervals = adj.intervals.clone();
        self.adjustments.push(adj);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fullsync_schedule() {
        let s = Schedule::new(Policy::fedavg(6), vec![10, 20, 30]);
        assert!(s.due_groups(5).is_empty());
        assert_eq!(s.due_groups(6), vec![0, 1, 2]);
        assert_eq!(s.due_groups(12), vec![0, 1, 2]);
        assert!(s.is_round_boundary(6));
        assert!(!s.is_round_boundary(7));
    }

    #[test]
    fn fedlama_starts_at_base_interval() {
        let s = Schedule::new(Policy::fedlama(6, 4), vec![10, 20]);
        assert_eq!(s.intervals, vec![6, 6]);
        assert_eq!(s.policy.round_len(), 24);
    }

    #[test]
    fn adjustment_splits_intervals() {
        let mut s = Schedule::new(Policy::fedlama(6, 4), vec![100, 100_000]);
        // big layer has tiny discrepancy -> relaxed after adjustment
        s.observe(0, 600.0); // unit = 600/(6*100) = 1.0
        s.observe(1, 600.0); // unit = 600/(6*100000) = 0.001
        s.maybe_adjust(23); // not a boundary -> no-op
        assert_eq!(s.intervals, vec![6, 6]);
        s.maybe_adjust(24);
        assert_eq!(s.intervals, vec![6, 24]);
        assert_eq!(s.adjustments.len(), 1);
        // due groups under mixed intervals
        assert_eq!(s.due_groups(30), vec![0]);
        assert_eq!(s.due_groups(48), vec![0, 1]);
    }

    #[test]
    fn phi_one_never_adjusts() {
        let mut s = Schedule::new(Policy::fedlama(6, 1), vec![10, 10]);
        s.observe(0, 1.0);
        s.observe(1, 100.0);
        s.maybe_adjust(6);
        assert!(s.adjustments.is_empty());
        assert_eq!(s.intervals, vec![6, 6]);
    }

    #[test]
    fn full_sync_guaranteed_every_round() {
        let mut s = Schedule::new(Policy::fedlama(3, 2), vec![50, 50, 50]);
        s.observe(0, 0.01);
        s.observe(1, 5.0);
        s.observe(2, 5.0);
        s.maybe_adjust(6);
        // whatever the intervals, every group is due at k = 6m
        for k in [6, 12, 18, 24] {
            assert_eq!(s.due_groups(k).len(), 3, "full sync at {k}");
        }
    }

    #[test]
    fn observe_normalizes_by_interval_and_dim() {
        let mut s = Schedule::new(Policy::fedlama(5, 2), vec![4]);
        s.observe(0, 40.0);
        assert!((s.last_unit_disc[0] - 2.0).abs() < 1e-12); // 40/(5*4)
    }
}
