//! Aggregation policies: which groups sync at iteration k, and how
//! intervals evolve (Algorithm 1's schedule state machine).
//!
//! Beyond the paper's FullSync/FedLAMA pair, the zoo adds two related-work
//! policies behind the same seam:
//!
//!   - [`Policy::DivergenceFeedback`] (FedLDF, arXiv 2404.08324): FedLAMA
//!     scheduling, but a group whose last *measured* unit discrepancy fell
//!     below `threshold` skips its next mid-round uplink entirely — zero
//!     bytes on the wire, zero Eq.9 charge.  Round boundaries still sync
//!     every group, so the full model is synchronized once per round and
//!     each group's discrepancy measurement refreshes at least that often
//!     (a permanently-quiet layer can wake back up).  `threshold == 0`
//!     never skips (discrepancies are non-negative), making the policy
//!     byte-identical to plain FedLAMA.
//!   - [`Policy::Personalized`] (pFedLA, arXiv 2205.03993): FullSync
//!     scheduling, but the coordinator maintains per-client layer mixing
//!     weights lambda updated at each sync point; clients blend the
//!     aggregate into their local params instead of adopting it outright.
//!     The schedule itself is plain periodic — the personalization lives
//!     in the decision fan-out and the client registry.

use super::interval::{adjust_intervals, adjust_intervals_accelerate, Adjustment};

/// Aggregation scheduling policy.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// Periodic full aggregation with a fixed interval (FedAvg & friends).
    FullSync { interval: usize },
    /// FedLAMA (Algorithm 1): per-group intervals in {tau, phi*tau},
    /// re-adjusted every phi*tau iterations from observed discrepancies.
    FedLama { tau: usize, phi: usize, accelerate: bool },
    /// FedLDF-style divergence feedback: FedLAMA intervals plus a
    /// per-group uplink skip when the measured unit discrepancy is below
    /// `threshold` (mid-round blocks only; round boundaries always sync).
    DivergenceFeedback { tau: usize, phi: usize, threshold: f64 },
    /// pFedLA-style personalized aggregation: periodic full sync with
    /// per-client layer mixing weights, moved toward each client's
    /// agreement with the aggregate at rate `eta` per sync.
    Personalized { interval: usize, eta: f64 },
}

impl Policy {
    pub fn fedavg(interval: usize) -> Policy {
        Policy::FullSync { interval }
    }
    pub fn fedlama(tau: usize, phi: usize) -> Policy {
        Policy::FedLama { tau, phi, accelerate: false }
    }
    pub fn divergence_feedback(tau: usize, phi: usize, threshold: f64) -> Policy {
        Policy::DivergenceFeedback { tau, phi, threshold }
    }
    pub fn personalized(interval: usize, eta: f64) -> Policy {
        Policy::Personalized { interval, eta }
    }

    /// The period after which the whole model is guaranteed synchronized
    /// (round boundary: client re-sampling + eval happen here).
    pub fn round_len(&self) -> usize {
        match self {
            Policy::FullSync { interval } => *interval,
            Policy::FedLama { tau, phi, .. } => tau * phi,
            Policy::DivergenceFeedback { tau, phi, .. } => tau * phi,
            Policy::Personalized { interval, .. } => *interval,
        }
    }

    pub fn base_interval(&self) -> usize {
        match self {
            Policy::FullSync { interval } => *interval,
            Policy::FedLama { tau, .. } => *tau,
            Policy::DivergenceFeedback { tau, .. } => *tau,
            Policy::Personalized { interval, .. } => *interval,
        }
    }

    /// The personalized mixing rate, if this policy personalizes.
    pub fn mix_eta(&self) -> Option<f64> {
        match self {
            Policy::Personalized { eta, .. } => Some(*eta),
            _ => None,
        }
    }
}

/// Live schedule state for one training run.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub policy: Policy,
    /// Current per-group intervals tau_l.
    pub intervals: Vec<usize>,
    /// Latest observed unit discrepancy per group (Eq. 2), refreshed at
    /// each group sync.
    pub last_unit_disc: Vec<f64>,
    /// Whether a group's discrepancy has ever been measured.  Divergence
    /// feedback only trusts `last_unit_disc` once it holds a real
    /// observation — the zero-initialized value must not suppress a
    /// group's very first sync.
    pub observed: Vec<bool>,
    /// Group dims (for Algorithm 2).
    dims: Vec<usize>,
    /// History of adjustments (for Figure 1 and diagnostics).
    pub adjustments: Vec<Adjustment>,
}

impl Schedule {
    pub fn new(policy: Policy, dims: Vec<usize>) -> Schedule {
        let l = dims.len();
        let tau = policy.base_interval();
        Schedule {
            policy,
            intervals: vec![tau; l],
            last_unit_disc: vec![0.0; l],
            observed: vec![false; l],
            dims,
            adjustments: Vec::new(),
        }
    }

    /// Groups due for aggregation at iteration k (1-based, as Algorithm 1).
    /// Under divergence feedback a group whose measured discrepancy sits
    /// below the threshold skips mid-round syncs — it transfers zero
    /// uplink bytes that block — but round boundaries always include it.
    pub fn due_groups(&self, k: usize) -> Vec<usize> {
        let boundary = self.is_round_boundary(k);
        (0..self.intervals.len())
            .filter(|&g| k % self.intervals[g] == 0)
            .filter(|&g| boundary || !self.skips_uplink(g))
            .collect()
    }

    /// Does group g currently skip its (mid-round) uplink?
    pub fn skips_uplink(&self, g: usize) -> bool {
        match self.policy {
            Policy::DivergenceFeedback { threshold, .. } => {
                self.observed[g] && self.last_unit_disc[g] < threshold
            }
            _ => false,
        }
    }

    /// Is iteration k a round boundary (full model synchronized)?
    pub fn is_round_boundary(&self, k: usize) -> bool {
        k % self.policy.round_len() == 0
    }

    /// Record the discrepancy observed when group g synced at interval
    /// tau_g (Algorithm 1 line 7): d_l = disc / (tau_l * dim_l).
    pub fn observe(&mut self, g: usize, disc: f64) {
        self.last_unit_disc[g] =
            super::discrepancy::unit_discrepancy(disc, self.intervals[g], self.dims[g]);
        self.observed[g] = true;
    }

    /// Algorithm 1 line 8-9: at round boundaries, re-run Algorithm 2.
    /// No-op for FullSync/Personalized and for phi == 1.  Divergence
    /// feedback keeps FedLAMA's interval adjustment (it is FedLAMA plus an
    /// uplink skip, so threshold = 0 stays bit-identical to FedLAMA).
    pub fn maybe_adjust(&mut self, k: usize) {
        let (tau, phi, accelerate) = match self.policy {
            Policy::FedLama { tau, phi, accelerate } => (tau, phi, accelerate),
            Policy::DivergenceFeedback { tau, phi, .. } => (tau, phi, false),
            _ => return,
        };
        if phi == 1 || k % (tau * phi) != 0 {
            return;
        }
        let adj = if accelerate {
            adjust_intervals_accelerate(&self.last_unit_disc, &self.dims, tau, phi)
        } else {
            adjust_intervals(&self.last_unit_disc, &self.dims, tau, phi)
        };
        self.intervals = adj.intervals.clone();
        self.adjustments.push(adj);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fullsync_schedule() {
        let s = Schedule::new(Policy::fedavg(6), vec![10, 20, 30]);
        assert!(s.due_groups(5).is_empty());
        assert_eq!(s.due_groups(6), vec![0, 1, 2]);
        assert_eq!(s.due_groups(12), vec![0, 1, 2]);
        assert!(s.is_round_boundary(6));
        assert!(!s.is_round_boundary(7));
    }

    #[test]
    fn fedlama_starts_at_base_interval() {
        let s = Schedule::new(Policy::fedlama(6, 4), vec![10, 20]);
        assert_eq!(s.intervals, vec![6, 6]);
        assert_eq!(s.policy.round_len(), 24);
    }

    #[test]
    fn adjustment_splits_intervals() {
        let mut s = Schedule::new(Policy::fedlama(6, 4), vec![100, 100_000]);
        // big layer has tiny discrepancy -> relaxed after adjustment
        s.observe(0, 600.0); // unit = 600/(6*100) = 1.0
        s.observe(1, 600.0); // unit = 600/(6*100000) = 0.001
        s.maybe_adjust(23); // not a boundary -> no-op
        assert_eq!(s.intervals, vec![6, 6]);
        s.maybe_adjust(24);
        assert_eq!(s.intervals, vec![6, 24]);
        assert_eq!(s.adjustments.len(), 1);
        // due groups under mixed intervals
        assert_eq!(s.due_groups(30), vec![0]);
        assert_eq!(s.due_groups(48), vec![0, 1]);
    }

    #[test]
    fn phi_one_never_adjusts() {
        let mut s = Schedule::new(Policy::fedlama(6, 1), vec![10, 10]);
        s.observe(0, 1.0);
        s.observe(1, 100.0);
        s.maybe_adjust(6);
        assert!(s.adjustments.is_empty());
        assert_eq!(s.intervals, vec![6, 6]);
    }

    #[test]
    fn full_sync_guaranteed_every_round() {
        let mut s = Schedule::new(Policy::fedlama(3, 2), vec![50, 50, 50]);
        s.observe(0, 0.01);
        s.observe(1, 5.0);
        s.observe(2, 5.0);
        s.maybe_adjust(6);
        // whatever the intervals, every group is due at k = 6m
        for k in [6, 12, 18, 24] {
            assert_eq!(s.due_groups(k).len(), 3, "full sync at {k}");
        }
    }

    #[test]
    fn observe_normalizes_by_interval_and_dim() {
        let mut s = Schedule::new(Policy::fedlama(5, 2), vec![4]);
        s.observe(0, 40.0);
        assert!((s.last_unit_disc[0] - 2.0).abs() < 1e-12); // 40/(5*4)
    }

    #[test]
    fn divergence_feedback_skips_quiet_groups_mid_round() {
        // tau = 3, phi = 2: groups due at k = 3 (mid-round) and k = 6
        // (round boundary)
        let mut s = Schedule::new(Policy::divergence_feedback(3, 2, 0.5), vec![10, 10]);
        // never measured: nothing skips, even under the threshold default
        assert_eq!(s.due_groups(3), vec![0, 1]);
        s.observe(0, 3.0); // unit = 3/(3*10) = 0.1 < 0.5 -> quiet
        s.observe(1, 300.0); // unit = 10.0 >= 0.5 -> loud
        assert!(s.skips_uplink(0));
        assert!(!s.skips_uplink(1));
        assert_eq!(s.due_groups(3), vec![1], "quiet group skips mid-round");
        assert_eq!(s.due_groups(6), vec![0, 1], "round boundary syncs everyone");
    }

    #[test]
    fn divergence_feedback_threshold_zero_matches_fedlama() {
        let mut fb = Schedule::new(Policy::divergence_feedback(3, 2, 0.0), vec![10, 10]);
        let mut lama = Schedule::new(Policy::fedlama(3, 2), vec![10, 10]);
        for (g, disc) in [(0usize, 0.0f64), (1, 0.004)] {
            fb.observe(g, disc);
            lama.observe(g, disc);
        }
        for k in 1..=24 {
            assert_eq!(fb.due_groups(k), lama.due_groups(k), "k={k}");
        }
        fb.maybe_adjust(6);
        lama.maybe_adjust(6);
        assert_eq!(fb.intervals, lama.intervals);
    }

    #[test]
    fn personalized_schedules_like_fullsync() {
        let mut s = Schedule::new(Policy::personalized(6, 0.5), vec![10, 20]);
        assert_eq!(s.policy.round_len(), 6);
        assert_eq!(s.policy.mix_eta(), Some(0.5));
        assert!(s.due_groups(5).is_empty());
        assert_eq!(s.due_groups(6), vec![0, 1]);
        s.observe(0, 1.0);
        s.maybe_adjust(6);
        assert!(s.adjustments.is_empty(), "personalized never adjusts intervals");
        assert!(Policy::fedavg(6).mix_eta().is_none());
    }
}
