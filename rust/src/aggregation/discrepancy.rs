//! Native (pure-rust) weighted aggregation + unit model discrepancy.
//!
//! This is the reference backend for the L1 Pallas kernel (`agg_d*_m*`
//! artifacts) and the fallback when no kernel was AOT-compiled for a
//! (dim, m) configuration.  It operates directly on per-client tensor
//! slices — no [m, d] stacking copy — which also makes it the performance
//! baseline the Pallas path is compared against in EXPERIMENTS.md §Perf.

use crate::runtime::simd::{self, Isa};

/// Weighted average of client rows into `u` (u must be zeroed or will be
/// overwritten), followed by the weighted squared-distance reduction.
///
/// rows[i] is client i's flattened group parameters, weights[i] its
/// (renormalized) aggregation weight.  Returns the discrepancy
/// sum_i w_i ||u - x_i||^2 (paper Eq. 2 numerator).
pub fn aggregate_native(rows: &[&[f32]], weights: &[f32], u: &mut [f32]) -> f64 {
    aggregate_native_with(simd::active_isa(), rows, weights, u)
}

/// [`aggregate_native`] pinned to an explicit SIMD dispatch path.  The
/// weighted sum runs on the `runtime::simd` ladder (lanes span independent
/// coordinates j; one mul + one add per accumulation, never FMA), so every
/// path is bit-identical — see `tests/simd_quant.rs`.
pub fn aggregate_native_with(isa: Isa, rows: &[&[f32]], weights: &[f32], u: &mut [f32]) -> f64 {
    assert_eq!(rows.len(), weights.len());
    assert!(!rows.is_empty());
    let d = u.len();
    for r in rows {
        assert_eq!(r.len(), d);
    }
    // pass 1: u = sum_i w_i x_i  (f32 accumulate matches the XLA kernel)
    u.fill(0.0);
    for (row, &w) in rows.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        simd::axpy(isa, u, w, row);
    }
    // pass 2: disc = sum_i w_i ||u - x_i||^2 (f64 accumulate for stability)
    let mut disc = 0.0f64;
    for (row, &w) in rows.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        let mut s = 0.0f64;
        for (uj, &xj) in u.iter().zip(row.iter()) {
            let dlt = (*uj - xj) as f64;
            s += dlt * dlt;
        }
        disc += w as f64 * s;
    }
    disc
}

/// The paper's layer-wise *unit* model discrepancy (Eq. 2):
/// d_l = disc / (tau_l * dim).
pub fn unit_discrepancy(disc: f64, tau: usize, dim: usize) -> f64 {
    disc / (tau as f64 * dim as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_rows_have_zero_discrepancy() {
        let a = vec![1.0f32, -2.0, 3.0];
        let rows: Vec<&[f32]> = vec![&a, &a, &a];
        let mut u = vec![0.0; 3];
        let disc = aggregate_native(&rows, &[0.2, 0.3, 0.5], &mut u);
        assert!(disc.abs() < 1e-12);
        for (x, y) in u.iter().zip(&a) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matches_hand_computation() {
        // two clients, equal weight: u = (x1+x2)/2, disc = 0.5*||u-x1||^2*2
        let x1 = vec![0.0f32, 0.0];
        let x2 = vec![2.0f32, 4.0];
        let rows: Vec<&[f32]> = vec![&x1, &x2];
        let mut u = vec![0.0; 2];
        let disc = aggregate_native(&rows, &[0.5, 0.5], &mut u);
        assert_eq!(u, vec![1.0, 2.0]);
        // ||u-x1||^2 = 1+4 = 5, same for x2 -> disc = 0.5*5 + 0.5*5 = 5
        assert!((disc - 5.0).abs() < 1e-9);
    }

    #[test]
    fn zero_weight_rows_are_ignored() {
        let x1 = vec![1.0f32, 1.0];
        let junk = vec![f32::MAX, -1.0e30];
        let rows: Vec<&[f32]> = vec![&x1, &junk];
        let mut u = vec![0.0; 2];
        let disc = aggregate_native(&rows, &[1.0, 0.0], &mut u);
        assert_eq!(u, vec![1.0, 1.0]);
        assert_eq!(disc, 0.0);
    }

    #[test]
    fn unit_discrepancy_normalizes() {
        assert!((unit_discrepancy(12.0, 3, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_average_is_convex_combination() {
        // result stays within [min, max] per coordinate
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let m = 2 + rng.below(5);
            let d = 1 + rng.below(8);
            let rows_data: Vec<Vec<f32>> =
                (0..m).map(|_| (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect()).collect();
            let mut w: Vec<f32> = (0..m).map(|_| rng.f32() + 0.01).collect();
            let s: f32 = w.iter().sum();
            w.iter_mut().for_each(|v| *v /= s);
            let rows: Vec<&[f32]> = rows_data.iter().map(|r| r.as_slice()).collect();
            let mut u = vec![0.0; d];
            let disc = aggregate_native(&rows, &w, &mut u);
            assert!(disc >= 0.0);
            for j in 0..d {
                let mn = rows.iter().map(|r| r[j]).fold(f32::INFINITY, f32::min);
                let mx = rows.iter().map(|r| r[j]).fold(f32::NEG_INFINITY, f32::max);
                assert!(u[j] >= mn - 1e-4 && u[j] <= mx + 1e-4);
            }
        }
    }
}
