//! Aggregation backends: native rust vs a fused compute-backend kernel.
//!
//! Both compute (u_l, disc_l) for one group across active clients.  The
//! native path reads client tensors in place (no stacking copy); the fused
//! path stacks rows into a scratch [m, d] buffer and calls
//! `ComputeBackend::fused_agg` (the Pallas kernel artifact under the pjrt
//! engine).  `Auto` uses the fused kernel when the backend has one for
//! (dim, m) and falls back to native otherwise.  Tests assert the two
//! agree.

use anyhow::{Context, Result};

use super::discrepancy::aggregate_native;
use crate::runtime::ComputeBackend;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggBackend {
    Native,
    Xla,
    Auto,
}

impl AggBackend {
    pub fn parse(s: &str) -> Option<AggBackend> {
        match s {
            "native" => Some(AggBackend::Native),
            "xla" => Some(AggBackend::Xla),
            "auto" => Some(AggBackend::Auto),
            _ => None,
        }
    }
}

/// Reusable scratch to avoid per-sync allocation on the hot path.
#[derive(Default)]
pub struct AggScratch {
    pub stack: Vec<f32>,
    pub u: Vec<f32>,
}

/// Aggregate one group.  `rows[i]` is active client i's flattened group
/// tensor; `weights` the renormalized p_i.  Writes u into scratch.u and
/// returns the discrepancy.
pub fn aggregate_group(
    backend: AggBackend,
    compute: &dyn ComputeBackend,
    rows: &[&[f32]],
    weights: &[f32],
    scratch: &mut AggScratch,
) -> Result<f64> {
    let m = rows.len();
    let dim = rows[0].len();
    scratch.u.resize(dim, 0.0);
    let use_fused = match backend {
        AggBackend::Native => false,
        AggBackend::Xla | AggBackend::Auto => compute.has_fused_agg(dim, m),
    };
    if backend == AggBackend::Xla && !use_fused {
        anyhow::bail!("no fused agg kernel for dim={dim}, m={m} (re-run `make artifacts` with --agg-m)");
    }
    if use_fused {
        scratch.stack.resize(m * dim, 0.0);
        for (i, row) in rows.iter().enumerate() {
            scratch.stack[i * dim..(i + 1) * dim].copy_from_slice(row);
        }
        let (u, disc) = compute
            .fused_agg(&scratch.stack, weights, dim)?
            .context("fused agg kernel vanished")?;
        scratch.u.copy_from_slice(&u);
        Ok(disc as f64)
    } else {
        Ok(aggregate_native(rows, weights, &mut scratch.u))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;
    use crate::runtime::NativeBackend;

    #[test]
    fn native_backend_has_no_fused_kernel_and_falls_back() {
        let nb = NativeBackend::for_dataset(DatasetKind::Toy);
        assert!(!nb.has_fused_agg(128, 4));
        assert_eq!(nb.fused_agg(&[0.0; 8], &[0.5, 0.5], 4).unwrap(), None);
        let r1 = [1.0f32, 2.0];
        let r2 = [3.0f32, 4.0];
        let rows: Vec<&[f32]> = vec![&r1, &r2];
        let mut scratch = AggScratch::default();
        // Auto falls back to native...
        let disc = aggregate_group(AggBackend::Auto, &nb, &rows, &[0.5, 0.5], &mut scratch)
            .unwrap();
        assert_eq!(scratch.u, vec![2.0, 3.0]);
        assert!(disc > 0.0);
        // ...while forcing Xla errors out.
        assert!(aggregate_group(AggBackend::Xla, &nb, &rows, &[0.5, 0.5], &mut scratch).is_err());
    }
}
