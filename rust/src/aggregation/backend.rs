//! Aggregation backends: native rust vs the AOT Pallas kernel.
//!
//! Both compute (u_l, disc_l) for one group across active clients.  The
//! native path reads client tensors in place (no stacking copy); the Xla
//! path stacks rows into a scratch [m, d] buffer and runs the fused Pallas
//! kernel artifact.  `Auto` uses the kernel when one exists for (dim, m)
//! and falls back to native otherwise.  Tests assert the two agree.

use anyhow::Result;

use super::discrepancy::aggregate_native;
use crate::runtime::ModelRuntime;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggBackend {
    Native,
    Xla,
    Auto,
}

impl AggBackend {
    pub fn parse(s: &str) -> Option<AggBackend> {
        match s {
            "native" => Some(AggBackend::Native),
            "xla" => Some(AggBackend::Xla),
            "auto" => Some(AggBackend::Auto),
            _ => None,
        }
    }
}

/// Reusable scratch to avoid per-sync allocation on the hot path.
#[derive(Default)]
pub struct AggScratch {
    pub stack: Vec<f32>,
    pub u: Vec<f32>,
}

/// Aggregate one group.  `rows[i]` is active client i's flattened group
/// tensor; `weights` the renormalized p_i.  Writes u into scratch.u and
/// returns the discrepancy.
pub fn aggregate_group(
    backend: AggBackend,
    runtime: &ModelRuntime,
    rows: &[&[f32]],
    weights: &[f32],
    scratch: &mut AggScratch,
) -> Result<f64> {
    let m = rows.len();
    let dim = rows[0].len();
    scratch.u.resize(dim, 0.0);
    let use_xla = match backend {
        AggBackend::Native => false,
        AggBackend::Xla | AggBackend::Auto => runtime.agg_kernel(dim, m).is_some(),
    };
    if backend == AggBackend::Xla && !use_xla {
        anyhow::bail!("no AOT agg kernel for dim={dim}, m={m} (re-run `make artifacts` with --agg-m)");
    }
    if use_xla {
        let exe = runtime.agg_kernel(dim, m).unwrap();
        scratch.stack.resize(m * dim, 0.0);
        for (i, row) in rows.iter().enumerate() {
            scratch.stack[i * dim..(i + 1) * dim].copy_from_slice(row);
        }
        let (u, disc) = runtime.run_agg(&exe, &scratch.stack, weights, dim)?;
        scratch.u.copy_from_slice(&u);
        Ok(disc as f64)
    } else {
        Ok(aggregate_native(rows, weights, &mut scratch.u))
    }
}
