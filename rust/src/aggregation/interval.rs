//! Algorithm 2: Layer-wise Adaptive Interval Adjustment — the core of the
//! paper's contribution.
//!
//! Given the observed unit discrepancies d_l (Eq. 2), sort ascending and
//! find the prefix of "least critical layers" whose cumulative discrepancy
//! share delta_l (Eq. 3) is still below their cumulative parameter share
//! lambda_l (Eq. 4): those layers get the long interval phi*tau', the rest
//! keep tau'.  Because delta_l grows slower than lambda_l exactly when
//! small-d_l layers are large, the crossover lands below 0.5 and the bulk
//! of traffic is relaxed at minimal discrepancy cost (paper Fig. 1).
//!
//! The "accelerate" variant (paper §4, last paragraph) sorts descending
//! and compares 1 - delta_l with lambda_l, shortening intervals of the
//! most critical layers instead — for latency-insensitive deployments.

/// Outcome of one interval adjustment.
#[derive(Debug, Clone, PartialEq)]
pub struct Adjustment {
    /// Per-group aggregation interval tau_l (either tau or phi*tau).
    pub intervals: Vec<usize>,
    /// Number of groups assigned the long interval.
    pub relaxed: usize,
    /// delta_l and 1 - lambda_l at each sorted prefix length (Figure 1's
    /// two curves), for diagnostics and the figure bench.
    pub delta_curve: Vec<f64>,
    pub comm_curve: Vec<f64>,
    /// Crossover prefix length (first l where delta_l >= lambda_l).
    pub crossover: usize,
}

/// Algorithm 2.  `d` is the latest unit discrepancy per group, `dims` the
/// group sizes, `tau` the base interval, `phi` the increase factor.
pub fn adjust_intervals(d: &[f64], dims: &[usize], tau: usize, phi: usize) -> Adjustment {
    assert_eq!(d.len(), dims.len());
    assert!(!d.is_empty());
    assert!(tau >= 1 && phi >= 1);
    let l_total = d.len();

    // Lines 1-2: sort ascending by d_l.
    let mut order: Vec<usize> = (0..l_total).collect();
    order.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap_or(std::cmp::Ordering::Equal));

    // Lines 3-4: totals.
    let lambda_total: f64 = dims.iter().map(|&x| x as f64).sum();
    let delta_total: f64 = d.iter().zip(dims).map(|(di, &sz)| di * sz as f64).sum();

    let mut intervals = vec![tau; l_total];
    let mut delta_curve = Vec::with_capacity(l_total);
    let mut comm_curve = Vec::with_capacity(l_total);
    let mut cum_delta = 0.0;
    let mut cum_lambda = 0.0;
    let mut relaxed = 0;
    let mut crossover = l_total;
    // Lines 5-12.
    for (pos, &gi) in order.iter().enumerate() {
        cum_delta += d[gi] * dims[gi] as f64;
        cum_lambda += dims[gi] as f64;
        // Degenerate case delta_total == 0 (all layers identical across
        // clients): treat every layer as least-critical.
        let delta_l = if delta_total > 0.0 { cum_delta / delta_total } else { 0.0 };
        let lambda_l = cum_lambda / lambda_total;
        delta_curve.push(delta_l);
        comm_curve.push(1.0 - lambda_l);
        if delta_l < lambda_l {
            intervals[gi] = phi * tau;
            relaxed += 1;
        } else if crossover == l_total {
            crossover = pos;
        }
    }
    Adjustment { intervals, relaxed, delta_curve, comm_curve, crossover }
}

/// The accelerate variant: the *most* critical layers get the short
/// interval tau, everything else phi*tau... inverted: sort descending and
/// shorten while 1 - delta_l > lambda_l would hold.  Following the paper's
/// sketch, we compute the crossover of 1 - delta_l (descending sort) with
/// lambda_l and give the prefix (most critical) the short interval.
pub fn adjust_intervals_accelerate(
    d: &[f64],
    dims: &[usize],
    tau: usize,
    phi: usize,
) -> Adjustment {
    assert_eq!(d.len(), dims.len());
    let l_total = d.len();
    let mut order: Vec<usize> = (0..l_total).collect();
    order.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap_or(std::cmp::Ordering::Equal));

    let lambda_total: f64 = dims.iter().map(|&x| x as f64).sum();
    let delta_total: f64 = d.iter().zip(dims).map(|(di, &sz)| di * sz as f64).sum();

    let mut intervals = vec![phi * tau; l_total];
    let mut delta_curve = Vec::with_capacity(l_total);
    let mut comm_curve = Vec::with_capacity(l_total);
    let mut cum_delta = 0.0;
    let mut cum_lambda = 0.0;
    let mut relaxed = l_total;
    let mut crossover = l_total;
    for (pos, &gi) in order.iter().enumerate() {
        cum_delta += d[gi] * dims[gi] as f64;
        cum_lambda += dims[gi] as f64;
        let delta_l = if delta_total > 0.0 { cum_delta / delta_total } else { 1.0 };
        let lambda_l = cum_lambda / lambda_total;
        delta_curve.push(1.0 - delta_l);
        comm_curve.push(lambda_l);
        if 1.0 - delta_l > lambda_l {
            // still in the high-discrepancy prefix: keep aggressive syncing
            intervals[gi] = tau;
            relaxed -= 1;
        } else if crossover == l_total {
            crossover = pos;
        }
    }
    Adjustment { intervals, relaxed, delta_curve, comm_curve, crossover }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Strategy, VecF64};
    use crate::util::rng::Rng;

    #[test]
    fn phi_one_reduces_to_fedavg() {
        let adj = adjust_intervals(&[0.5, 0.1, 0.9], &[10, 1000, 10], 6, 1);
        assert!(adj.intervals.iter().all(|&t| t == 6));
    }

    #[test]
    fn large_low_discrepancy_layer_is_relaxed() {
        // fc layer: tiny d_l, huge dim -> relaxed; conv: large d_l -> kept.
        let d = vec![1.0, 0.001];
        let dims = vec![100, 100_000];
        let adj = adjust_intervals(&d, &dims, 6, 4);
        assert_eq!(adj.intervals, vec![6, 24]);
        assert_eq!(adj.relaxed, 1);
    }

    #[test]
    fn paper_fig1_narrative_crossover_below_half() {
        // Paper Fig. 1: output-side layers are large and low-discrepancy ->
        // the delta_l and 1-lambda_l curves cross well below y=0.5.  Build
        // such a profile: 20 layers, dims grow geometrically, unit
        // discrepancy shrinks super-linearly with size.
        let dims: Vec<usize> = (0..20).map(|i| 100 << (i / 2)).collect();
        let d: Vec<f64> = dims.iter().map(|&s| 1.0 / (s as f64 * s as f64)).collect();
        let adj = adjust_intervals(&d, &dims, 6, 2);
        // find where delta_l rises above 1 - lambda_l (the Fig. 1 crossing)
        let cross = adj
            .delta_curve
            .iter()
            .zip(&adj.comm_curve)
            .position(|(dl, cl)| dl >= cl)
            .unwrap();
        let height = adj.delta_curve[cross];
        assert!(height < 0.5, "Fig.1 crossing height {height} should be < 0.5");
        assert!(adj.relaxed > 0 && adj.relaxed < 20);
    }

    #[test]
    fn intervals_are_only_tau_or_phitau() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let n = 1 + rng.below(30);
            let d: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let dims: Vec<usize> = (0..n).map(|_| 1 + rng.below(10_000)).collect();
            let adj = adjust_intervals(&d, &dims, 6, 4);
            assert!(adj.intervals.iter().all(|&t| t == 6 || t == 24));
        }
    }

    #[test]
    fn monotone_in_discrepancy() {
        // Raising one layer's d_l can never move it short -> long.
        let dims = vec![500, 500, 500, 500];
        let d0 = vec![0.1, 0.2, 0.3, 0.4];
        let base = adjust_intervals(&d0, &dims, 6, 2);
        for i in 0..4 {
            let mut d = d0.clone();
            d[i] *= 10.0;
            let adj = adjust_intervals(&d, &dims, 6, 2);
            if base.intervals[i] == 6 {
                assert_eq!(adj.intervals[i], 6, "layer {i} got relaxed after d_l increased");
            }
        }
    }

    #[test]
    fn curves_are_monotone() {
        let d = vec![0.3, 0.1, 0.7, 0.05, 0.9];
        let dims = vec![10, 2000, 50, 30_000, 20];
        let adj = adjust_intervals(&d, &dims, 10, 4);
        for w in adj.delta_curve.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "delta_l must be nondecreasing");
        }
        for w in adj.comm_curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "1-lambda_l must be nonincreasing");
        }
        assert!((adj.delta_curve.last().unwrap() - 1.0).abs() < 1e-9);
        assert!(adj.comm_curve.last().unwrap().abs() < 1e-9);
    }

    #[test]
    fn zero_discrepancy_relaxes_everything() {
        let adj = adjust_intervals(&[0.0, 0.0], &[10, 10], 6, 2);
        assert_eq!(adj.relaxed, 2);
        assert!(adj.intervals.iter().all(|&t| t == 12));
    }

    #[test]
    fn accelerate_variant_keeps_critical_short() {
        let d = vec![1.0, 0.001];
        let dims = vec![100, 100_000];
        let adj = adjust_intervals_accelerate(&d, &dims, 6, 4);
        // the high-discrepancy layer keeps tau, the low one phi*tau
        assert_eq!(adj.intervals, vec![6, 24]);
    }

    /// Property: Algorithm 2 invariants over random profiles.
    #[test]
    fn prop_invariants() {
        struct Profile;
        impl Strategy for Profile {
            type Value = (Vec<f64>, Vec<usize>);
            fn generate(&self, rng: &mut Rng) -> Self::Value {
                let n = 1 + rng.below(40);
                let d = (0..n).map(|_| rng.f64() * 10.0).collect();
                let dims = (0..n).map(|_| 1 + rng.below(100_000)).collect();
                (d, dims)
            }
        }
        forall(42, 300, &Profile, |(d, dims)| {
            let adj = adjust_intervals(d, dims, 6, 4);
            if adj.intervals.len() != d.len() {
                return Err("arity".into());
            }
            if !adj.intervals.iter().all(|&t| t == 6 || t == 24) {
                return Err(format!("bad interval in {:?}", adj.intervals));
            }
            if adj.relaxed != adj.intervals.iter().filter(|&&t| t == 24).count() {
                return Err("relaxed count mismatch".into());
            }
            // full sync guaranteed at phi*tau: lcm(6,24)=24 divides 24
            if adj.intervals.iter().any(|&t| 24 % t != 0) {
                return Err("phi*tau not a multiple of tau_l".into());
            }
            Ok(())
        });
        // If the smallest d_l sits strictly below the dim-weighted mean of
        // d, then the first sorted layer satisfies delta_1 < lambda_1 and
        // at least one layer must be relaxed.
        forall(43, 300, &Profile, |(d, dims)| {
            let adj = adjust_intervals(d, dims, 6, 2);
            let lambda: f64 = dims.iter().map(|&s| s as f64).sum();
            let delta: f64 = d.iter().zip(dims).map(|(x, &s)| x * s as f64).sum();
            let dmin = d.iter().cloned().fold(f64::INFINITY, f64::min);
            if delta > 0.0 && dmin < delta / lambda * 0.999 && adj.relaxed == 0 {
                return Err(format!("dmin {dmin} < mean {} but nothing relaxed", delta / lambda));
            }
            Ok(())
        });
        let _ = VecF64 { min_len: 1, max_len: 2, lo: 0.0, hi: 1.0 }; // keep import used
    }
}
