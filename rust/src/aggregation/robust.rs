//! Byzantine-robust aggregation reducers.
//!
//! FedLAMA's layer-wise scheduling makes robustness layer-granular: each
//! aggregation group folds at its own sync point, so each group's fold can
//! screen corrupted updates independently.  This module provides the pure
//! reducers; `CoordinatorCore::apply_updates_quorum` feeds them one flat
//! vector per surviving client (the group's tensors concatenated in layer
//! order) and charges the ledger from the returned per-update flags.
//!
//! A `--aggregator SPEC` is a `+`-chained pipeline of *screens* followed by
//! one terminal *fold*:
//!
//! ```text
//!   spec    := stage ('+' stage)*
//!   stage   := 'mean' | 'median' | 'trimmed:F'
//!            | 'normclip' [':MULT']      (default MULT 2.0)
//!            | 'filter'   [':MULT']      (default MULT 3.0)
//! ```
//!
//!   - `normclip:T` — norm-clipped mean screen: radius r = T x the median
//!     update norm of the group; any update with norm > r is scaled down
//!     onto the radius (direction preserved) and counted as clipped.
//!   - `filter:T`  — distance-based outlier screen: distances are measured
//!     from the coordinate-wise weighted median of the group; any update
//!     farther than T x the median distance is rejected outright.
//!   - `trimmed:F` — trimmed mean fold: the F updates farthest from the
//!     coordinate-wise weighted median are rejected, the rest are
//!     weight-renormalized and averaged.  Requires 2F < survivors.
//!   - `median`    — coordinate-wise weighted median fold (no rejection).
//!   - `mean`      — plain weighted mean (the default; also the implicit
//!     fold when a spec is screens-only, e.g. `normclip:2`).
//!
//! Determinism contract: rows arrive in survivor order (the active list,
//! never arrival order), every sort is a stable sort keyed by
//! `(value, client id)` via `f64::total_cmp`, and all randomless reductions
//! accumulate in row order — so the fold is bit-identical across the
//! in-proc, `--workers N`, and TCP transports, and permutation-invariant
//! over the order updates arrived on the wire.

use anyhow::{bail, ensure, Context, Result};

/// Pre-fold screen: mutates or rejects individual updates.
#[derive(Debug, Clone, PartialEq)]
pub enum Screen {
    /// Clip each update onto `mult x median-norm` of the group.
    NormClip { mult: f32 },
    /// Reject updates farther than `mult x median-distance` from the
    /// coordinate-wise weighted median.
    DistFilter { mult: f32 },
}

/// Terminal fold over the accepted updates.
#[derive(Debug, Clone, PartialEq)]
pub enum Fold {
    Mean,
    Median,
    /// Reject the `f` farthest-from-median updates, then mean the rest.
    Trimmed { f: usize },
}

/// Parsed `--aggregator` spec: screens applied in order, then one fold.
#[derive(Debug, Clone, PartialEq)]
pub struct RobustSpec {
    pub screens: Vec<Screen>,
    pub fold: Fold,
}

impl RobustSpec {
    /// The plain weighted-mean aggregator (the default).
    pub fn mean() -> RobustSpec {
        RobustSpec { screens: Vec::new(), fold: Fold::Mean }
    }

    /// Is this the plain mean?  The coordinator core keeps the original
    /// zero-copy fold for it.
    pub fn is_mean(&self) -> bool {
        self.screens.is_empty() && self.fold == Fold::Mean
    }

    /// Updates the fold is guaranteed to discard per group (screens reject
    /// a data-dependent number on top).  `RunConfig::validate` checks this
    /// against the worst-case quorum survivor count.
    pub fn guaranteed_trim(&self) -> usize {
        match self.fold {
            Fold::Trimmed { f } => f,
            _ => 0,
        }
    }

    /// Parse an `--aggregator` spec (grammar in the module docs).
    pub fn parse(spec: &str) -> Result<RobustSpec> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "mean" {
            return Ok(RobustSpec::mean());
        }
        let mut screens = Vec::new();
        let mut fold: Option<Fold> = None;
        for stage in spec.split('+') {
            ensure!(
                fold.is_none(),
                "bad --aggregator {spec:?}: fold stage must be last (screens \
                 like normclip/filter come before mean/median/trimmed)"
            );
            let (name, arg) = match stage.split_once(':') {
                Some((n, a)) => (n.trim(), Some(a.trim())),
                None => (stage.trim(), None),
            };
            match name {
                "mean" => {
                    ensure!(arg.is_none(), "bad --aggregator stage {stage:?}: mean takes no arg");
                    fold = Some(Fold::Mean);
                }
                "median" => {
                    ensure!(arg.is_none(), "bad --aggregator stage {stage:?}: median takes no arg");
                    fold = Some(Fold::Median);
                }
                "trimmed" => {
                    let f: usize = arg
                        .context("bad --aggregator: trimmed needs a count, e.g. trimmed:1")?
                        .parse()
                        .with_context(|| format!("bad --aggregator stage {stage:?}"))?;
                    ensure!(f > 0, "bad --aggregator stage {stage:?}: trim count must be > 0");
                    fold = Some(Fold::Trimmed { f });
                }
                "normclip" => {
                    let mult: f32 = match arg {
                        Some(a) => a
                            .parse()
                            .with_context(|| format!("bad --aggregator stage {stage:?}"))?,
                        None => 2.0,
                    };
                    ensure!(
                        mult.is_finite() && mult > 0.0,
                        "bad --aggregator stage {stage:?}: clip multiplier must be finite and > 0"
                    );
                    screens.push(Screen::NormClip { mult });
                }
                "filter" => {
                    let mult: f32 = match arg {
                        Some(a) => a
                            .parse()
                            .with_context(|| format!("bad --aggregator stage {stage:?}"))?,
                        None => 3.0,
                    };
                    // mult >= 1 keeps the median-distance update itself in
                    // radius, so the filter can never reject every update.
                    ensure!(
                        mult.is_finite() && mult >= 1.0,
                        "bad --aggregator stage {stage:?}: filter multiplier must be >= 1"
                    );
                    screens.push(Screen::DistFilter { mult });
                }
                other => bail!(
                    "bad --aggregator stage {other:?} in {spec:?} \
                     (mean|median|trimmed:F|normclip[:T]|filter[:T], '+'-chained)"
                ),
            }
        }
        Ok(RobustSpec { screens, fold: fold.unwrap_or(Fold::Mean) })
    }

    /// Canonical display form (round-trips through `parse`).
    pub fn display(&self) -> String {
        let mut parts: Vec<String> = self
            .screens
            .iter()
            .map(|s| match s {
                Screen::NormClip { mult } => format!("normclip:{mult}"),
                Screen::DistFilter { mult } => format!("filter:{mult}"),
            })
            .collect();
        parts.push(match self.fold {
            Fold::Mean => "mean".to_string(),
            Fold::Median => "median".to_string(),
            Fold::Trimmed { f } => format!("trimmed:{f}"),
        });
        parts.join("+")
    }
}

/// What the reducer did to one client's update (ledger attribution).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UpdateFlags {
    /// Excluded from the fold (filter screen or trimmed fold).
    pub rejected: bool,
    /// Scaled down onto the clip radius (normclip screen).
    pub clipped: bool,
}

/// Run the full spec over one aggregation group.
///
/// `rows[i]` is client `clients[i]`'s update for the group (all tensors
/// concatenated in layer order), in survivor order; `weights[i]` its
/// aggregation weight (already renormalized over survivors).  `out`
/// receives the folded group vector; the return value is the group
/// discrepancy `sum_i w'_i ||out - x_i||^2` over accepted updates with
/// weights `w'` renormalized over the accepted set, plus per-row flags.
pub fn reduce(
    spec: &RobustSpec,
    rows: &mut [Vec<f32>],
    weights: &[f32],
    clients: &[usize],
    out: &mut [f32],
) -> Result<(f64, Vec<UpdateFlags>)> {
    let m = rows.len();
    ensure!(m > 0, "robust reduce over zero updates");
    ensure!(
        weights.len() == m && clients.len() == m,
        "robust reduce shape mismatch: {m} rows, {} weights, {} clients",
        weights.len(),
        clients.len()
    );
    let dim = out.len();
    for (i, r) in rows.iter().enumerate() {
        ensure!(
            r.len() == dim,
            "robust reduce row {i} (client {}) has {} elements, group dim is {dim}",
            clients[i],
            r.len()
        );
    }
    let mut flags = vec![UpdateFlags::default(); m];

    for screen in &spec.screens {
        match *screen {
            Screen::NormClip { mult } => {
                let norms: Vec<f64> = rows
                    .iter()
                    .zip(&flags)
                    .map(|(r, f)| if f.rejected { f64::NAN } else { norm(r) })
                    .collect();
                let radius = mult as f64 * median_with_ties(&norms, clients, &flags)?;
                for i in 0..m {
                    if flags[i].rejected || norms[i] <= radius || norms[i] == 0.0 {
                        continue;
                    }
                    let scale = (radius / norms[i]) as f32;
                    for x in rows[i].iter_mut() {
                        *x *= scale;
                    }
                    flags[i].clipped = true;
                }
            }
            Screen::DistFilter { mult } => {
                let center = coordwise_weighted_median(rows, weights, clients, &flags, dim);
                let dists = distances(rows, &center, &flags);
                let threshold = mult as f64 * median_with_ties(&dists, clients, &flags)?;
                for i in 0..m {
                    if !flags[i].rejected && dists[i] > threshold {
                        flags[i].rejected = true;
                    }
                }
            }
        }
    }

    let disc = match spec.fold {
        Fold::Trimmed { f } => {
            let survivors = flags.iter().filter(|fl| !fl.rejected).count();
            ensure!(
                survivors > 2 * f,
                "trimmed:{f} needs more than {} surviving updates per group, got {survivors} \
                 (lower the trim count or raise --quorum / --active-ratio)",
                2 * f
            );
            let center = coordwise_weighted_median(rows, weights, clients, &flags, dim);
            let dists = distances(rows, &center, &flags);
            // Stable sort by (distance, client id): ties cannot depend on
            // arrival order, so every transport trims the same updates.
            let mut order: Vec<usize> = (0..m).filter(|&i| !flags[i].rejected).collect();
            order.sort_by(|&a, &b| {
                dists[a].total_cmp(&dists[b]).then(clients[a].cmp(&clients[b]))
            });
            for &i in order.iter().rev().take(f) {
                flags[i].rejected = true;
            }
            weighted_mean(rows, weights, &flags, out)?
        }
        Fold::Mean => weighted_mean(rows, weights, &flags, out)?,
        Fold::Median => {
            let center = coordwise_weighted_median(rows, weights, clients, &flags, dim);
            out.copy_from_slice(&center);
            let renorm = renormalized(weights, &flags)?;
            let mut disc = 0.0f64;
            for (i, r) in rows.iter().enumerate() {
                if flags[i].rejected {
                    continue;
                }
                let mut d2 = 0.0f64;
                for (&u, &x) in out.iter().zip(r.iter()) {
                    let e = (u - x) as f64;
                    d2 += e * e;
                }
                disc += renorm[i] as f64 * d2;
            }
            disc
        }
    };
    Ok((disc, flags))
}

/// L2 norm of one row, accumulated in f64.
fn norm(row: &[f32]) -> f64 {
    row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Distance of each accepted row to `center` (rejected rows get NaN —
/// they are never compared).
fn distances(rows: &[Vec<f32>], center: &[f32], flags: &[UpdateFlags]) -> Vec<f64> {
    rows.iter()
        .zip(flags)
        .map(|(r, f)| {
            if f.rejected {
                return f64::NAN;
            }
            let mut d2 = 0.0f64;
            for (&x, &c) in r.iter().zip(center.iter()) {
                let e = (x - c) as f64;
                d2 += e * e;
            }
            d2.sqrt()
        })
        .collect()
}

/// Lower median of the accepted values, ties broken by client id (stable
/// under any permutation of equal values).
fn median_with_ties(vals: &[f64], clients: &[usize], flags: &[UpdateFlags]) -> Result<f64> {
    let mut order: Vec<usize> = (0..vals.len()).filter(|&i| !flags[i].rejected).collect();
    ensure!(!order.is_empty(), "robust screen over zero accepted updates");
    order.sort_by(|&a, &b| vals[a].total_cmp(&vals[b]).then(clients[a].cmp(&clients[b])));
    Ok(vals[order[(order.len() - 1) / 2]])
}

/// Renormalize `weights` over the accepted rows (rejected rows get 0).
fn renormalized(weights: &[f32], flags: &[UpdateFlags]) -> Result<Vec<f32>> {
    let total: f32 =
        weights.iter().zip(flags).filter(|(_, f)| !f.rejected).map(|(&w, _)| w).sum();
    ensure!(
        total > 0.0,
        "robust fold rejected every weighted update (accepted weight sum is {total})"
    );
    Ok(weights
        .iter()
        .zip(flags)
        .map(|(&w, f)| if f.rejected { 0.0 } else { w / total })
        .collect())
}

/// Weighted mean over accepted rows with renormalized weights; returns the
/// group discrepancy over the accepted set.  Rejected rows ride along with
/// weight 0 so the shared two-pass kernel keeps its row-order accumulation.
fn weighted_mean(
    rows: &[Vec<f32>],
    weights: &[f32],
    flags: &[UpdateFlags],
    out: &mut [f32],
) -> Result<f64> {
    let renorm = renormalized(weights, flags)?;
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    Ok(super::aggregate_native(&refs, &renorm, out))
}

/// Coordinate-wise weighted median over the accepted rows: per coordinate,
/// values sort by `(value, client id)` and the median is the first value
/// whose cumulative weight reaches half the accepted total.
fn coordwise_weighted_median(
    rows: &[Vec<f32>],
    weights: &[f32],
    clients: &[usize],
    flags: &[UpdateFlags],
    dim: usize,
) -> Vec<f32> {
    let accepted: Vec<usize> = (0..rows.len()).filter(|&i| !flags[i].rejected).collect();
    let total: f64 = accepted.iter().map(|&i| weights[i] as f64).sum();
    let half = total / 2.0;
    let mut center = vec![0.0f32; dim];
    let mut order = accepted.clone();
    for (j, c) in center.iter_mut().enumerate() {
        order.copy_from_slice(&accepted);
        order.sort_by(|&a, &b| {
            rows[a][j].total_cmp(&rows[b][j]).then(clients[a].cmp(&clients[b]))
        });
        let mut cum = 0.0f64;
        let mut pick = order[order.len() - 1];
        for &i in &order {
            cum += weights[i] as f64;
            if cum >= half {
                pick = i;
                break;
            }
        }
        *c = rows[pick][j];
    }
    center
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(m: usize) -> Vec<f32> {
        vec![1.0 / m as f32; m]
    }

    fn run(
        spec: &str,
        rows: &[Vec<f32>],
        weights: &[f32],
        clients: &[usize],
    ) -> (Vec<f32>, f64, Vec<UpdateFlags>) {
        let spec = RobustSpec::parse(spec).unwrap();
        let mut rows = rows.to_vec();
        let mut out = vec![0.0f32; rows[0].len()];
        let (disc, flags) = reduce(&spec, &mut rows, weights, clients, &mut out).unwrap();
        (out, disc, flags)
    }

    #[test]
    fn spec_grammar_round_trips() {
        for (s, canon) in [
            ("mean", "mean"),
            ("", "mean"),
            ("median", "median"),
            ("trimmed:2", "trimmed:2"),
            ("normclip", "normclip:2+mean"),
            ("normclip:1.5+trimmed:1", "normclip:1.5+trimmed:1"),
            ("filter:4+median", "filter:4+median"),
        ] {
            let spec = RobustSpec::parse(s).unwrap();
            assert_eq!(RobustSpec::parse(&spec.display()).unwrap(), spec, "{s}");
            if !spec.is_mean() {
                assert_eq!(spec.display(), canon, "{s}");
            }
        }
        assert!(RobustSpec::parse("mean").unwrap().is_mean());
        assert!(!RobustSpec::parse("median").unwrap().is_mean());
        for bad in
            ["krum", "trimmed", "trimmed:0", "trimmed:x", "mean+median", "filter:0.5", "normclip:-1", "mean:2"]
        {
            assert!(RobustSpec::parse(bad).is_err(), "{bad} must not parse");
        }
    }

    #[test]
    fn trimmed_mean_matches_hand_computed_fixture() {
        // three honest updates near 1.0, one sign-flipped attacker at -9
        let rows = vec![
            vec![1.0f32, 2.0],
            vec![1.2, 2.2],
            vec![-9.0, -18.0],
            vec![0.8, 1.8],
        ];
        let clients = [0usize, 1, 2, 3];
        let (out, disc, flags) = run("trimmed:1", &rows, &uniform(4), &clients);
        // the attacker (client 2) is farthest from the coordinate-wise
        // median and gets trimmed; the rest average at weight 1/3
        assert!(flags[2].rejected && !flags[0].rejected && !flags[1].rejected && !flags[3].rejected);
        let want = [(1.0 + 1.2 + 0.8) / 3.0, (2.0 + 2.2 + 1.8) / 3.0];
        for (g, w) in out.iter().zip(want) {
            assert!((g - w).abs() < 1e-6, "{out:?} vs {want:?}");
        }
        assert!(disc > 0.0 && disc < 1.0, "disc over accepted only, got {disc}");
    }

    #[test]
    fn coordinate_wise_median_matches_fixture() {
        let rows = vec![vec![1.0f32, 5.0], vec![3.0, -1.0], vec![100.0, 3.0]];
        let clients = [0usize, 1, 2];
        let (out, _, flags) = run("median", &rows, &uniform(3), &clients);
        assert_eq!(out, vec![3.0, 3.0]);
        assert!(flags.iter().all(|f| *f == UpdateFlags::default()));
        // weighted: client 0 carries over half the weight -> its values win
        let (out, _, _) = run("median", &rows, &[0.6, 0.2, 0.2], &clients);
        assert_eq!(out, vec![1.0, 5.0]);
    }

    #[test]
    fn normclip_is_idempotent_on_in_radius_updates() {
        let rows = vec![vec![1.0f32, 0.0], vec![0.0, 1.0], vec![0.5, 0.5]];
        let clients = [0usize, 1, 2];
        let (clipped, disc_c, flags) = run("normclip:2", &rows, &uniform(3), &clients);
        let (plain, disc_p, _) = run("mean", &rows, &uniform(3), &clients);
        assert_eq!(clipped, plain, "in-radius updates must pass through untouched");
        assert_eq!(disc_c.to_bits(), disc_p.to_bits());
        assert!(flags.iter().all(|f| !f.clipped && !f.rejected));
    }

    #[test]
    fn normclip_scales_the_oversized_update_onto_the_radius() {
        let rows = vec![vec![1.0f32, 0.0], vec![0.0, 1.0], vec![30.0, 40.0]];
        let clients = [0usize, 1, 2];
        let (out, _, flags) = run("normclip:1", &rows, &uniform(3), &clients);
        assert!(flags[2].clipped && !flags[0].clipped && !flags[1].clipped);
        // median norm is 1.0 -> client 2 (norm 50) scales by 1/50
        let want = [(1.0 + 30.0 / 50.0) / 3.0, (1.0 + 40.0 / 50.0) / 3.0];
        for (g, w) in out.iter().zip(want) {
            assert!((g - w).abs() < 1e-6, "{out:?} vs {want:?}");
        }
    }

    #[test]
    fn distance_filter_rejects_the_outlier_and_renormalizes() {
        let rows = vec![
            vec![1.0f32, 1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
            vec![-50.0, 80.0],
        ];
        let clients = [4usize, 7, 9, 13];
        let (out, _, flags) = run("filter:3", &rows, &uniform(4), &clients);
        assert!(flags[3].rejected);
        assert_eq!(flags.iter().filter(|f| f.rejected).count(), 1);
        let want = [(1.0 + 1.1 + 0.9) / 3.0, (1.0 + 0.9 + 1.1) / 3.0];
        for (g, w) in out.iter().zip(want) {
            assert!((g - w).abs() < 1e-6, "{out:?} vs {want:?}");
        }
    }

    #[test]
    fn screens_compose_with_folds() {
        // the scaled attacker gets clipped back into radius, then the
        // sign-flipped one gets trimmed
        let rows = vec![
            vec![1.0f32, 1.0],
            vec![200.0, 200.0],
            vec![-1.0, -1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
        ];
        let clients = [0usize, 1, 2, 3, 4];
        let (_, _, flags) = run("normclip:1.5+trimmed:1", &rows, &uniform(5), &clients);
        assert!(flags[1].clipped, "scaled update must clip");
        assert!(flags[2].rejected, "sign-flipped update must trim");
        assert_eq!(flags.iter().filter(|f| f.rejected).count(), 1);
    }

    #[test]
    fn trimmed_needs_enough_survivors() {
        let spec = RobustSpec::parse("trimmed:1").unwrap();
        let mut rows = vec![vec![1.0f32], vec![2.0]];
        let mut out = vec![0.0f32];
        let err = reduce(&spec, &mut rows, &uniform(2), &[0, 1], &mut out).unwrap_err();
        assert!(format!("{err:#}").contains("trimmed:1 needs"), "{err:#}");
    }

    #[test]
    fn reducers_are_permutation_invariant_over_row_order() {
        let base: Vec<(usize, Vec<f32>, f32)> = vec![
            (3, vec![1.0, 2.0, 3.0], 0.4),
            (0, vec![-9.0, 4.0, 0.5], 0.1),
            (7, vec![1.1, 2.1, 2.9], 0.2),
            (5, vec![0.9, 1.9, 3.1], 0.3),
        ];
        for spec in ["median", "trimmed:1", "normclip:1", "filter:3", "normclip:1+trimmed:1"] {
            let perms: Vec<Vec<usize>> =
                vec![vec![0, 1, 2, 3], vec![3, 2, 1, 0], vec![1, 3, 0, 2], vec![2, 0, 3, 1]];
            let mut golden: Option<(Vec<u32>, u64)> = None;
            for p in perms {
                let rows: Vec<Vec<f32>> = p.iter().map(|&i| base[i].1.clone()).collect();
                let weights: Vec<f32> = p.iter().map(|&i| base[i].2).collect();
                let clients: Vec<usize> = p.iter().map(|&i| base[i].0).collect();
                let (out, disc, _) = run(spec, &rows, &weights, &clients);
                // compare exact bit patterns: "close enough" is not the
                // contract, bit-identical across arrival orders is
                let bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
                match &golden {
                    None => golden = Some((bits, disc.to_bits())),
                    Some((gb, gd)) => {
                        assert_eq!(&bits, gb, "{spec} out diverged under permutation {p:?}");
                        assert_eq!(disc.to_bits(), *gd, "{spec} disc diverged under {p:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn tie_breaking_is_by_client_id_not_position() {
        // two identical extreme rows: trimmed:1 must always trim the one
        // with the larger client id, wherever it sits in the row order
        let a = vec![50.0f32, 50.0];
        let honest = vec![1.0f32, 1.0];
        let rows1 = vec![a.clone(), a.clone(), honest.clone(), honest.clone(), honest.clone()];
        let clients1 = [9usize, 2, 0, 1, 3];
        let (_, _, flags1) = run("trimmed:1", &rows1, &uniform(5), &clients1);
        assert!(flags1[0].rejected && !flags1[1].rejected, "{flags1:?}");
        let rows2 = vec![a.clone(), a, honest.clone(), honest.clone(), honest];
        let clients2 = [2usize, 9, 0, 1, 3];
        let (_, _, flags2) = run("trimmed:1", &rows2, &uniform(5), &clients2);
        assert!(flags2[1].rejected && !flags2[0].rejected, "{flags2:?}");
    }
}
