//! Experiment runners + paper-style reports: the code that regenerates
//! every table and figure (DESIGN.md §6).  Shared by the CLI, examples,
//! and the bench harness.

use std::path::Path;

use anyhow::Result;

use crate::aggregation::Policy;
use crate::config::presets::{Experiment, ExperimentRow};
use crate::config::RunConfig;
use crate::coordinator::Coordinator;
use crate::metrics::tables::{acc_cell, pct_cell, Table};
use crate::metrics::RunMetrics;

/// Run every row of an experiment (optionally with `repeats` seeds to get
/// the paper's ± std column) and return per-row metrics.
pub fn run_experiment(exp: &Experiment, repeats: usize, verbose: bool) -> Result<Vec<RowResult>> {
    let mut out = Vec::with_capacity(exp.rows.len());
    for row in &exp.rows {
        out.push(run_row(row, repeats, verbose)?);
    }
    Ok(out)
}

#[derive(Debug, Clone)]
pub struct RowResult {
    pub label: String,
    pub lr: f32,
    pub acc_mean: f64,
    pub acc_std: f64,
    pub comm_cost: u64,
    pub wall_secs: f64,
    /// Metrics of the first repeat (curves, per-group detail).
    pub metrics: RunMetrics,
}

pub fn run_row(row: &ExperimentRow, repeats: usize, verbose: bool) -> Result<RowResult> {
    let repeats = repeats.max(1);
    let mut accs = Vec::with_capacity(repeats);
    let mut first: Option<RunMetrics> = None;
    let mut comm = 0;
    let mut wall = 0.0;
    for r in 0..repeats {
        let cfg = RunConfig { seed: row.cfg.seed + r as u64, verbose, ..row.cfg.clone() };
        let mut coord = Coordinator::new(cfg)?;
        let m = coord.run()?;
        accs.push(m.final_acc);
        comm = m.total_comm_cost;
        wall += m.wall_secs;
        if first.is_none() {
            first = Some(m);
        }
    }
    let mean = crate::util::stats::mean(&accs);
    let std = crate::util::stats::stddev(&accs);
    Ok(RowResult {
        label: row.label.clone(),
        lr: row.lr,
        acc_mean: mean,
        acc_std: std,
        comm_cost: comm,
        wall_secs: wall,
        metrics: first.unwrap(),
    })
}

/// Render an experiment's results in the paper's table format
/// (LR | setting | accuracy | comm-cost% vs the baseline row).
pub fn render_table(exp: &Experiment, results: &[RowResult]) -> Table {
    let base = results[exp.baseline_row].comm_cost.max(1) as f64;
    let mut t = Table::new(&exp.title, &["LR", "Setting", "Validation acc.", "Comm. cost"]);
    for r in results {
        t.row(vec![
            format!("{}", r.lr),
            r.label.clone(),
            acc_cell(r.acc_mean, r.acc_std),
            pct_cell(100.0 * r.comm_cost as f64 / base),
        ]);
    }
    t
}

/// Figure 1: the delta_l / (1 - lambda_l) curves from the *first* interval
/// adjustment of a FedLAMA run.  Returns CSV: l, delta_l, one_minus_lambda_l.
pub fn figure1_csv(coord: &Coordinator) -> Option<String> {
    let adj = coord.schedule().adjustments.first()?;
    let mut s = String::from("l,delta_l,one_minus_lambda_l\n");
    for (i, (d, c)) in adj.delta_curve.iter().zip(&adj.comm_curve).enumerate() {
        s.push_str(&format!("{},{:.6},{:.6}\n", i + 1, d, c));
    }
    Some(s)
}

/// Figures 2 & 3: per-layer sync counts and Eq. 9 data sizes for a set of
/// finished runs (paper compares FedAvg vs FedLAMA side by side).
pub fn figure23_csv(results: &[(&str, &RunMetrics)]) -> String {
    let mut s = String::from("layer,dim");
    for (tag, _) in results {
        s.push_str(&format!(",{tag}_syncs,{tag}_cost"));
    }
    s.push('\n');
    let n = results[0].1.per_group.len();
    for g in 0..n {
        let (name, dim, _, _) = &results[0].1.per_group[g];
        s.push_str(&format!("{name},{dim}"));
        for (_, m) in results {
            let (_, _, syncs, cost) = &m.per_group[g];
            s.push_str(&format!(",{syncs},{cost}"));
        }
        s.push('\n');
    }
    s
}

/// Figures 4-6: learning curves of several runs, merged on iteration.
pub fn curves_csv(results: &[(&str, &RunMetrics)]) -> String {
    let mut s = String::from("tag,iteration,round,train_loss,val_acc,comm_cost\n");
    for (tag, m) in results {
        for p in &m.curve {
            s.push_str(&format!(
                "{tag},{},{},{:.6},{},{}\n",
                p.iteration,
                p.round,
                p.train_loss,
                p.val_acc.map(|v| format!("{v:.4}")).unwrap_or_default(),
                p.comm_cost
            ));
        }
    }
    s
}

/// ASCII rendering of Figure 1 (two curves against prefix length).
pub fn figure1_ascii(coord: &Coordinator, width: usize, height: usize) -> Option<String> {
    let adj = coord.schedule().adjustments.first()?;
    let n = adj.delta_curve.len();
    if n == 0 {
        return None;
    }
    let mut grid = vec![vec![b' '; width]; height];
    let put = |grid: &mut Vec<Vec<u8>>, x: f64, y: f64, ch: u8| {
        let col = ((x * (width - 1) as f64).round() as usize).min(width - 1);
        let row = (((1.0 - y) * (height - 1) as f64).round() as usize).min(height - 1);
        grid[row][col] = ch;
    };
    for (i, (&d, &c)) in adj.delta_curve.iter().zip(&adj.comm_curve).enumerate() {
        let x = i as f64 / (n - 1).max(1) as f64;
        put(&mut grid, x, c, b'o'); // 1 - lambda_l
        put(&mut grid, x, d, b'*'); // delta_l
    }
    let mut s = String::new();
    s.push_str("Figure 1: * = delta_l (discrepancy share), o = 1-lambda_l (comm share)\n");
    for row in grid {
        s.push_str("  |");
        s.push_str(std::str::from_utf8(&row).unwrap());
        s.push('\n');
    }
    s.push_str(&format!("   +{}\n", "-".repeat(width)));
    s.push_str(&format!("    1 .. L={n} (layers, sorted by d_l ascending)\n"));
    Some(s)
}

/// Write a string to a file, creating parent dirs.
pub fn write_report(path: &Path, content: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

/// Human summary line for one run (used by quickstart + CLI).
pub fn summary_line(label: &str, m: &RunMetrics) -> String {
    format!(
        "{label:28} acc={:6.2}%  comm(Eq.9)={:>12}  syncs={:>6}  wall={:.1}s",
        100.0 * m.final_acc,
        m.total_comm_cost,
        m.total_syncs,
        m.wall_secs
    )
}

/// Per-participant traffic table for sharded runs (stdio workers or TCP
/// participants): nominal Eq.9-style bytes folded by round-robin shard.
/// `None` for in-proc runs — a single-shard table carries nothing beyond
/// the ledger totals.
pub fn participants_summary(m: &RunMetrics) -> Option<String> {
    if m.per_participant.len() <= 1 {
        return None;
    }
    let mut s = String::from("participants (nominal Eq.9-style bytes, shard = client mod n):\n");
    for p in &m.per_participant {
        s.push_str(&format!(
            "  shard {}: {:>5} layer updates  {:>12} B up  {:>12} B down",
            p.shard, p.updates, p.uplink_bytes, p.downlink_bytes
        ));
        // membership events only appear on elastic (quorum) runs
        if p.departures + p.rejoins + p.missed_blocks > 0 {
            s.push_str(&format!(
                "  [departed x{}, rejoined x{}, missed {} blocks]",
                p.departures, p.rejoins, p.missed_blocks
            ));
        }
        // robust-aggregation attribution only appears when a reducer
        // actually screened this shard's updates
        if p.rejected_updates + p.clipped_updates > 0 {
            s.push_str(&format!(
                "  [rejected {}, clipped {}]",
                p.rejected_updates, p.clipped_updates
            ));
        }
        s.push('\n');
    }
    // registry-granularity totals: one aggregate over per-client counters
    // (keyed by registered client id, so they survive sampling gaps and
    // shard remapping — the shard rows above cannot)
    if !m.per_client.is_empty() {
        let updates: u64 = m.per_client.iter().map(|(_, c)| c.updates).sum();
        let up: u64 = m.per_client.iter().map(|(_, c)| c.uplink_bytes).sum();
        let down: u64 = m.per_client.iter().map(|(_, c)| c.downlink_bytes).sum();
        s.push_str(&format!(
            "  clients: {} participated  {:>5} layer updates  {:>12} B up  {:>12} B down\n",
            m.per_client.len(),
            updates,
            up,
            down
        ));
    }
    Some(s)
}

/// Comm-efficiency comparison used in several reports: FedLAMA vs the two
/// FedAvg reference points the paper anchors on.
pub fn tradeoff_note(
    fedavg_short: &RunMetrics,
    fedavg_long: &RunMetrics,
    fedlama: &RunMetrics,
) -> String {
    format!(
        "FedLAMA comm = {:.1}% of FedAvg(tau'), accuracy {:+.2}pp vs FedAvg(tau'), \
         {:+.2}pp vs FedAvg(phi*tau')",
        100.0 * fedlama.total_comm_cost as f64 / fedavg_short.total_comm_cost.max(1) as f64,
        100.0 * (fedlama.final_acc - fedavg_short.final_acc),
        100.0 * (fedlama.final_acc - fedavg_long.final_acc),
    )
}

/// Build the Policy for a figure run given CLI-ish params.  `threshold`
/// feeds divergence feedback (FedLDF uplink-skip cut-off) and `eta` the
/// personalized mixing rate; the other policies ignore them.
pub fn policy_of(kind: &str, tau: usize, phi: usize, threshold: f64, eta: f64) -> Option<Policy> {
    match kind {
        "fedavg" => Some(Policy::fedavg(tau)),
        "fedlama" => Some(Policy::fedlama(tau, phi)),
        "fedlama-acc" => Some(Policy::FedLama { tau, phi, accelerate: true }),
        "divergence-feedback" => Some(Policy::divergence_feedback(tau, phi, threshold)),
        "personalized" => Some(Policy::personalized(tau, eta)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::CurvePoint;

    fn fake_metrics(tag: &str) -> RunMetrics {
        RunMetrics {
            tag: tag.into(),
            final_acc: 0.84,
            total_comm_cost: 1000,
            per_group: vec![
                ("conv".into(), 100, 10, 1000),
                ("fc".into(), 900, 5, 4500),
            ],
            curve: vec![CurvePoint {
                iteration: 6,
                round: 1,
                train_loss: 2.0,
                val_acc: Some(0.5),
                val_loss: Some(1.9),
                comm_cost: 500,
            }],
            ..Default::default()
        }
    }

    #[test]
    fn figure23_merges_runs() {
        let a = fake_metrics("fedavg");
        let b = fake_metrics("fedlama");
        let csv = figure23_csv(&[("fedavg", &a), ("fedlama", &b)]);
        assert!(csv.starts_with("layer,dim,fedavg_syncs,fedavg_cost,fedlama_syncs,fedlama_cost"));
        assert!(csv.contains("conv,100,10,1000,10,1000"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn curves_csv_format() {
        let a = fake_metrics("x");
        let csv = curves_csv(&[("x", &a)]);
        assert!(csv.contains("x,6,1,2.000000,0.5000,500"));
    }

    #[test]
    fn summary_and_tradeoff() {
        let short = RunMetrics { final_acc: 0.9, total_comm_cost: 1000, ..Default::default() };
        let long = RunMetrics { final_acc: 0.8, total_comm_cost: 250, ..Default::default() };
        let lama = RunMetrics { final_acc: 0.89, total_comm_cost: 400, ..Default::default() };
        let note = tradeoff_note(&short, &long, &lama);
        assert!(note.contains("40.0%"), "{note}");
        assert!(note.contains("-1.00pp"), "{note}");
        assert!(note.contains("+9.00pp"), "{note}");
        assert!(summary_line("t", &short).contains("90.00%"));
    }

    #[test]
    fn participants_summary_renders_only_when_sharded() {
        let shard_row = |shard| crate::comm::ParticipantComm {
            shard,
            updates: 12,
            uplink_bytes: 4096,
            downlink_bytes: 2048,
            ..Default::default()
        };
        let mut m = fake_metrics("fedlama");
        m.per_participant = vec![shard_row(0)];
        assert!(participants_summary(&m).is_none(), "single shard: nothing beyond totals");
        m.per_participant = vec![shard_row(0), shard_row(1)];
        let s = participants_summary(&m).unwrap();
        assert!(s.contains("shard 0"), "{s}");
        assert!(s.contains("shard 1"), "{s}");
        assert!(s.contains("4096"), "{s}");
        assert!(!s.contains("departed"), "steady roster hides membership: {s}");
        assert_eq!(s.lines().count(), 3);
        // a shard that dropped and came back is called out
        m.per_participant[1].departures = 1;
        m.per_participant[1].rejoins = 1;
        m.per_participant[1].missed_blocks = 2;
        let s = participants_summary(&m).unwrap();
        assert!(s.contains("departed x1, rejoined x1, missed 2 blocks"), "{s}");
        assert!(!s.contains("rejected"), "honest run hides robust counters: {s}");
        // a shard the robust reducer screened is called out
        m.per_participant[0].rejected_updates = 3;
        m.per_participant[0].clipped_updates = 1;
        let s = participants_summary(&m).unwrap();
        assert!(s.contains("[rejected 3, clipped 1]"), "{s}");
        assert_eq!(s.lines().count(), 3);
        // registry-granularity client totals append one aggregate line
        m.per_client = vec![
            (0, crate::comm::ClientComm { updates: 12, uplink_bytes: 4096, downlink_bytes: 2048 }),
            (7, crate::comm::ClientComm { updates: 12, uplink_bytes: 4096, downlink_bytes: 2048 }),
        ];
        let s = participants_summary(&m).unwrap();
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains("clients: 2 participated"), "{s}");
        assert!(s.contains("24 layer updates"), "{s}");
        assert!(s.contains("8192"), "{s}");
    }

    #[test]
    fn policy_parse() {
        assert_eq!(policy_of("fedavg", 6, 2, 0.0, 0.0), Some(Policy::fedavg(6)));
        assert_eq!(policy_of("fedlama", 6, 2, 0.0, 0.0), Some(Policy::fedlama(6, 2)));
        assert_eq!(
            policy_of("divergence-feedback", 6, 2, 0.05, 0.0),
            Some(Policy::divergence_feedback(6, 2, 0.05))
        );
        assert_eq!(
            policy_of("personalized", 6, 2, 0.0, 0.25),
            Some(Policy::personalized(6, 0.25))
        );
        assert!(policy_of("nope", 6, 2, 0.0, 0.0).is_none());
    }
}
