//! fedlama — leader entrypoint / CLI.
//!
//! Subcommands:
//!   train    one federated training run (all knobs exposed)
//!   serve    TCP federation coordinator: bind, wait for N participants,
//!            then train (bit-identical to `train --workers N`)
//!   join     TCP federation participant: dial a serve coordinator
//!   repro    regenerate a paper table (table1..table11, baselines, all)
//!   figure   regenerate a paper figure (1..6)
//!   bench    kernel/op/end-to-end microbenches -> BENCH_kernels.json
//!   inspect  print a model's artifact manifest summary
//!   list     list available experiment presets
//!   worker   federation-protocol participant over stdin/stdout (spawned
//!            by `train --workers N`; not for interactive use)
//!
//! Examples:
//!   fedlama train --model resnet20 --dataset cifar10 --policy fedlama \
//!       --tau 6 --phi 4 --clients 16 --iters 960 --lr 0.4
//!   fedlama repro --table table1 --scale smoke
//!   fedlama figure --id 1

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use fedlama::aggregation::AggBackend;
use fedlama::config::presets::{self, Scale, ALL_TABLE_IDS};
use fedlama::config::{Algorithm, EngineKind, PartitionKind, RunConfig};
use fedlama::coordinator::Coordinator;
use fedlama::data::DatasetKind;
use fedlama::reports;
use fedlama::runtime::{zoo, Manifest};
use fedlama::util::cli::Args;

fn main() {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "train" => run_train(&args),
        "serve" => run_serve(&args),
        "join" => run_join(&args),
        "repro" => run_repro(&args),
        "figure" => run_figure(&args),
        "bench" => run_bench(&args),
        "inspect" => run_inspect(&args),
        "list" => run_list(),
        "worker" => run_worker(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "fedlama — FedLAMA (AAAI'23) reproduction\n\n\
         USAGE: fedlama <train|serve|join|repro|figure|inspect|list|worker> [--flags]\n\n\
         train   --model mlp|femnist_cnn|cifar_cnn100|resnet20 --dataset D\n\
                 [--policy fedavg|fedlama|fedlama-acc|divergence-feedback\n\
                  |personalized] [--threshold 0.05 (divergence-feedback:\n\
                  groups under this unit discrepancy skip mid-round uplinks)]\n\
                 [--mix-eta 0.25 (personalized: per-client layer mixing rate)]\n\
                 [--tau 6] [--phi 2] [--clients 16] [--active-ratio 1.0]\n\
                 [--partition iid|dirichlet|writers|single-class|power-law]\n\
                 [--alpha 0.1] [--exponent 1.5 (power-law size skew)]\n\
                 [--samples 512]\n\
                 [--lr 0.1] [--warmup 4] [--iters 960] [--eval-every 4]\n\
                 [--algo sgd|fedprox|scaffold|fednova] [--mu 0.01] [--hetero]\n\
                 [--engine native|pjrt] [--threads 1 (0=auto)] [--workers 0]\n\
                 [--backend auto|native|xla] [--no-chunk] [--seed 1]\n\
                 [--out run.json] [--curve curve.csv] [--verbose]\n\
                 [--checkpoint-dir D (snapshot state at each round boundary,\n\
                  any --algo/--policy: control variates and personalized\n\
                  mixing weights ride the registry into the snapshot)]\n\
                 [--resume (restart from D's snapshot;\n\
                  metrics bit-identical to the uninterrupted run)]\n\
                 [--halt-after-rounds R (stop early after R completed rounds;\n\
                  pairs with --checkpoint-dir to stage an interrupted run)]\n\
                 [--aggregator mean|median|trimmed:F|normclip[:T]|filter[:T]\n\
                  ('+'-chained screens before one fold, e.g. normclip:2+trimmed:1;\n\
                  Byzantine-robust per-group reducers, bit-identical across\n\
                  transports)]\n\
                 [--chaos signflip[:N]|scale:Fx[:N]|noise[:SIGMA][:N]|stall[:N]\n\
                  |corrupt-frame[:N], each optionally @rK, comma-separated\n\
                  (seeded fault injection: the lowest N shards turn adversarial;\n\
                  stall/corrupt-frame are TCP wire faults)]\n\
         serve   --bind HOST:PORT --expect N + every train flag\n\
                 [--quorum Q (default N: strict full roster)]\n\
                 [--join-timeout 120] [--io-timeout 600] [--heartbeat-secs 2]\n\
                 (TCP coordinator: waits for N `fedlama join` participants,\n\
                  then runs the training loop over the sockets; metrics are\n\
                  bit-identical to `train --workers N`.  With --quorum Q < N\n\
                  each block commits once Q shards report; departed shards\n\
                  go vacant and fresh joins re-claim them at the next round)\n\
         join    --connect HOST:PORT [--retry-secs 30] [--io-timeout 600]\n\
                 [--depart-after B (leave cleanly after B blocks; chaos test)]\n\
                 (TCP participant: dials a `fedlama serve` coordinator and\n\
                  serves one training session)\n\
         repro   --table table1..table11|baselines|all [--scale smoke|default|full]\n\
                 [--repeats 1] [--out-dir reports] [--verbose]\n\
         figure  --id 1..6 [--scale ...] [--out-dir reports]\n\
         bench   [--quick] [--threads 0] [--out BENCH_kernels.json]\n\
                 [--scale [--registered 1000000] [--sampled 1000]]\n\
                 (SIMD matmul kernels vs scalar, per-op latency, e2e step,\n\
                  persistent-pool overhead, wire transport throughput —\n\
                  monolithic vs streamed per-layer framing;\n\
                  --scale adds the registry roster bench: N registered\n\
                  clients with spill-to-disk state, k sampled per round in\n\
                  O(k) memory, reporting rounds/s + coordinator peak RSS;\n\
                  FEDLAMA_SIMD=scalar|sse2|avx2 forces a narrower path)\n\
         inspect --model M [--dataset D]   (native zoo manifest when no artifacts)\n\
         list\n\
         worker  (internal: federation-protocol participant on stdin/stdout,\n\
                  spawned by train --workers N)"
    );
}

fn artifacts_root() -> PathBuf {
    std::env::var_os("FEDLAMA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn cfg_from_args(args: &Args) -> Result<RunConfig> {
    let model = args.str_or("model", "mlp");
    let dataset = DatasetKind::parse(&args.str_or("dataset", "toy"))
        .context("bad --dataset (toy|cifar10|cifar100|femnist)")?;
    let tau = args.usize_or("tau", 6);
    let phi = args.usize_or("phi", 2);
    let threshold = args.f64_or("threshold", 0.05);
    let mix_eta = args.f64_or("mix-eta", 0.25);
    let policy = reports::policy_of(&args.str_or("policy", "fedavg"), tau, phi, threshold, mix_eta)
        .context("bad --policy (fedavg|fedlama|fedlama-acc|divergence-feedback|personalized)")?;
    let algorithm = Algorithm::parse(&args.str_or("algo", "sgd"), args.f32_or("mu", 0.01))
        .context("bad --algo (sgd|fedprox|scaffold|fednova)")?;
    let partition = match args.str_or("partition", "iid").as_str() {
        "iid" => PartitionKind::Iid,
        "dirichlet" => PartitionKind::Dirichlet { alpha: args.f64_or("alpha", 0.1) },
        "writers" => PartitionKind::Writers,
        "single-class" => PartitionKind::SingleClass,
        "power-law" => PartitionKind::PowerLaw { exponent: args.f64_or("exponent", 1.5) },
        p => anyhow::bail!("bad --partition {p}"),
    };
    let backend = AggBackend::parse(&args.str_or("backend", "auto"))
        .context("bad --backend (auto|native|xla)")?;
    let engine = EngineKind::parse(&args.str_or("engine", "native"))
        .context("bad --engine (native|pjrt)")?;
    let iters = args.usize_or("iters", 960);
    Ok(RunConfig {
        engine,
        threads: args.usize_or("threads", 1),
        workers: args.usize_or("workers", 0),
        quorum: args.usize_or("quorum", 0),
        model_dir: artifacts_root().join(&model),
        model,
        dataset,
        algorithm,
        policy,
        n_clients: args.usize_or("clients", 16),
        active_ratio: args.f64_or("active-ratio", 1.0),
        partition,
        samples: args.usize_or("samples", 512),
        lr: args.f32_or("lr", 0.1),
        warmup_rounds: args.usize_or("warmup", 4),
        iterations: iters,
        eval_every_rounds: args.usize_or("eval-every", 4),
        eval_examples: args.usize_or("eval-examples", 1024),
        seed: args.u64_or("seed", 1),
        backend,
        use_chunk: !args.bool_or("no-chunk", false),
        hetero_local_steps: args.bool_or("hetero", false),
        compressor: args.str_or("compress", "dense"),
        aggregator: args.str_or("aggregator", "mean"),
        chaos: args.str_or("chaos", ""),
        verbose: args.bool_or("verbose", false),
        checkpoint_dir: args.get("checkpoint-dir").map(PathBuf::from),
        resume: args.bool_or("resume", false),
        resume_blocks: 0,
        halt_after_rounds: args.usize_or("halt-after-rounds", 0),
    })
}

/// Serve the federation protocol on stdin/stdout.  stdout carries frames
/// exclusively — all diagnostics go to stderr.
fn run_worker() -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    fedlama::protocol::worker::run(stdin.lock(), stdout.lock())
}

fn run_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let tag = cfg.tag();
    let engine = cfg.engine.name();
    eprintln!(
        "running {tag} on {:?} ({} clients, engine={engine}, threads={}, workers={})",
        cfg.dataset,
        cfg.n_clients,
        if cfg.threads == 0 { "auto".to_string() } else { cfg.threads.to_string() },
        if cfg.workers == 0 { "in-proc".to_string() } else { cfg.workers.to_string() }
    );
    let mut coord = Coordinator::new(cfg)?;
    let threads = coord.effective_threads();
    let metrics = coord.run()?;
    report_run(args, &tag, engine, threads, &metrics)
}

/// Serve the federation over TCP: bind, wait for `--expect N` participants
/// to join, then run the standard training loop over the sockets.  Takes
/// every `train` flag; the JSON metrics (wall-clock excluded) are
/// bit-identical to `train --workers N` with the same flags.
fn run_serve(args: &Args) -> Result<()> {
    let expect = args.usize_or("expect", 0);
    anyhow::ensure!(expect > 0, "serve needs --expect N (the participant count)");
    let bind = args.str_or("bind", "127.0.0.1:7070");
    let mut cfg = cfg_from_args(args)?;
    // workers = participant count: shard map, validation, and the
    // per-participant ledger all match the stdio --workers run exactly.
    // Check the sharded-transport constraints under the serve name first,
    // so a scaffold/pjrt misconfiguration blames `fedlama serve`, not a
    // --workers flag the user never passed.
    anyhow::ensure!(
        cfg.workers == 0 || cfg.workers == expect,
        "--workers {} conflicts with --expect {expect}: serve shards over the TCP \
         participants, one per shard (drop --workers or make them equal)",
        cfg.workers
    );
    cfg.workers = expect;
    cfg.validate_sharded("fedlama serve")?;
    let opts = fedlama::protocol::TcpOpts {
        join_timeout: Duration::from_secs(args.u64_or("join-timeout", 120)),
        io_timeout: Duration::from_secs(args.u64_or("io-timeout", 600)),
        heartbeat_every: Duration::from_secs(args.u64_or("heartbeat-secs", 2)),
    };
    let tag = cfg.tag();
    let engine = cfg.engine.name();
    let mut coord = Coordinator::new(cfg)?;
    let threads = coord.effective_threads();
    let server = fedlama::protocol::TcpServer::bind(&bind)?;
    eprintln!(
        "serving {tag} on {} — waiting up to {}s for {expect} participant(s) \
         (`fedlama join --connect <this address>`)",
        server.local_addr()?,
        opts.join_timeout.as_secs()
    );
    let mut transport = server.accept_participants(&coord.cfg, expect, &opts)?;
    for (shard, addr) in transport.peer_addrs() {
        eprintln!("  shard {shard} <- {addr}");
    }
    let metrics = coord.run_with_transport(&mut transport)?;
    report_run(args, &tag, engine, threads, &metrics)
}

/// Join a TCP coordinator as a participant and serve one training session.
fn run_join(args: &Args) -> Result<()> {
    let addr = args.get("connect").context("join needs --connect HOST:PORT")?;
    let depart_after = args.usize_or("depart-after", 0);
    let opts = fedlama::protocol::JoinOpts {
        connect_retry: Duration::from_secs(args.u64_or("retry-secs", 30)),
        io_timeout: Duration::from_secs(args.u64_or("io-timeout", 600)),
        depart_after_blocks: (depart_after > 0).then_some(depart_after),
    };
    eprintln!("joining coordinator at {addr} ...");
    let shard = fedlama::protocol::tcp::join(addr, &opts)?;
    eprintln!("session complete (served shard {shard})");
    Ok(())
}

/// Post-run reporting shared by `train` and `serve`: summary + runtime +
/// throughput lines, per-participant traffic when sharded, and the
/// `--out`/`--curve` report files.
fn report_run(
    args: &Args,
    tag: &str,
    engine: &str,
    threads: usize,
    metrics: &fedlama::metrics::RunMetrics,
) -> Result<()> {
    println!("{}", reports::summary_line(tag, metrics));
    // runtime_secs sums per-worker compute time, so normalize utilization by
    // the worker count — with threads > 1 it can legitimately exceed wall.
    let budget = metrics.wall_secs.max(1e-9) * threads as f64;
    println!(
        "runtime: {engine} compute {:.1}s summed over {threads} worker thread(s), \
         {:.1}s wall — worker utilization {:.0}%",
        metrics.runtime_secs,
        metrics.wall_secs,
        (100.0 * metrics.runtime_secs / budget).min(100.0),
    );
    println!(
        "throughput: {:.0} assigned samples/s ({} examples); round wall p50 {:.1} ms, \
         p95 {:.1} ms over {} rounds",
        metrics.samples_per_sec,
        metrics.train_samples,
        metrics.round_wall_ms_pct(50.0),
        metrics.round_wall_ms_pct(95.0),
        metrics.round_wall_secs.len(),
    );
    if let Some(table) = reports::participants_summary(metrics) {
        print!("{table}");
    }
    if let Some(out) = args.get("out") {
        reports::write_report(std::path::Path::new(out), &metrics.to_json().to_string_pretty())?;
        eprintln!("wrote {out}");
    }
    if let Some(curve) = args.get("curve") {
        reports::write_report(std::path::Path::new(curve), &metrics.curve_csv())?;
        eprintln!("wrote {curve}");
    }
    Ok(())
}

/// Run the kernel/op/end-to-end microbenches and write the JSON perf
/// artifact (BENCH_kernels.json at the repo root by default — the
/// committed baseline the perf trajectory is tracked against).
fn run_bench(args: &Args) -> Result<()> {
    let opts = fedlama::bench::BenchOpts {
        quick: args.bool_or("quick", false),
        threads: args.usize_or("threads", 0),
        scale: args.has("scale"),
        registered: args.usize_or("registered", 0),
        sampled: args.usize_or("sampled", 0),
    };
    let out = args.str_or("out", "BENCH_kernels.json");
    eprintln!(
        "benching kernels (quick={}, simd={}) ...",
        opts.quick,
        fedlama::runtime::simd::active_isa().name()
    );
    let doc = fedlama::bench::run(&opts)?;
    for k in doc.req("kernels")?.as_arr().unwrap_or(&[]) {
        println!(
            "{:14} {:30} {:>7} {:>9.2} GFLOP/s  {:>6.2}x vs scalar",
            k.get("kernel").and_then(|v| v.as_str()).unwrap_or("?"),
            k.get("shape").and_then(|v| v.as_str()).unwrap_or("?"),
            k.get("dispatch").and_then(|v| v.as_str()).unwrap_or("?"),
            k.get("gflops").and_then(|v| v.as_f64()).unwrap_or(0.0),
            k.get("speedup_vs_scalar").and_then(|v| v.as_f64()).unwrap_or(0.0),
        );
    }
    for t in doc.req("transport")?.as_arr().unwrap_or(&[]) {
        println!(
            "transport {:>8} {:>10}: {:>9.1} MB/s enc  {:>9.1} MB/s dec  peak staging {:>9} B",
            t.get("model").and_then(|v| v.as_str()).unwrap_or("?"),
            t.get("path").and_then(|v| v.as_str()).unwrap_or("?"),
            t.get("encode_mb_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            t.get("decode_mb_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            t.get("peak_staging_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        );
    }
    if let Some(s) = doc.get("scale") {
        println!(
            "scale: {} registered / {} sampled x {} rounds: {:>7.1} rounds/s, \
             peak RSS {:.1} MiB (bound {:.1} MiB), spill log {} B",
            s.get("registered").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            s.get("sampled").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            s.get("rounds").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            s.get("rounds_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0),
            s.get("peak_rss_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) / (1024.0 * 1024.0),
            s.get("rss_bound_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) / (1024.0 * 1024.0),
            s.get("spill_log_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        );
    }
    reports::write_report(std::path::Path::new(&out), &doc.to_string_pretty())?;
    eprintln!("wrote {out}");
    Ok(())
}

fn run_repro(args: &Args) -> Result<()> {
    let scale = Scale::parse(&args.str_or("scale", "default")).context("bad --scale")?;
    let repeats = args.usize_or("repeats", 1);
    let verbose = args.bool_or("verbose", false);
    let out_dir = PathBuf::from(args.str_or("out-dir", "reports"));
    let which = args.str_or("table", "all");
    let ids: Vec<String> = if which == "all" {
        ALL_TABLE_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        which.split(',').map(|s| s.trim().to_string()).collect()
    };
    for id in &ids {
        let exp = presets::by_id(id, scale).with_context(|| format!("unknown table {id}"))?;
        eprintln!("=== {id}: {} rows ===", exp.rows.len());
        let results = reports::run_experiment(&exp, repeats, verbose)?;
        let table = reports::render_table(&exp, &results);
        println!("{}", table.render());
        reports::write_report(&out_dir.join(format!("{id}.md")), &table.render_markdown())?;
        let curves: Vec<(&str, &fedlama::metrics::RunMetrics)> =
            results.iter().map(|r| (r.label.as_str(), &r.metrics)).collect();
        reports::write_report(
            &out_dir.join(format!("{id}_curves.csv")),
            &reports::curves_csv(&curves),
        )?;
    }
    Ok(())
}

fn run_figure(args: &Args) -> Result<()> {
    let scale = Scale::parse(&args.str_or("scale", "default")).context("bad --scale")?;
    let out_dir = PathBuf::from(args.str_or("out-dir", "reports"));
    let id = args.usize_or("id", 1);
    let p = presets::scale_params(scale);
    match id {
        1 => {
            // delta_l / 1-lambda_l curves: (a) resnet20, (b) cifar_cnn100
            for (model, ds) in
                [("resnet20", DatasetKind::Cifar10), ("cifar_cnn100", DatasetKind::Cifar100)]
            {
                let cfg = RunConfig {
                    model_dir: artifacts_root().join(model),
                    dataset: ds,
                    policy: fedlama::aggregation::Policy::fedlama(6, 2),
                    n_clients: p.n_clients,
                    samples: p.samples,
                    iterations: (p.iterations_t1 / 10).max(12) / 12 * 12,
                    eval_every_rounds: 0,
                    eval_examples: 256,
                    lr: 0.4,
                    warmup_rounds: 0,
                    ..Default::default()
                };
                let mut coord = Coordinator::new(cfg)?;
                let _ = coord.run()?;
                let csv = reports::figure1_csv(&coord).context("no adjustment recorded")?;
                let ascii =
                    reports::figure1_ascii(&coord, 60, 16).context("no adjustment recorded")?;
                println!("--- Figure 1 ({model}) ---\n{ascii}");
                reports::write_report(&out_dir.join(format!("figure1_{model}.csv")), &csv)?;
            }
        }
        2 | 3 => {
            // per-layer comm counts (fig 2) and data sizes (fig 3)
            let mk = |policy| RunConfig {
                model_dir: artifacts_root().join("resnet20"),
                dataset: DatasetKind::Cifar10,
                policy,
                partition: PartitionKind::Dirichlet { alpha: 0.1 },
                n_clients: p.n_clients,
                samples: p.samples,
                iterations: (p.iterations_t1 / 2).max(12) / 12 * 12,
                eval_every_rounds: 0,
                eval_examples: 256,
                lr: 0.4,
                warmup_rounds: 2,
                ..Default::default()
            };
            let mut avg = Coordinator::new(mk(fedlama::aggregation::Policy::fedavg(6)))?;
            let m_avg = avg.run()?;
            let mut lama = Coordinator::new(mk(fedlama::aggregation::Policy::fedlama(6, 2)))?;
            let m_lama = lama.run()?;
            let csv = reports::figure23_csv(&[("fedavg6", &m_avg), ("fedlama6_2", &m_lama)]);
            println!("{csv}");
            reports::write_report(&out_dir.join("figure2_3.csv"), &csv)?;
            println!(
                "total Eq.9 cost: fedavg={} fedlama={} ({:.1}%)",
                m_avg.total_comm_cost,
                m_lama.total_comm_cost,
                100.0 * m_lama.total_comm_cost as f64 / m_avg.total_comm_cost as f64
            );
        }
        4 | 5 | 6 => {
            // learning curves
            let (model, ds, tau): (&str, DatasetKind, usize) = match id {
                4 => ("resnet20", DatasetKind::Cifar10, 6),
                5 => ("cifar_cnn100", DatasetKind::Cifar100, 6),
                _ => ("femnist_cnn", DatasetKind::Femnist, 10),
            };
            let iters = if tau == 6 { p.iterations_t1 } else { p.iterations_t10 };
            let partition = if id == 6 {
                PartitionKind::Writers
            } else {
                PartitionKind::Dirichlet { alpha: 0.1 }
            };
            let mk = |policy| RunConfig {
                model_dir: artifacts_root().join(model),
                dataset: ds,
                policy,
                partition,
                n_clients: p.n_clients,
                samples: p.samples,
                iterations: iters,
                eval_every_rounds: 2,
                eval_examples: p.eval_examples,
                lr: if id == 6 { 0.06 } else { 0.4 },
                warmup_rounds: 4,
                ..Default::default()
            };
            use fedlama::aggregation::Policy;
            let runs: Vec<(String, RunConfig)> = vec![
                (format!("FedAvg({tau})"), mk(Policy::fedavg(tau))),
                (format!("FedAvg({})", 4 * tau), mk(Policy::fedavg(4 * tau))),
                (format!("FedLAMA({tau},4)"), mk(Policy::fedlama(tau, 4))),
            ];
            let mut results = Vec::new();
            for (tag, cfg) in runs {
                let mut coord = Coordinator::new(cfg)?;
                let m = coord.run()?;
                eprintln!("{}", reports::summary_line(&tag, &m));
                results.push((tag, m));
            }
            let refs: Vec<(&str, &fedlama::metrics::RunMetrics)> =
                results.iter().map(|(t, m)| (t.as_str(), m)).collect();
            let csv = reports::curves_csv(&refs);
            reports::write_report(&out_dir.join(format!("figure{id}_curves.csv")), &csv)?;
            println!("wrote {}/figure{id}_curves.csv", out_dir.display());
        }
        _ => anyhow::bail!("--id must be 1..6"),
    }
    Ok(())
}

fn run_inspect(args: &Args) -> Result<()> {
    let model = args.str_or("model", "mlp");
    let dir = artifacts_root().join(&model);
    let m = if dir.join("manifest.json").exists() {
        Manifest::load(&dir)?
    } else {
        // Without artifacts, resolve through the native model registry —
        // unknown names are an error, never a silent substitute.
        anyhow::ensure!(
            zoo::is_known(&model),
            "no artifacts at {} and {model:?} is not a native model ({:?}); run \
             `make artifacts` for custom models",
            dir.display(),
            zoo::MODELS
        );
        let dataset = match args.get("dataset") {
            Some(d) => DatasetKind::parse(d)
                .context("bad --dataset (toy|cifar10|cifar100|femnist)")?,
            None => zoo::default_dataset(&model).expect("known model has a default dataset"),
        };
        eprintln!(
            "(no artifacts at {}; showing the native {model} manifest for {dataset:?})",
            dir.display()
        );
        zoo::build(&model, dataset)?.manifest().clone()
    };
    println!("model {} (base {})", m.model, m.base);
    println!(
        "  {} params in {} tensors / {} groups; batch={} eval_batch={} chunk_k={}",
        m.num_params,
        m.num_tensors(),
        m.groups.len(),
        m.batch_size,
        m.eval_batch_size,
        m.chunk_k
    );
    println!("  input {:?} classes {}", m.input_shape, m.num_classes);
    println!("  groups:");
    for g in &m.groups {
        println!("    {:24} dim {:>8}  ({} tensors)", g.name, g.dim, g.params.len());
    }
    println!("  entries: {}", m.entries.keys().cloned().collect::<Vec<_>>().join(", "));
    println!(
        "  agg kernels: {} dims x m in {:?}",
        m.agg_by_dim.len(),
        m.agg_by_dim
            .values()
            .next()
            .map(|v| v.keys().cloned().collect::<Vec<_>>())
            .unwrap_or_default()
    );
    Ok(())
}

fn run_list() -> Result<()> {
    println!("experiment presets (use with: fedlama repro --table <id>):");
    for id in ALL_TABLE_IDS {
        let exp = presets::by_id(id, Scale::Default).unwrap();
        println!("  {:10} {} ({} rows)", id, exp.title, exp.rows.len());
    }
    println!("figures (use with: fedlama figure --id <n>): 1..6");
    Ok(())
}
