//! Simulated network substrate: per-layer message ledger and the paper's
//! Eq. 9 communication-cost accounting.

pub mod compression;
pub mod ledger;

pub use compression::{parse as parse_compressor, Compressor, Dense, Quantizer, Spec, TopK};
pub use ledger::{ClientComm, CommLedger, GroupComm, ParticipantComm};
