//! Update-compression substrates (paper §2 / §7: "harmonizing FedLAMA with
//! gradient compression ... is a promising future work").
//!
//! These compose with the layer-wise schedule: a compressor transforms each
//! layer's *update* (u_l - previous u_l, or the raw tensor) before it is
//! "sent", and the ledger charges the compressed byte count.  Implemented:
//!
//!   - `Quantizer` — QSGD-style stochastic uniform quantization to b bits
//!     with per-chunk scale (Alistarh et al. 2017).
//!   - `TopK` — magnitude sparsification keeping the top k fraction
//!     (Wangni et al. 2017), with index overhead accounted.
//!
//! Both are *lossy simulations* faithful in the quantity the paper reports
//! (Eq. 9 bytes): compress(x) returns the decoded tensor plus the exact
//! encoded size, so experiments measure the accuracy/traffic trade-off of
//! FedLAMA x compression.

use crate::runtime::simd::{self, Isa};
use crate::util::rng::Rng;

/// A lossy update compressor: returns the decoded (lossy) values in place
/// and the encoded size in bytes.
pub trait Compressor {
    fn compress(&mut self, data: &mut [f32]) -> usize;
    fn name(&self) -> String;
}

/// No-op compressor (dense f32): baseline byte accounting.
pub struct Dense;

impl Compressor for Dense {
    fn compress(&mut self, data: &mut [f32]) -> usize {
        std::mem::size_of_val(data)
    }
    fn name(&self) -> String {
        "dense".into()
    }
}

/// QSGD-style stochastic uniform quantization to `bits` bits per value,
/// one f32 scale per `chunk` values.
///
/// The two scale maps (|v|/max·levels forward, q/levels·max back) run on
/// the `runtime::simd` ladder; only the stochastic-rounding draw stays
/// scalar, because the RNG stream is consumed strictly in element order
/// and that order is part of the determinism contract.  Every dispatch
/// path is bit-identical (per-element op sequence unchanged — see
/// `tests/simd_quant.rs`).
pub struct Quantizer {
    pub bits: u32,
    pub chunk: usize,
    rng: Rng,
    isa: Isa,
    scratch: Vec<f32>,
}

impl Quantizer {
    pub fn new(bits: u32, seed: u64) -> Quantizer {
        Quantizer::with_isa(bits, seed, simd::active_isa())
    }

    /// [`Quantizer::new`] pinned to an explicit dispatch path (oracle
    /// tests / A-B benches).
    pub fn with_isa(bits: u32, seed: u64, isa: Isa) -> Quantizer {
        assert!((1..=16).contains(&bits), "bits in 1..=16");
        Quantizer { bits, chunk: 1024, rng: Rng::new(seed).fork(0xC0_DE), isa, scratch: Vec::new() }
    }

    /// Encoded size: bits per value + one f32 scale per chunk.
    pub fn encoded_bytes(&self, n: usize) -> usize {
        let payload = (n * self.bits as usize).div_ceil(8);
        let scales = n.div_ceil(self.chunk) * 4;
        payload + scales
    }
}

impl Compressor for Quantizer {
    fn compress(&mut self, data: &mut [f32]) -> usize {
        let levels = ((1u32 << self.bits) - 1) as f32;
        let Quantizer { chunk, rng, isa, scratch, .. } = self;
        scratch.resize(*chunk, 0.0);
        for chunk_vals in data.chunks_mut(*chunk) {
            let max = chunk_vals.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            if max == 0.0 {
                continue; // no RNG draws: zero chunks are skipped on every path
            }
            // forward map |v| / max * levels (in [0, levels]), vectorized
            let t = &mut scratch[..chunk_vals.len()];
            simd::abs_div_mul(*isa, t, chunk_vals, max, levels);
            // stochastic rounding: unbiased estimator.  Scalar on purpose —
            // one rng.f32() per element, in element order.
            for (v, &ti) in chunk_vals.iter_mut().zip(t.iter()) {
                let lo = ti.floor();
                let q = if rng.f32() < ti - lo { lo + 1.0 } else { lo };
                *v = v.signum() * q;
            }
            // scale back: (signum * q) / levels * max, vectorized
            simd::div_mul(*isa, chunk_vals, levels, max);
        }
        self.encoded_bytes(data.len())
    }
    fn name(&self) -> String {
        format!("q{}", self.bits)
    }
}

/// Top-k magnitude sparsification: keeps the `ratio` fraction of largest-
/// magnitude entries, zeroes the rest.  Encoded size = kept values (f32)
/// + kept indices (u32).
pub struct TopK {
    pub ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> TopK {
        assert!(ratio > 0.0 && ratio <= 1.0);
        TopK { ratio }
    }

    pub fn kept(&self, n: usize) -> usize {
        ((n as f64 * self.ratio).ceil() as usize).clamp(1, n)
    }
}

impl Compressor for TopK {
    fn compress(&mut self, data: &mut [f32]) -> usize {
        let n = data.len();
        let k = self.kept(n);
        if k == n {
            return 4 * n;
        }
        // threshold = k-th largest magnitude (select_nth on a copy)
        let mut mags: Vec<f32> = data.iter().map(|v| v.abs()).collect();
        let idx = n - k;
        mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        let thresh = mags[idx];
        let mut kept = 0usize;
        for v in data.iter_mut() {
            if v.abs() >= thresh && kept < k {
                kept += 1;
            } else {
                *v = 0.0;
            }
        }
        kept * (4 + 4)
    }
    fn name(&self) -> String {
        format!("top{:.0}%", 100.0 * self.ratio)
    }
}

/// A parsed compressor specification.  `Spec` separates *what* transform a
/// spec names from the seeded `Compressor` instance that applies it, so the
/// federation protocol can re-instantiate the same transform with a fresh,
/// message-derived RNG stream per uplink (transport-invariant compression:
/// the lossy values do not depend on which process compresses, or in which
/// order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Spec {
    Dense,
    QBits { bits: u32 },
    TopK { ratio: f64 },
}

impl Spec {
    /// Parse "dense", "qN" (N in 1..=16), "topP" (percent in (0, 100]).
    pub fn parse(spec: &str) -> Option<Spec> {
        if spec == "dense" || spec.is_empty() {
            return Some(Spec::Dense);
        }
        if let Some(bits) = spec.strip_prefix('q').and_then(|s| s.parse::<u32>().ok()) {
            if (1..=16).contains(&bits) {
                return Some(Spec::QBits { bits });
            }
            return None;
        }
        if let Some(pct) = spec.strip_prefix("top").and_then(|s| s.parse::<f64>().ok()) {
            if pct > 0.0 && pct <= 100.0 {
                return Some(Spec::TopK { ratio: pct / 100.0 });
            }
        }
        None
    }

    /// Instantiate the compressor with the given RNG seed.
    pub fn build(&self, seed: u64) -> Box<dyn Compressor> {
        match *self {
            Spec::Dense => Box::new(Dense),
            Spec::QBits { bits } => Box::new(Quantizer::new(bits, seed)),
            Spec::TopK { ratio } => Box::new(TopK::new(ratio)),
        }
    }
}

/// Parse a compressor spec: "dense", "q4", "q8", "top1", "top10" (percent).
pub fn parse(spec: &str, seed: u64) -> Option<Box<dyn Compressor>> {
    Spec::parse(spec).map(|s| s.build(seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn dense_is_identity() {
        let mut v = randvec(100, 1);
        let orig = v.clone();
        let bytes = Dense.compress(&mut v);
        assert_eq!(v, orig);
        assert_eq!(bytes, 400);
    }

    #[test]
    fn quantizer_is_unbiased_and_bounded() {
        let mut q = Quantizer::new(4, 2);
        let orig = randvec(20_000, 3);
        // unbiased: mean of decoded ~= mean of original
        let mut v = orig.clone();
        let bytes = q.compress(&mut v);
        assert!(bytes < 2 * orig.len()); // 4 bits ~ 0.5B + scales < 2B/value
        let mo: f64 = orig.iter().map(|&x| x as f64).sum::<f64>() / orig.len() as f64;
        let md: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!((mo - md).abs() < 0.02, "bias {mo} vs {md}");
        // bounded error: |x - q(x)| <= max/levels per chunk
        for (a, b) in orig.iter().zip(&v) {
            assert!((a - b).abs() <= 4.5 / 15.0 + 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn quantizer_high_bits_near_lossless() {
        let mut q = Quantizer::new(16, 4);
        let orig = randvec(1000, 5);
        let mut v = orig.clone();
        q.compress(&mut v);
        for (a, b) in orig.iter().zip(&v) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn quantizer_zero_chunk_stays_zero() {
        let mut q = Quantizer::new(8, 6);
        let mut v = vec![0.0f32; 512];
        q.compress(&mut v);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn topk_keeps_largest() {
        let mut t = TopK::new(0.1);
        let mut v = randvec(1000, 7);
        let orig = v.clone();
        let bytes = t.compress(&mut v);
        let kept: Vec<usize> = (0..v.len()).filter(|&i| v[i] != 0.0).collect();
        assert!(kept.len() <= 100 + 1);
        assert_eq!(bytes, kept.len() * 8);
        // every kept magnitude >= every dropped magnitude
        let min_kept = kept.iter().map(|&i| orig[i].abs()).fold(f32::INFINITY, f32::min);
        let max_dropped = (0..v.len())
            .filter(|i| !kept.contains(i))
            .map(|i| orig[i].abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped - 1e-6, "{min_kept} < {max_dropped}");
        // kept values unchanged
        for &i in &kept {
            assert_eq!(v[i], orig[i]);
        }
    }

    #[test]
    fn topk_full_ratio_is_dense() {
        let mut t = TopK::new(1.0);
        let mut v = randvec(64, 8);
        let orig = v.clone();
        let bytes = t.compress(&mut v);
        assert_eq!(v, orig);
        assert_eq!(bytes, 256);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(parse("dense", 0).unwrap().name(), "dense");
        assert_eq!(parse("q4", 0).unwrap().name(), "q4");
        assert_eq!(parse("top10", 0).unwrap().name(), "top10%");
        assert!(parse("q99", 0).is_none());
        assert!(parse("bogus", 0).is_none());
        assert!(parse("top0", 0).is_none());
    }

    #[test]
    fn compression_reduces_bytes_ordering() {
        let n = 4096;
        let dense = Dense.compress(&mut randvec(n, 9));
        let q8 = Quantizer::new(8, 10).compress(&mut randvec(n, 9));
        let q4 = Quantizer::new(4, 11).compress(&mut randvec(n, 9));
        let top1 = TopK::new(0.01).compress(&mut randvec(n, 9));
        assert!(top1 < q4 && q4 < q8 && q8 < dense, "{top1} {q4} {q8} {dense}");
    }
}
