//! Per-layer communication accounting (the paper's Eq. 9).
//!
//! The paper reports the total communication cost C = sum_l dim(u_l) * k_l
//! where k_l is the number of aggregations at layer l.  The ledger tracks
//! k_l and C exactly, plus the simulated-network byte count (each
//! aggregation of layer l moves dim*4 bytes up + dim*4 bytes down per
//! active client) and an alpha-beta latency estimate.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::protocol::wire::{Dec, Enc};

/// Per aggregation-unit counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GroupComm {
    pub name: String,
    pub dim: usize,
    /// k_l: number of aggregation events.
    pub syncs: u64,
    /// Eq. 9 contribution: dim * syncs (parameter count, the paper's unit).
    pub cost: u64,
    /// Simulated network bytes (up + down, all active clients).
    pub bytes: u64,
}

/// Per-participant (shard) traffic counters.  Clients map to shards
/// round-robin (client c -> shard c mod n), so these are identical for
/// every transport with the same shard count — the stdio `--workers N`
/// run and an N-participant TCP run charge the same tables.  Bytes are
/// *nominal* (the compressor's idealized encoded size uplink, dense f32
/// downlink), like the rest of the ledger — never the frame overhead of
/// whichever wire carried them.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParticipantComm {
    /// Shard id (worker / TCP participant index).
    pub shard: usize,
    /// `LayerUpdate` messages received from this shard.
    pub updates: u64,
    /// Nominal uplink bytes from this shard (sum of payload encoded sizes;
    /// exact per update, unlike the per-group column's per-client mean).
    pub uplink_bytes: u64,
    /// Nominal downlink bytes to this shard (dense group params per owned
    /// active client per sync decision).
    pub downlink_bytes: u64,
    /// Mid-run departures of this shard (disconnect, timeout, Abort).
    pub departures: u64,
    /// Times a fresh connection claimed this shard after a departure.
    pub rejoins: u64,
    /// Blocks committed by quorum while this shard was absent.
    pub missed_blocks: u64,
    /// Updates from this shard a robust aggregator excluded from the fold
    /// (distance filter or trimmed mean) — counted per (group, client).
    pub rejected_updates: u64,
    /// Updates from this shard the norm-clip screen scaled down onto the
    /// clip radius before folding.
    pub clipped_updates: u64,
}

/// Per registered-client traffic counters, keyed by global client id.
///
/// Shards are a *transport* artifact: the same client folds into
/// different `ParticipantComm` slots depending on the worker count, and
/// a shard slot survives its occupant departing.  These counters instead
/// follow the client itself — across sampling gaps, departures, and
/// rejoins — which is the granularity Eq. 9 actually charges and the one
/// the registry persists.  Only *sampled* clients ever get an entry, so
/// the map stays O(participating), never O(registered).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClientComm {
    /// `LayerUpdate` messages received from this client.
    pub updates: u64,
    /// Nominal uplink bytes (payload encoded sizes, exact per update).
    pub uplink_bytes: u64,
    /// Nominal downlink bytes (dense group params per sync decision).
    pub downlink_bytes: u64,
}

#[derive(Debug, Clone, Default)]
pub struct CommLedger {
    pub groups: Vec<GroupComm>,
    /// Per-shard uplink/downlink counters (one entry when in-proc).
    pub participants: Vec<ParticipantComm>,
    /// Per registered-client counters keyed by global client id; entries
    /// appear on first participation.
    pub clients: BTreeMap<usize, ClientComm>,
    /// Number of synchronization *rounds* (iterations at which >= 1 group
    /// synced) — the latency-bearing events.
    pub rounds: u64,
    /// alpha-beta cost model accumulators.
    pub latency_alpha_events: u64,
    pub latency_beta_bytes: u64,
}

impl CommLedger {
    pub fn new(groups: &[(String, usize)]) -> CommLedger {
        Self::with_shards(groups, 1)
    }

    /// Like [`CommLedger::new`] with `n_shards` per-participant slots
    /// (`n_shards = workers.max(1)` — in-proc runs are one shard).
    pub fn with_shards(groups: &[(String, usize)], n_shards: usize) -> CommLedger {
        CommLedger {
            groups: groups
                .iter()
                .map(|(name, dim)| GroupComm { name: name.clone(), dim: *dim, ..Default::default() })
                .collect(),
            participants: (0..n_shards.max(1))
                .map(|shard| ParticipantComm { shard, ..Default::default() })
                .collect(),
            ..Default::default()
        }
    }

    /// The shard owning a global client id (round-robin, every transport).
    /// 0 for a ledger without participant slots (`Default`-constructed).
    pub fn shard_of(&self, client: usize) -> usize {
        client % self.participants.len().max(1)
    }

    /// Charge one uplink update from `client`: `bytes` nominal encoded
    /// payload bytes.  No-op when the ledger has no participant slots
    /// (`Default`-constructed — group counters still work).
    pub fn record_uplink(&mut self, client: usize, bytes: usize) {
        if self.participants.is_empty() {
            return;
        }
        let s = self.shard_of(client);
        self.participants[s].updates += 1;
        self.participants[s].uplink_bytes += bytes as u64;
        let c = self.clients.entry(client).or_default();
        c.updates += 1;
        c.uplink_bytes += bytes as u64;
    }

    /// Charge one downlink broadcast to `client`: `bytes` nominal dense
    /// bytes of the decided group.
    pub fn record_downlink(&mut self, client: usize, bytes: usize) {
        if self.participants.is_empty() {
            return;
        }
        let s = self.shard_of(client);
        self.participants[s].downlink_bytes += bytes as u64;
        self.clients.entry(client).or_default().downlink_bytes += bytes as u64;
    }

    /// Charge raw per-participant bytes without counting an update message
    /// (FedNova's full-model reduction moves deltas without `LayerUpdate`
    /// uplinks).
    pub fn record_participant_bytes(&mut self, client: usize, up: usize, down: usize) {
        if self.participants.is_empty() {
            return;
        }
        let s = self.shard_of(client);
        self.participants[s].uplink_bytes += up as u64;
        self.participants[s].downlink_bytes += down as u64;
        let c = self.clients.entry(client).or_default();
        c.uplink_bytes += up as u64;
        c.downlink_bytes += down as u64;
    }

    /// Note a mid-run departure of shard `s` (elastic membership).
    pub fn record_departure(&mut self, s: usize) {
        if let Some(p) = self.participants.get_mut(s) {
            p.departures += 1;
        }
    }

    /// Note a fresh connection claiming vacant shard `s`.
    pub fn record_rejoin(&mut self, s: usize) {
        if let Some(p) = self.participants.get_mut(s) {
            p.rejoins += 1;
        }
    }

    /// Note a block committed by quorum while shard `s` was absent.
    pub fn record_missed_block(&mut self, s: usize) {
        if let Some(p) = self.participants.get_mut(s) {
            p.missed_blocks += 1;
        }
    }

    /// Charge a robust-aggregator rejection of one of `client`'s group
    /// updates to its shard.
    pub fn record_rejected(&mut self, client: usize) {
        if self.participants.is_empty() {
            return;
        }
        let s = self.shard_of(client);
        self.participants[s].rejected_updates += 1;
    }

    /// Charge a norm-clip of one of `client`'s group updates to its shard.
    pub fn record_clipped(&mut self, client: usize) {
        if self.participants.is_empty() {
            return;
        }
        let s = self.shard_of(client);
        self.participants[s].clipped_updates += 1;
    }

    /// Record one aggregation of group `g` across `m_active` clients.
    pub fn record_sync(&mut self, g: usize, m_active: usize) {
        let dense_up = self.groups[g].dim * 4;
        self.record_sync_bytes(g, m_active, dense_up);
    }

    /// Like `record_sync` but with a custom per-client uplink byte count
    /// (update compression).  Eq. 9 cost stays in parameter count — the
    /// paper's unit — while the byte column reflects the compressed wire
    /// size (uplink compressed per client + dense downlink broadcast).
    pub fn record_sync_bytes(&mut self, g: usize, m_active: usize, uplink_per_client: usize) {
        let grp = &mut self.groups[g];
        grp.syncs += 1;
        grp.cost += grp.dim as u64;
        let wire = ((uplink_per_client + grp.dim * 4) * m_active) as u64;
        grp.bytes += wire;
        self.latency_beta_bytes += wire;
    }

    /// Record that iteration k had at least one sync (one latency event).
    pub fn record_round(&mut self) {
        self.rounds += 1;
        self.latency_alpha_events += 1;
    }

    /// Paper Eq. 9: total cost in parameter count.
    pub fn total_cost(&self) -> u64 {
        self.groups.iter().map(|g| g.cost).sum()
    }

    pub fn total_syncs(&self) -> u64 {
        self.groups.iter().map(|g| g.syncs).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.groups.iter().map(|g| g.bytes).sum()
    }

    /// Cost relative to a baseline ledger (the paper reports "Comm. cost"
    /// as % of FedAvg with interval tau').
    pub fn cost_ratio_vs(&self, baseline: &CommLedger) -> f64 {
        let b = baseline.total_cost();
        if b == 0 {
            return f64::NAN;
        }
        self.total_cost() as f64 / b as f64
    }

    /// Estimated wall time of communication under an alpha-beta model:
    /// alpha secs/round + beta secs/byte.
    pub fn estimated_latency(&self, alpha: f64, beta: f64) -> f64 {
        self.latency_alpha_events as f64 * alpha + self.latency_beta_bytes as f64 * beta
    }

    /// Per-group sync counts: (name, dim, syncs, cost) — Figures 2 and 3.
    pub fn per_group(&self) -> Vec<(&str, usize, u64, u64)> {
        self.groups.iter().map(|g| (g.name.as_str(), g.dim, g.syncs, g.cost)).collect()
    }

    /// Serialize the full ledger for a coordinator checkpoint.
    pub fn encode(&self, e: &mut Enc) -> Result<()> {
        e.u32(self.groups.len() as u32);
        for g in &self.groups {
            e.str(&g.name)?;
            e.usize(g.dim);
            e.u64(g.syncs);
            e.u64(g.cost);
            e.u64(g.bytes);
        }
        e.u32(self.participants.len() as u32);
        for p in &self.participants {
            e.usize(p.shard);
            e.u64(p.updates);
            e.u64(p.uplink_bytes);
            e.u64(p.downlink_bytes);
            e.u64(p.departures);
            e.u64(p.rejoins);
            e.u64(p.missed_blocks);
            e.u64(p.rejected_updates);
            e.u64(p.clipped_updates);
        }
        e.u32(self.clients.len() as u32);
        for (id, c) in &self.clients {
            e.usize(*id);
            e.u64(c.updates);
            e.u64(c.uplink_bytes);
            e.u64(c.downlink_bytes);
        }
        e.u64(self.rounds);
        e.u64(self.latency_alpha_events);
        e.u64(self.latency_beta_bytes);
        Ok(())
    }

    /// Inverse of [`CommLedger::encode`].
    pub fn decode(d: &mut Dec) -> Result<CommLedger> {
        let n_groups = d.u32()? as usize;
        let mut groups = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            groups.push(GroupComm {
                name: d.str()?,
                dim: d.usize()?,
                syncs: d.u64()?,
                cost: d.u64()?,
                bytes: d.u64()?,
            });
        }
        let n_parts = d.u32()? as usize;
        let mut participants = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            participants.push(ParticipantComm {
                shard: d.usize()?,
                updates: d.u64()?,
                uplink_bytes: d.u64()?,
                downlink_bytes: d.u64()?,
                departures: d.u64()?,
                rejoins: d.u64()?,
                missed_blocks: d.u64()?,
                rejected_updates: d.u64()?,
                clipped_updates: d.u64()?,
            });
        }
        let n_clients = d.u32()? as usize;
        let mut clients = BTreeMap::new();
        for _ in 0..n_clients {
            let id = d.usize()?;
            clients.insert(
                id,
                ClientComm {
                    updates: d.u64()?,
                    uplink_bytes: d.u64()?,
                    downlink_bytes: d.u64()?,
                },
            );
        }
        Ok(CommLedger {
            groups,
            participants,
            clients,
            rounds: d.u64()?,
            latency_alpha_events: d.u64()?,
            latency_beta_bytes: d.u64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger3() -> CommLedger {
        CommLedger::new(&[
            ("conv1".to_string(), 100),
            ("conv2".to_string(), 1000),
            ("fc".to_string(), 10_000),
        ])
    }

    #[test]
    fn eq9_accounting_is_exact() {
        let mut l = ledger3();
        for _ in 0..5 {
            l.record_sync(0, 4);
        }
        for _ in 0..2 {
            l.record_sync(2, 4);
        }
        assert_eq!(l.total_cost(), 5 * 100 + 2 * 10_000);
        assert_eq!(l.total_syncs(), 7);
        assert_eq!(l.groups[0].syncs, 5);
        assert_eq!(l.groups[1].syncs, 0);
        // bytes: dim*4 bytes up+down per client
        assert_eq!(l.groups[0].bytes, 5 * 100 * 4 * 2 * 4);
    }

    #[test]
    fn ratio_vs_baseline() {
        let mut a = ledger3();
        let mut b = ledger3();
        for _ in 0..10 {
            a.record_sync(2, 4);
            b.record_sync(2, 4);
        }
        for _ in 0..10 {
            b.record_sync(0, 4);
            b.record_sync(1, 4);
        }
        let r = a.cost_ratio_vs(&b);
        let expect = 100_000.0 / (100_000.0 + 11_000.0);
        assert!((r - expect).abs() < 1e-12);
    }

    /// Hand-computed Eq. 9 on a 3-group FedLAMA schedule: tau'=6, phi=2,
    /// 48 iterations, m=4 active clients.  The first adjustment (k=12)
    /// relaxes the fc group to tau=12, so from k=13 on it syncs only at
    /// multiples of 12:
    ///
    ///   k:        6   12   18   24   30   36   42   48
    ///   conv1:    x    x    x    x    x    x    x    x    -> 8 syncs
    ///   conv2:    x    x    x    x    x    x    x    x    -> 8 syncs
    ///   fc:       x    x         x         x         x    -> 5 syncs
    ///
    /// C = sum_l dim_l * k_l = 100*8 + 1000*8 + 10000*5 = 58_800.
    #[test]
    fn eq9_matches_hand_computed_three_group_schedule() {
        let mut l = ledger3();
        let dims = [100usize, 1000, 10_000];
        let m = 4;
        let mut syncs = [0u64; 3];
        for k in (6..=48).step_by(6) {
            let fc_due = if k <= 12 { true } else { k % 12 == 0 };
            l.record_round();
            for g in 0..2 {
                l.record_sync(g, m);
                syncs[g] += 1;
            }
            if fc_due {
                l.record_sync(2, m);
                syncs[2] += 1;
            }
        }
        assert_eq!(syncs, [8, 8, 5]);
        assert_eq!(l.total_cost(), 100 * 8 + 1000 * 8 + 10_000 * 5);
        assert_eq!(l.total_cost(), 58_800);
        assert_eq!(l.total_syncs(), 21);
        assert_eq!(l.rounds, 8);
        // vs the FedAvg(6) baseline over the same horizon: 8 full syncs
        let mut avg = ledger3();
        for _ in 0..8 {
            avg.record_round();
            for g in 0..3 {
                avg.record_sync(g, m);
            }
        }
        assert_eq!(avg.total_cost(), 8 * (100 + 1000 + 10_000));
        let ratio = l.cost_ratio_vs(&avg);
        assert!((ratio - 58_800.0 / 88_800.0).abs() < 1e-12);
        // wire bytes: (uplink + downlink) * m per sync, dense f32 both ways
        let expect_bytes: u64 =
            (0..3).map(|g| syncs[g] * (dims[g] * 4 * 2 * m) as u64).sum();
        assert_eq!(l.total_bytes(), expect_bytes);
    }

    /// Compressed uplink: Eq. 9 cost stays in parameter count (the paper's
    /// unit) while the byte column reflects the smaller wire size.
    #[test]
    fn compressed_uplink_shrinks_bytes_not_cost() {
        let mut dense = ledger3();
        let mut q8 = ledger3();
        let m = 4;
        // group 2 (dim 10_000): dense uplink = 40_000 B; q8 ~ 10_040 B
        dense.record_sync(2, m);
        q8.record_sync_bytes(2, m, 10_040);
        assert_eq!(dense.total_cost(), q8.total_cost());
        assert_eq!(dense.groups[2].syncs, q8.groups[2].syncs);
        assert_eq!(dense.total_bytes(), ((40_000 + 40_000) * m) as u64);
        assert_eq!(q8.total_bytes(), ((10_040 + 40_000) * m) as u64);
        assert!(q8.total_bytes() < dense.total_bytes());
    }

    #[test]
    fn per_participant_counters_fold_round_robin() {
        let mut l = CommLedger::with_shards(
            &[("conv1".to_string(), 100), ("fc".to_string(), 1000)],
            3,
        );
        assert_eq!(l.participants.len(), 3);
        // clients 0..5 upload group 0 (100 nominal B each); shard = c % 3
        for c in 0..5 {
            l.record_uplink(c, 100);
        }
        // every client gets the dense fc group (4000 B) pushed down
        for c in 0..5 {
            l.record_downlink(c, 4000);
        }
        assert_eq!(l.participants[0].updates, 2); // clients 0, 3
        assert_eq!(l.participants[1].updates, 2); // clients 1, 4
        assert_eq!(l.participants[2].updates, 1); // client 2
        assert_eq!(l.participants[0].uplink_bytes, 200);
        assert_eq!(l.participants[2].uplink_bytes, 100);
        assert_eq!(l.participants[0].downlink_bytes, 8000);
        assert_eq!(l.participants[2].downlink_bytes, 4000);
        assert_eq!(l.shard_of(7), 1);
        // the default ctor is the single-shard (in-proc) case
        let mut one = CommLedger::new(&[("g".to_string(), 10)]);
        one.record_uplink(9, 40);
        assert_eq!(one.participants.len(), 1);
        assert_eq!(one.participants[0].updates, 1);
    }

    #[test]
    fn membership_counters_track_departures_and_rejoins() {
        let mut l = CommLedger::with_shards(&[("g".to_string(), 10)], 3);
        l.record_departure(1);
        l.record_missed_block(1);
        l.record_missed_block(1);
        l.record_rejoin(1);
        assert_eq!(l.participants[1].departures, 1);
        assert_eq!(l.participants[1].rejoins, 1);
        assert_eq!(l.participants[1].missed_blocks, 2);
        assert_eq!(l.participants[0].departures, 0);
        // out-of-range shards are ignored, not a panic
        l.record_departure(9);
    }

    #[test]
    fn robust_counters_charge_the_owning_shard() {
        let mut l = CommLedger::with_shards(&[("g".to_string(), 10)], 3);
        // clients fold round-robin: 4 -> shard 1, 5 -> shard 2
        l.record_rejected(5);
        l.record_rejected(5);
        l.record_clipped(4);
        assert_eq!(l.participants[2].rejected_updates, 2);
        assert_eq!(l.participants[1].clipped_updates, 1);
        assert_eq!(l.participants[0].rejected_updates, 0);
        assert_eq!(l.participants[0].clipped_updates, 0);
        // a Default-constructed ledger has no participant slots: no-op
        let mut none = CommLedger::default();
        none.record_rejected(0);
        none.record_clipped(0);
    }

    /// Per-client counters are keyed by the registered client id, so they
    /// accumulate across shard remappings (worker-count changes fold the
    /// same client into different shards; the client row must not care).
    #[test]
    fn per_client_counters_survive_shard_remapping() {
        let groups = [("g".to_string(), 100)];
        let mut l = CommLedger::with_shards(&groups, 3);
        l.record_uplink(7, 100);
        l.record_downlink(7, 400);
        // simulate resuming the same run with a different shard count:
        // carry the clients map over, as the checkpoint does
        let mut l2 = CommLedger::with_shards(&groups, 5);
        l2.clients = l.clients.clone();
        l2.record_uplink(7, 100);
        l2.record_participant_bytes(7, 8, 16);
        let c = &l2.clients[&7];
        assert_eq!(c.updates, 2);
        assert_eq!(c.uplink_bytes, 208);
        assert_eq!(c.downlink_bytes, 416);
        // shard rows differ across the two ledgers; the client row is one
        assert_eq!(l.shard_of(7), 1);
        assert_eq!(l2.shard_of(7), 2);
        // only sampled clients get entries — the map is O(participating)
        assert_eq!(l2.clients.len(), 1);
    }

    #[test]
    fn ledger_encode_decode_round_trips() {
        let mut l = CommLedger::with_shards(
            &[("conv1".to_string(), 100), ("fc".to_string(), 1000)],
            2,
        );
        l.record_round();
        l.record_sync(0, 3);
        l.record_sync_bytes(1, 3, 1040);
        l.record_uplink(4, 100);
        l.record_uplink(5, 1040);
        l.record_downlink(4, 4000);
        l.record_participant_bytes(9, 7, 11);
        l.record_departure(1);
        l.record_rejoin(1);
        l.record_missed_block(0);
        l.record_rejected(5);
        l.record_clipped(4);
        let mut e = crate::protocol::wire::Enc::new();
        l.encode(&mut e).unwrap();
        let mut d = crate::protocol::wire::Dec::new(&e.buf);
        let back = CommLedger::decode(&mut d).unwrap();
        assert_eq!(d.remaining(), 0);
        assert_eq!(back.groups, l.groups);
        assert_eq!(back.participants, l.participants);
        assert_eq!(back.clients, l.clients);
        assert_eq!(back.rounds, l.rounds);
        assert_eq!(back.latency_alpha_events, l.latency_alpha_events);
        assert_eq!(back.latency_beta_bytes, l.latency_beta_bytes);
    }

    #[test]
    fn latency_model() {
        let mut l = ledger3();
        l.record_round();
        l.record_sync(0, 2);
        l.record_round();
        let t = l.estimated_latency(0.01, 1e-9);
        assert!((t - (0.02 + 1600.0 * 1e-9)).abs() < 1e-12);
    }
}
