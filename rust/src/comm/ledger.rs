//! Per-layer communication accounting (the paper's Eq. 9).
//!
//! The paper reports the total communication cost C = sum_l dim(u_l) * k_l
//! where k_l is the number of aggregations at layer l.  The ledger tracks
//! k_l and C exactly, plus the simulated-network byte count (each
//! aggregation of layer l moves dim*4 bytes up + dim*4 bytes down per
//! active client) and an alpha-beta latency estimate.

/// Per aggregation-unit counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GroupComm {
    pub name: String,
    pub dim: usize,
    /// k_l: number of aggregation events.
    pub syncs: u64,
    /// Eq. 9 contribution: dim * syncs (parameter count, the paper's unit).
    pub cost: u64,
    /// Simulated network bytes (up + down, all active clients).
    pub bytes: u64,
}

#[derive(Debug, Clone, Default)]
pub struct CommLedger {
    pub groups: Vec<GroupComm>,
    /// Number of synchronization *rounds* (iterations at which >= 1 group
    /// synced) — the latency-bearing events.
    pub rounds: u64,
    /// alpha-beta cost model accumulators.
    pub latency_alpha_events: u64,
    pub latency_beta_bytes: u64,
}

impl CommLedger {
    pub fn new(groups: &[(String, usize)]) -> CommLedger {
        CommLedger {
            groups: groups
                .iter()
                .map(|(name, dim)| GroupComm { name: name.clone(), dim: *dim, ..Default::default() })
                .collect(),
            ..Default::default()
        }
    }

    /// Record one aggregation of group `g` across `m_active` clients.
    pub fn record_sync(&mut self, g: usize, m_active: usize) {
        let dense_up = self.groups[g].dim * 4;
        self.record_sync_bytes(g, m_active, dense_up);
    }

    /// Like `record_sync` but with a custom per-client uplink byte count
    /// (update compression).  Eq. 9 cost stays in parameter count — the
    /// paper's unit — while the byte column reflects the compressed wire
    /// size (uplink compressed per client + dense downlink broadcast).
    pub fn record_sync_bytes(&mut self, g: usize, m_active: usize, uplink_per_client: usize) {
        let grp = &mut self.groups[g];
        grp.syncs += 1;
        grp.cost += grp.dim as u64;
        let wire = ((uplink_per_client + grp.dim * 4) * m_active) as u64;
        grp.bytes += wire;
        self.latency_beta_bytes += wire;
    }

    /// Record that iteration k had at least one sync (one latency event).
    pub fn record_round(&mut self) {
        self.rounds += 1;
        self.latency_alpha_events += 1;
    }

    /// Paper Eq. 9: total cost in parameter count.
    pub fn total_cost(&self) -> u64 {
        self.groups.iter().map(|g| g.cost).sum()
    }

    pub fn total_syncs(&self) -> u64 {
        self.groups.iter().map(|g| g.syncs).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.groups.iter().map(|g| g.bytes).sum()
    }

    /// Cost relative to a baseline ledger (the paper reports "Comm. cost"
    /// as % of FedAvg with interval tau').
    pub fn cost_ratio_vs(&self, baseline: &CommLedger) -> f64 {
        let b = baseline.total_cost();
        if b == 0 {
            return f64::NAN;
        }
        self.total_cost() as f64 / b as f64
    }

    /// Estimated wall time of communication under an alpha-beta model:
    /// alpha secs/round + beta secs/byte.
    pub fn estimated_latency(&self, alpha: f64, beta: f64) -> f64 {
        self.latency_alpha_events as f64 * alpha + self.latency_beta_bytes as f64 * beta
    }

    /// Per-group sync counts: (name, dim, syncs, cost) — Figures 2 and 3.
    pub fn per_group(&self) -> Vec<(&str, usize, u64, u64)> {
        self.groups.iter().map(|g| (g.name.as_str(), g.dim, g.syncs, g.cost)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger3() -> CommLedger {
        CommLedger::new(&[
            ("conv1".to_string(), 100),
            ("conv2".to_string(), 1000),
            ("fc".to_string(), 10_000),
        ])
    }

    #[test]
    fn eq9_accounting_is_exact() {
        let mut l = ledger3();
        for _ in 0..5 {
            l.record_sync(0, 4);
        }
        for _ in 0..2 {
            l.record_sync(2, 4);
        }
        assert_eq!(l.total_cost(), 5 * 100 + 2 * 10_000);
        assert_eq!(l.total_syncs(), 7);
        assert_eq!(l.groups[0].syncs, 5);
        assert_eq!(l.groups[1].syncs, 0);
        // bytes: dim*4 bytes up+down per client
        assert_eq!(l.groups[0].bytes, 5 * 100 * 4 * 2 * 4);
    }

    #[test]
    fn ratio_vs_baseline() {
        let mut a = ledger3();
        let mut b = ledger3();
        for _ in 0..10 {
            a.record_sync(2, 4);
            b.record_sync(2, 4);
        }
        for _ in 0..10 {
            b.record_sync(0, 4);
            b.record_sync(1, 4);
        }
        let r = a.cost_ratio_vs(&b);
        let expect = 100_000.0 / (100_000.0 + 11_000.0);
        assert!((r - expect).abs() < 1e-12);
    }

    #[test]
    fn latency_model() {
        let mut l = ledger3();
        l.record_round();
        l.record_sync(0, 2);
        l.record_round();
        let t = l.estimated_latency(0.01, 1e-9);
        assert!((t - (0.02 + 1600.0 * 1e-9)).abs() < 1e-12);
    }
}
