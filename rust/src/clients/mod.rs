//! Client state and participation sampling.

pub mod sampler;

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// One simulated federated client.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub id: usize,
    /// Local model (same tensor layout as the manifest).
    pub params: Vec<HostTensor>,
    /// Model at the start of the current round (FedProx reference /
    /// FedNova delta base).  Only kept when the algorithm needs it.
    pub round_start: Option<Vec<HostTensor>>,
    /// SCAFFOLD client control variate c_i.
    pub control: Option<Vec<HostTensor>>,
    /// Local steps taken in the current round (FedNova a_i accounting).
    pub steps_in_round: usize,
    /// Target local steps this round (heterogeneous workloads; usize::MAX
    /// means "every iteration").
    pub local_budget: usize,
    /// Private data-sampling stream (deterministic per client).
    pub rng: Rng,
}

impl ClientState {
    pub fn new(id: usize, params: Vec<HostTensor>, seed: u64) -> ClientState {
        ClientState {
            id,
            params,
            round_start: None,
            control: None,
            steps_in_round: 0,
            local_budget: usize::MAX,
            rng: Rng::new(seed).fork(id as u64 ^ 0xC11E_17),
        }
    }

    /// Cheap placeholder left in the fleet while a client's real state is
    /// temporarily moved out for a parallel training block
    /// (`runtime::cluster`).  Never trained or aggregated.
    pub fn placeholder() -> ClientState {
        ClientState {
            id: usize::MAX,
            params: Vec::new(),
            round_start: None,
            control: None,
            steps_in_round: 0,
            local_budget: 0,
            rng: Rng::new(0),
        }
    }

    /// Download the current global model.
    pub fn pull(&mut self, global: &[HostTensor]) {
        for (p, g) in self.params.iter_mut().zip(global) {
            p.data.copy_from_slice(&g.data);
        }
    }

    pub fn snapshot_round_start(&mut self) {
        self.round_start = Some(self.params.clone());
    }
}

pub use sampler::ClientSampler;
