//! Partial-participation sampling: at every round boundary (phi*tau'
//! iterations), a fresh subset of clients becomes active (paper §6,
//! "randomly chosen 25% of the clients participate ... at every phi*tau'
//! iterations").
//!
//! Since the registry subsystem landed, the draw itself is the streaming
//! O(sampled) Fisher–Yates from `registry::sampler` — bit-identical to
//! the eager `Rng::choose_k` it replaced (same rng draws, same indices),
//! so every existing run reproduces exactly while the coordinator no
//! longer materializes the roster to sample it.

use crate::registry::sampler::{sample_stream, SAMPLER_STREAM};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ClientSampler {
    pub n_clients: usize,
    pub n_active: usize,
    rng: Rng,
}

impl ClientSampler {
    /// `active_ratio` in (0, 1]; at least one client is always active.
    pub fn new(n_clients: usize, active_ratio: f64, seed: u64) -> ClientSampler {
        assert!(n_clients > 0);
        assert!(active_ratio > 0.0 && active_ratio <= 1.0, "active_ratio in (0,1]");
        let n_active = ((n_clients as f64 * active_ratio).round() as usize).clamp(1, n_clients);
        ClientSampler { n_clients, n_active, rng: Rng::new(seed).fork(SAMPLER_STREAM) }
    }

    /// Sample the active set for the next round (sorted, distinct).
    pub fn sample(&mut self) -> Vec<usize> {
        if self.n_active == self.n_clients {
            return (0..self.n_clients).collect();
        }
        let mut ids = sample_stream(&mut self.rng, self.n_clients, self.n_active);
        ids.sort_unstable();
        ids
    }

    /// Rng snapshot for checkpointing.
    pub fn rng_state(&self) -> ([u64; 4], Option<f64>) {
        self.rng.state()
    }

    /// Restore the rng from a checkpoint snapshot.
    pub fn restore_rng(&mut self, s: [u64; 4], spare: Option<f64>) {
        self.rng = Rng::from_state(s, spare);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_is_identity() {
        let mut s = ClientSampler::new(8, 1.0, 1);
        assert_eq!(s.sample(), (0..8).collect::<Vec<_>>());
        assert_eq!(s.sample(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn partial_is_distinct_and_sized() {
        let mut s = ClientSampler::new(16, 0.25, 2);
        for _ in 0..50 {
            let ids = s.sample();
            assert_eq!(ids.len(), 4);
            let mut d = ids.clone();
            d.dedup();
            assert_eq!(d.len(), 4);
            assert!(ids.iter().all(|&i| i < 16));
        }
    }

    #[test]
    fn rounds_vary_and_cover() {
        let mut s = ClientSampler::new(16, 0.25, 3);
        let mut seen = vec![false; 16];
        let mut distinct_rounds = std::collections::BTreeSet::new();
        for _ in 0..100 {
            let ids = s.sample();
            for &i in &ids {
                seen[i] = true;
            }
            distinct_rounds.insert(ids);
        }
        assert!(seen.iter().all(|&b| b), "all clients eventually sampled");
        assert!(distinct_rounds.len() > 10, "sampling should vary across rounds");
    }

    #[test]
    fn at_least_one_active() {
        let mut s = ClientSampler::new(3, 0.01, 4);
        assert_eq!(s.sample().len(), 1);
    }
}
