//! Training metrics: loss/accuracy curves, run reports, CSV + JSON emit,
//! and paper-style table formatting.

pub mod tables;

use crate::comm::{ClientComm, CommLedger, ParticipantComm};
use crate::util::json::Json;

/// One point on the learning curve (recorded at round boundaries).
#[derive(Debug, Clone, PartialEq)]
pub struct CurvePoint {
    pub iteration: usize,
    pub round: usize,
    pub train_loss: f64,
    /// Present only at eval rounds.
    pub val_acc: Option<f64>,
    pub val_loss: Option<f64>,
    /// Eq. 9 cumulative comm cost at this point.
    pub comm_cost: u64,
}

/// Complete record of one training run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub tag: String,
    pub curve: Vec<CurvePoint>,
    pub final_acc: f64,
    pub final_loss: f64,
    pub wall_secs: f64,
    pub total_comm_cost: u64,
    pub total_syncs: u64,
    pub total_bytes: u64,
    /// Per-group (name, dim, syncs, cost) — Figures 2/3.
    pub per_group: Vec<(String, usize, u64, u64)>,
    /// Per-participant counters (updates, nominal Eq.9-style bytes,
    /// elastic-membership events) folded by round-robin shard.  Identical
    /// across transports with the same shard count (in-proc runs have one
    /// shard, so compare it only between runs sharing a worker count).
    pub per_participant: Vec<ParticipantComm>,
    /// Per registered-client counters keyed by global client id — the
    /// shard-independent view (one row per client that ever participated;
    /// survives sampling gaps and worker-count changes across a resume).
    pub per_client: Vec<(usize, ClientComm)>,
    /// Coordinator overhead: wall time not spent inside PJRT executables.
    pub runtime_secs: f64,
    /// Local-training examples *assigned* (block steps x batch size,
    /// counted for clients that reported a finite block loss).  Exact
    /// under homogeneous budgets; an upper bound under `--hetero`, where
    /// a client's budget can run out mid-block.
    pub train_samples: u64,
    /// Training throughput: `train_samples` over the summed
    /// (eval-excluded) round wall time, so the number is invariant to
    /// `--eval-every` cadence (assigned samples — see `train_samples`
    /// for the hetero caveat).
    pub samples_per_sec: f64,
    /// Wall seconds per completed round, evaluation excluded — feed
    /// `util::stats::percentile` for the p50/p95 the CLI prints.
    pub round_wall_secs: Vec<f64>,
}

impl RunMetrics {
    /// Round wall-time percentile in milliseconds (0 when no rounds ran).
    pub fn round_wall_ms_pct(&self, p: f64) -> f64 {
        if self.round_wall_secs.is_empty() {
            return 0.0;
        }
        1e3 * crate::util::stats::percentile(&self.round_wall_secs, p)
    }

    pub fn record_ledger(&mut self, ledger: &CommLedger) {
        self.total_comm_cost = ledger.total_cost();
        self.total_syncs = ledger.total_syncs();
        self.total_bytes = ledger.total_bytes();
        self.per_group = ledger
            .per_group()
            .into_iter()
            .map(|(n, d, s, c)| (n.to_string(), d, s, c))
            .collect();
        self.per_participant = ledger.participants.clone();
        self.per_client = ledger.clients.iter().map(|(id, c)| (*id, c.clone())).collect();
    }

    /// Paper-style "Comm. cost" percentage vs a baseline run.
    pub fn comm_pct_vs(&self, baseline: &RunMetrics) -> f64 {
        if baseline.total_comm_cost == 0 {
            return f64::NAN;
        }
        100.0 * self.total_comm_cost as f64 / baseline.total_comm_cost as f64
    }

    /// Learning curve as CSV (iteration,round,loss,acc,comm).
    pub fn curve_csv(&self) -> String {
        let mut s = String::from("iteration,round,train_loss,val_acc,val_loss,comm_cost\n");
        for p in &self.curve {
            s.push_str(&format!(
                "{},{},{:.6},{},{},{}\n",
                p.iteration,
                p.round,
                p.train_loss,
                p.val_acc.map(|v| format!("{v:.4}")).unwrap_or_default(),
                p.val_loss.map(|v| format!("{v:.4}")).unwrap_or_default(),
                p.comm_cost
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tag", Json::str(self.tag.clone())),
            ("final_acc", Json::num(self.final_acc)),
            ("final_loss", Json::num(self.final_loss)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("total_comm_cost", Json::num(self.total_comm_cost as f64)),
            ("total_syncs", Json::num(self.total_syncs as f64)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            (
                "throughput",
                Json::obj(vec![
                    ("train_samples", Json::num(self.train_samples as f64)),
                    ("samples_per_sec", Json::num(self.samples_per_sec)),
                    ("round_wall_ms_p50", Json::num(self.round_wall_ms_pct(50.0))),
                    ("round_wall_ms_p95", Json::num(self.round_wall_ms_pct(95.0))),
                    ("rounds_timed", Json::num(self.round_wall_secs.len() as f64)),
                ]),
            ),
            (
                "per_group",
                Json::arr(self.per_group.iter().map(|(n, d, s, c)| {
                    Json::obj(vec![
                        ("name", Json::str(n.clone())),
                        ("dim", Json::num(*d as f64)),
                        ("syncs", Json::num(*s as f64)),
                        ("cost", Json::num(*c as f64)),
                    ])
                })),
            ),
            (
                "per_participant",
                Json::arr(self.per_participant.iter().map(|p| {
                    Json::obj(vec![
                        ("shard", Json::num(p.shard as f64)),
                        ("updates", Json::num(p.updates as f64)),
                        ("uplink_bytes", Json::num(p.uplink_bytes as f64)),
                        ("downlink_bytes", Json::num(p.downlink_bytes as f64)),
                        ("departures", Json::num(p.departures as f64)),
                        ("rejoins", Json::num(p.rejoins as f64)),
                        ("missed_blocks", Json::num(p.missed_blocks as f64)),
                        ("rejected_updates", Json::num(p.rejected_updates as f64)),
                        ("clipped_updates", Json::num(p.clipped_updates as f64)),
                    ])
                })),
            ),
            (
                "per_client",
                Json::arr(self.per_client.iter().map(|(id, c)| {
                    Json::obj(vec![
                        ("client", Json::num(*id as f64)),
                        ("updates", Json::num(c.updates as f64)),
                        ("uplink_bytes", Json::num(c.uplink_bytes as f64)),
                        ("downlink_bytes", Json::num(c.downlink_bytes as f64)),
                    ])
                })),
            ),
            (
                "curve",
                Json::arr(self.curve.iter().map(|p| {
                    Json::obj(vec![
                        ("iter", Json::num(p.iteration as f64)),
                        ("loss", Json::num(p.train_loss)),
                        ("acc", p.val_acc.map(Json::num).unwrap_or(Json::Null)),
                        ("comm", Json::num(p.comm_cost as f64)),
                    ])
                })),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics_with(cost: u64) -> RunMetrics {
        RunMetrics { total_comm_cost: cost, ..Default::default() }
    }

    #[test]
    fn comm_pct() {
        let a = metrics_with(50);
        let b = metrics_with(200);
        assert!((a.comm_pct_vs(&b) - 25.0).abs() < 1e-12);
        assert!(a.comm_pct_vs(&metrics_with(0)).is_nan());
    }

    #[test]
    fn csv_and_json_round_trip() {
        let mut m = RunMetrics { tag: "fedlama(6,4)".into(), ..Default::default() };
        m.curve.push(CurvePoint {
            iteration: 24,
            round: 1,
            train_loss: 2.3,
            val_acc: Some(0.41),
            val_loss: Some(2.1),
            comm_cost: 1234,
        });
        m.curve.push(CurvePoint {
            iteration: 48,
            round: 2,
            train_loss: 2.0,
            val_acc: None,
            val_loss: None,
            comm_cost: 2468,
        });
        m.per_participant = (0..2)
            .map(|shard| ParticipantComm {
                shard,
                updates: 8,
                uplink_bytes: 4096,
                downlink_bytes: 2048,
                ..Default::default()
            })
            .collect();
        m.per_client = vec![
            (3, ClientComm { updates: 5, uplink_bytes: 100, downlink_bytes: 200 }),
            (9, ClientComm { updates: 2, uplink_bytes: 40, downlink_bytes: 80 }),
        ];
        let csv = m.curve_csv();
        assert!(csv.contains("24,1,2.300000,0.4100,2.1000,1234"));
        assert!(csv.lines().count() == 3);
        let j = m.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("tag").unwrap().as_str(), Some("fedlama(6,4)"));
        assert_eq!(parsed.get("curve").unwrap().as_arr().unwrap().len(), 2);
        let pp = parsed.get("per_participant").unwrap().as_arr().unwrap();
        assert_eq!(pp.len(), 2);
        assert_eq!(pp[1].get("shard").unwrap().as_usize(), Some(1));
        assert_eq!(pp[1].get("uplink_bytes").unwrap().as_usize(), Some(4096));
        assert_eq!(pp[1].get("downlink_bytes").unwrap().as_usize(), Some(2048));
        assert_eq!(pp[1].get("rejected_updates").unwrap().as_usize(), Some(0));
        assert_eq!(pp[1].get("clipped_updates").unwrap().as_usize(), Some(0));
        let pc = parsed.get("per_client").unwrap().as_arr().unwrap();
        assert_eq!(pc.len(), 2);
        assert_eq!(pc[0].get("client").unwrap().as_usize(), Some(3));
        assert_eq!(pc[0].get("updates").unwrap().as_usize(), Some(5));
        assert_eq!(pc[1].get("downlink_bytes").unwrap().as_usize(), Some(80));
    }

    #[test]
    fn throughput_percentiles_and_json() {
        let m = RunMetrics {
            train_samples: 4096,
            samples_per_sec: 1024.0,
            round_wall_secs: (1..=100).map(|i| i as f64 * 1e-3).collect(),
            ..Default::default()
        };
        // nearest-rank on 1..=100 ms: p50 -> index 50 -> 51 ms, p95 -> 95 ms
        assert!((m.round_wall_ms_pct(50.0) - 51.0).abs() < 1e-9);
        assert!((m.round_wall_ms_pct(95.0) - 95.0).abs() < 1e-9);
        let t = m.to_json();
        let tp = t.get("throughput").unwrap();
        assert_eq!(tp.get("train_samples").unwrap().as_usize(), Some(4096));
        assert_eq!(tp.get("rounds_timed").unwrap().as_usize(), Some(100));
        // no rounds -> percentiles report 0 instead of panicking
        assert_eq!(RunMetrics::default().round_wall_ms_pct(95.0), 0.0);
    }
}
