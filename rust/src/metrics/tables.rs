//! Paper-style table formatting: the bench harness prints the same rows
//! the paper's tables report.

/// A rendered table with a title and aligned columns.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for i in 0..ncol {
                s.push_str(&format!("{:w$} | ", cells[i], w = widths[i]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep, &widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavored markdown rendering (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format an accuracy cell like the paper: "88.41 ±0.01%".
pub fn acc_cell(mean: f64, std: f64) -> String {
    format!("{:.2} ±{:.2}%", 100.0 * mean, 100.0 * std)
}

/// Format a comm-cost cell like the paper: "62.33%".
pub fn pct_cell(pct: f64) -> String {
    format!("{pct:.2}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Table 1", &["LR", "tau", "phi", "acc", "comm"]);
        t.row(vec!["0.8".into(), "6".into(), "1 (FedAvg)".into(), acc_cell(0.8837, 0.0002), pct_cell(100.0)]);
        t.row(vec!["0.4".into(), "6".into(), "2 (FedLAMA)".into(), acc_cell(0.8841, 0.0001), pct_cell(62.33)]);
        let s = t.render();
        assert!(s.contains("== Table 1 =="));
        assert!(s.contains("88.37 ±0.02%"));
        assert!(s.contains("62.33%"));
        // every body line has the same column separators
        for line in s.lines().skip(1) {
            assert_eq!(line.matches('|').count(), 6, "bad row: {line}");
        }
    }

    #[test]
    fn markdown() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
