//! FedLAMA: layer-wise adaptive model aggregation for scalable federated
//! learning (AAAI'23) — rust coordinator with a hermetic native compute
//! backend (default) and an optional JAX/Pallas AOT compute stack behind
//! `--features pjrt`.
//!
//! The federation loop is a message protocol (`protocol`): a pure
//! `CoordinatorCore` exchanges typed, wire-encodable messages with
//! `Participant`s over a `Transport` — in-proc by default, `--workers N`
//! subprocesses for multi-process runs, bit-identical either way.
//!
//! See rust/DESIGN.md for the architecture (protocol roles and wire
//! format, backend trait, cluster threading model, artifact-vs-native
//! execution paths).

pub mod aggregation;
pub mod bench;
pub mod clients;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod protocol;
pub mod registry;
pub mod runtime;
pub mod util;

pub use config::{Algorithm, EngineKind, PartitionKind, RunConfig};
pub use coordinator::Coordinator;
pub use protocol::{CoordinatorCore, Participant, Transport};
pub use runtime::{ComputeBackend, NativeBackend};
pub mod reports;
