//! FedLAMA: layer-wise adaptive model aggregation for scalable federated
//! learning (AAAI'23) — rust coordinator + JAX/Pallas AOT compute stack.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured reproduction results.

pub mod aggregation;
pub mod clients;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod runtime;
pub mod util;

pub use config::{Algorithm, PartitionKind, RunConfig};
pub use coordinator::Coordinator;
pub mod reports;
