//! The federated training coordinator: Algorithm 1 end-to-end.
//!
//! One `Coordinator` owns a compute backend (a native layer-graph model
//! from `runtime::zoo` by default, PJRT behind `--features pjrt`), the
//! simulated client fleet, the layer-wise
//! aggregation schedule, and the communication ledger, and runs the
//! paper's training loop:
//!
//!   for k = 1..K:
//!     every active client takes one local SGD step        (L2 compute)
//!     for every group with k mod tau_l == 0:
//!       aggregate layer l across clients + measure d_l    (L1 kernel)
//!     if k mod phi*tau' == 0:
//!       adjust intervals (Algorithm 2), resample clients  (L3, this file)
//!
//! The loop is blocked by base-interval gaps so local work can use the
//! fused `train_chunk` path (K steps per call) — all sync points are
//! multiples of tau' by construction.  Within a block the active clients
//! are independent, and `runtime::cluster` fans them across `cfg.threads`
//! workers when the backend is `Sync`; results are bit-identical to the
//! serial order for every thread count.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::aggregation::{AggBackend, AggScratch, Schedule};
use crate::clients::{ClientSampler, ClientState};
use crate::comm::CommLedger;
use crate::config::{Algorithm, EngineKind, PartitionKind, RunConfig};
use crate::data::{
    dirichlet_partition, femnist_partition, iid_partition, ClientData, Generator, Partition,
};
use crate::metrics::{CurvePoint, RunMetrics};
use crate::runtime::{cluster, zoo, ComputeBackend, GroupInfo, HostTensor, Manifest};
use crate::util::rng::Rng;

pub struct Coordinator {
    pub cfg: RunConfig,
    backend: Box<dyn ComputeBackend>,
    pub gen: Generator,
    pub partition: Partition,
    pub schedule: Schedule,
    pub ledger: CommLedger,
    pub sampler: ClientSampler,
    pub clients: Vec<ClientState>,
    pub global: Vec<HostTensor>,
    /// SCAFFOLD server control variate.
    server_control: Option<Vec<HostTensor>>,
    /// Uplink update compressor ("dense" = no-op).
    compressor: Box<dyn crate::comm::Compressor>,
    compress_enabled: bool,
    scratch: AggScratch,
    val_x: Vec<f32>,
    val_y: Vec<i32>,
}

impl Coordinator {
    /// Build a coordinator with the backend `cfg.engine` selects.
    pub fn new(cfg: RunConfig) -> Result<Coordinator> {
        cfg.validate()?;
        let backend: Box<dyn ComputeBackend> = match cfg.engine {
            // The zoo registry resolves the named architecture (and errors
            // on unknown names — no silent MLP fallback).
            EngineKind::Native => Box::new(zoo::build(&cfg.model, cfg.dataset)?),
            EngineKind::Pjrt => load_pjrt_backend(&cfg)?,
        };
        Self::with_backend(cfg, backend)
    }

    /// Build a coordinator around an explicit compute backend.
    pub fn with_backend(cfg: RunConfig, backend: Box<dyn ComputeBackend>) -> Result<Coordinator> {
        cfg.validate()?;
        {
            let manifest = backend.manifest();
            anyhow::ensure!(
                manifest.input_shape == cfg.dataset.input_shape(),
                "model {} input shape {:?} != dataset {:?} shape {:?}",
                manifest.model,
                manifest.input_shape,
                cfg.dataset,
                cfg.dataset.input_shape()
            );
            anyhow::ensure!(
                manifest.num_classes == cfg.dataset.num_classes(),
                "model classes {} != dataset classes {}",
                manifest.num_classes,
                cfg.dataset.num_classes()
            );
        }
        let gen = Generator::new(cfg.dataset, cfg.seed);
        let mut prng = Rng::new(cfg.seed).fork(0x9A27);
        let partition = build_partition(&cfg, &mut prng);
        let dims: Vec<usize> = backend.manifest().groups.iter().map(|g| g.dim).collect();
        let names: Vec<(String, usize)> =
            backend.manifest().groups.iter().map(|g| (g.name.clone(), g.dim)).collect();
        let schedule = Schedule::new(cfg.policy.clone(), dims);
        let ledger = CommLedger::new(&names);
        let sampler = ClientSampler::new(cfg.n_clients, cfg.active_ratio, cfg.seed);
        let global = backend.init_params(cfg.seed as u32)?;
        let clients = (0..cfg.n_clients)
            .map(|i| ClientState::new(i, global.clone(), cfg.seed))
            .collect();
        let eval_b = backend.manifest().eval_batch_size;
        let n_val = (cfg.eval_examples / eval_b).max(1) * eval_b;
        let (val_x, val_y) = gen.validation_set(n_val);
        let compressor = crate::comm::parse_compressor(&cfg.compressor, cfg.seed)
            .ok_or_else(|| anyhow::anyhow!("unknown compressor {:?}", cfg.compressor))?;
        let compress_enabled = cfg.compressor != "dense";
        Ok(Coordinator {
            cfg,
            backend,
            gen,
            partition,
            schedule,
            ledger,
            sampler,
            clients,
            global,
            server_control: None,
            compressor,
            compress_enabled,
            scratch: AggScratch::default(),
            val_x,
            val_y,
        })
    }

    /// Build around a PJRT `ModelRuntime` (compat wrapper).
    #[cfg(feature = "pjrt")]
    pub fn with_runtime(
        cfg: RunConfig,
        runtime: crate::runtime::ModelRuntime,
    ) -> Result<Coordinator> {
        Self::with_backend(cfg, Box::new(runtime))
    }

    /// The backend's manifest (parameter layout and aggregation groups).
    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// The compute backend executing this run.
    pub fn backend(&self) -> &dyn ComputeBackend {
        self.backend.as_ref()
    }

    /// Worker threads the local-training fan-out will actually use: 1 when
    /// the backend is thread-confined (PJRT), otherwise the configured
    /// count with 0 resolving to auto.
    pub fn effective_threads(&self) -> usize {
        if self.backend.as_parallel().is_none() {
            return 1;
        }
        if self.cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            self.cfg.threads
        }
    }

    /// Learning rate at a given round (linear warmup, as in the paper).
    pub fn lr_at(&self, round: usize) -> f32 {
        if self.cfg.warmup_rounds == 0 || round >= self.cfg.warmup_rounds {
            self.cfg.lr
        } else {
            self.cfg.lr * (round + 1) as f32 / self.cfg.warmup_rounds as f32
        }
    }

    /// Run the full training loop; returns the metrics record.
    pub fn run(&mut self) -> Result<RunMetrics> {
        let t0 = Instant::now();
        let round_len = self.cfg.policy.round_len();
        let gap = self.cfg.policy.base_interval();
        let total_rounds = self.cfg.iterations / round_len;
        let mut metrics = RunMetrics { tag: self.cfg.tag(), ..Default::default() };

        // round 0 setup
        let mut active = self.sampler.sample();
        let mut weights = self.partition.active_weights(&active);
        self.begin_round(&active);

        let mut round = 0usize;
        let mut round_loss_sum = 0.0f64;
        let mut round_loss_n = 0usize;

        let blocks = self.cfg.iterations / gap;
        for blk in 1..=blocks {
            let k = blk * gap;
            let lr = self.lr_at(round);

            // --- local training: active clients advance `gap` steps, fanned
            // across the cluster's worker threads (order-preserving).
            let losses = self.run_local_block(&active, gap, lr)?;
            for loss in losses {
                if loss.is_finite() {
                    round_loss_sum += loss;
                    round_loss_n += 1;
                }
            }

            // --- layer-wise aggregation at due groups
            if self.cfg.algorithm == Algorithm::Nova {
                // FedNova replaces plain averaging at the (full-sync) boundary.
                if self.schedule.is_round_boundary(k) {
                    self.nova_aggregate(&active, &weights)?;
                }
            } else {
                if self.cfg.algorithm == Algorithm::Scaffold && self.schedule.is_round_boundary(k) {
                    // control update must read pre-aggregation client params
                    self.scaffold_update_controls(&active, round_len, lr)?;
                }
                let due = self.schedule.due_groups(k);
                if !due.is_empty() {
                    self.ledger.record_round();
                    for g in due {
                        let (disc, uplink) = self.sync_group(g, &active, &weights)?;
                        self.schedule.observe(g, disc);
                        self.ledger.record_sync_bytes(g, active.len(), uplink);
                    }
                }
            }

            // --- Algorithm 2 at round boundaries
            self.schedule.maybe_adjust(k);

            if k % round_len == 0 {
                round += 1;
                let train_loss =
                    if round_loss_n > 0 { round_loss_sum / round_loss_n as f64 } else { 0.0 };
                round_loss_sum = 0.0;
                round_loss_n = 0;

                let do_eval = (self.cfg.eval_every_rounds > 0
                    && round % self.cfg.eval_every_rounds == 0)
                    || round == total_rounds;
                let (val_acc, val_loss) = if do_eval {
                    let (a, l) = self.evaluate()?;
                    (Some(a), Some(l))
                } else {
                    (None, None)
                };
                metrics.curve.push(CurvePoint {
                    iteration: k,
                    round,
                    train_loss,
                    val_acc,
                    val_loss,
                    comm_cost: self.ledger.total_cost(),
                });
                if self.cfg.verbose {
                    let acc =
                        val_acc.map(|a| format!(" acc={:.2}%", 100.0 * a)).unwrap_or_default();
                    eprintln!(
                        "[{}] round {round}/{total_rounds} k={k} loss={train_loss:.4}{acc} comm={}",
                        metrics.tag,
                        self.ledger.total_cost()
                    );
                }

                if round < total_rounds {
                    // partial participation: resample every phi*tau' iters
                    active = self.sampler.sample();
                    weights = self.partition.active_weights(&active);
                    self.begin_round(&active);
                }
            }
        }

        let (acc, loss) = self.evaluate()?;
        metrics.final_acc = acc;
        metrics.final_loss = loss;
        metrics.record_ledger(&self.ledger);
        metrics.wall_secs = t0.elapsed().as_secs_f64();
        metrics.runtime_secs = self.backend.stats_total_secs();
        Ok(metrics)
    }

    /// Round-start bookkeeping: newly active clients download the global
    /// model; algorithm-specific state snapshots.
    fn begin_round(&mut self, active: &[usize]) {
        let hetero = self.cfg.hetero_local_steps;
        let round_len = self.cfg.policy.round_len();
        let mean_n = self.partition.total as f64 / self.cfg.n_clients as f64;
        for &ci in active {
            let need_ref = matches!(self.cfg.algorithm, Algorithm::Prox { .. } | Algorithm::Nova);
            let frac = self.partition.clients[ci].total as f64 / mean_n;
            let c = &mut self.clients[ci];
            c.pull(&self.global);
            c.steps_in_round = 0;
            c.local_budget = if hetero {
                ((round_len as f64 * frac).round() as usize).clamp(1, round_len)
            } else {
                usize::MAX
            };
            if need_ref {
                c.snapshot_round_start();
            }
            if self.cfg.algorithm == Algorithm::Scaffold && c.control.is_none() {
                c.control =
                    Some(self.global.iter().map(|t| HostTensor::zeros(&t.shape)).collect());
            }
        }
        if self.cfg.algorithm == Algorithm::Scaffold && self.server_control.is_none() {
            self.server_control =
                Some(self.global.iter().map(|t| HostTensor::zeros(&t.shape)).collect());
        }
    }

    /// Advance every active client `gap` local steps via the cluster
    /// runtime.  Clients are temporarily moved out of the fleet so the
    /// workers get disjoint `&mut` access; they are restored afterwards.
    /// Returns per-client mean losses in `active` order (NaN = budget
    /// exhausted).
    fn run_local_block(&mut self, active: &[usize], gap: usize, lr: f32) -> Result<Vec<f64>> {
        let mut moved: Vec<ClientState> = active
            .iter()
            .map(|&ci| std::mem::replace(&mut self.clients[ci], ClientState::placeholder()))
            .collect();
        let parts: Vec<&ClientData> =
            active.iter().map(|&ci| &self.partition.clients[ci]).collect();
        let ctx = cluster::StepCtx {
            gen: &self.gen,
            parts: &parts,
            algorithm: self.cfg.algorithm,
            server_control: self.server_control.as_deref(),
            gap,
            lr,
            use_chunk: self.cfg.use_chunk,
        };
        let threads = self.effective_threads();
        let result = match self.backend.as_parallel() {
            Some(par) if threads > 1 => cluster::advance_parallel(par, &ctx, &mut moved, threads),
            _ => cluster::advance_serial(self.backend.as_ref(), &ctx, &mut moved),
        };
        for (&ci, c) in active.iter().zip(moved) {
            self.clients[ci] = c;
        }
        result
    }

    /// Aggregate one group across the active clients (fused L1 kernel when
    /// the backend provides one, native fallback otherwise), write the
    /// result into the global model and broadcast to the active clients.
    /// Returns the group discrepancy sum_i w_i ||u - x_i||^2 and the
    /// per-client uplink byte count (compressed wire size when a compressor
    /// is configured).
    fn sync_group(&mut self, g: usize, active: &[usize], weights: &[f32]) -> Result<(f64, usize)> {
        let group = self.backend.manifest().groups[g].clone();
        let m = active.len();
        // Backend choice: on the CPU PJRT each kernel call pays a fixed
        // ~60-100us literal/dispatch overhead while the native path runs at
        // memory bandwidth (micro-agg bench), so Auto resolves to native
        // here.  `Xla` forces the fused Pallas artifact — the path a TPU
        // deployment would take.
        let use_fused = match self.cfg.backend {
            AggBackend::Native | AggBackend::Auto => false,
            AggBackend::Xla => self.backend.has_fused_agg(group.dim, m),
        };
        if self.cfg.backend == AggBackend::Xla && !use_fused {
            anyhow::bail!(
                "backend=xla but no fused agg kernel for dim={} m={m}; re-run `make artifacts` \
                 with --agg-m including {m}",
                group.dim
            );
        }
        if self.compress_enabled {
            // compression path: clients upload lossy-compressed tensors
            return self.sync_group_compressed(&group, active, weights);
        }
        let disc = if use_fused {
            self.sync_group_fused(&group, active, weights)?
        } else {
            self.sync_group_native(&group, active, weights)?
        };
        Ok((disc, group.dim * 4))
    }

    /// Compression-composed sync (paper §2/§7 future work): each active
    /// client's group tensor is lossy-compressed before aggregation; the
    /// server averages the decoded uploads.  Returns (discrepancy,
    /// per-client uplink bytes).
    fn sync_group_compressed(
        &mut self,
        group: &GroupInfo,
        active: &[usize],
        weights: &[f32],
    ) -> Result<(f64, usize)> {
        let mut disc = 0.0f64;
        let mut uplink = 0usize;
        let m = active.len();
        for &t in &group.params {
            let n = self.global[t].data.len();
            // decode buffer: m rows of the lossy uploads
            let mut decoded = vec![0.0f32; m * n];
            for (row, &ci) in active.iter().enumerate() {
                let dst = &mut decoded[row * n..(row + 1) * n];
                dst.copy_from_slice(&self.clients[ci].params[t].data);
                uplink += self.compressor.compress(dst);
            }
            let rows: Vec<&[f32]> = (0..m).map(|r| &decoded[r * n..(r + 1) * n]).collect();
            disc += crate::aggregation::aggregate_native(&rows, weights, &mut self.global[t].data);
            for &ci in active {
                self.clients[ci].params[t].data.copy_from_slice(&self.global[t].data);
            }
        }
        Ok((disc, uplink / m.max(1)))
    }

    fn sync_group_native(
        &mut self,
        group: &GroupInfo,
        active: &[usize],
        weights: &[f32],
    ) -> Result<f64> {
        let mut disc = 0.0f64;
        for &t in &group.params {
            {
                let rows: Vec<&[f32]> =
                    active.iter().map(|&ci| self.clients[ci].params[t].data.as_slice()).collect();
                disc +=
                    crate::aggregation::aggregate_native(&rows, weights, &mut self.global[t].data);
            }
            for &ci in active {
                self.clients[ci].params[t].data.copy_from_slice(&self.global[t].data);
            }
        }
        Ok(disc)
    }

    fn sync_group_fused(
        &mut self,
        group: &GroupInfo,
        active: &[usize],
        weights: &[f32],
    ) -> Result<f64> {
        let dim = group.dim;
        self.scratch.stack.resize(active.len() * dim, 0.0);
        for (row, &ci) in active.iter().enumerate() {
            let mut off = row * dim;
            for &t in &group.params {
                let src = &self.clients[ci].params[t].data;
                self.scratch.stack[off..off + src.len()].copy_from_slice(src);
                off += src.len();
            }
        }
        let (u, disc) = self
            .backend
            .fused_agg(&self.scratch.stack, weights, dim)?
            .context("fused agg kernel vanished")?;
        // scatter u back into the global tensors + broadcast
        let mut off = 0;
        for &t in &group.params {
            let dst_len = self.global[t].data.len();
            self.global[t].data.copy_from_slice(&u[off..off + dst_len]);
            off += dst_len;
            for &ci in active {
                self.clients[ci].params[t].data.copy_from_slice(&self.global[t].data);
            }
        }
        Ok(disc as f64)
    }

    /// FedNova: normalized averaging of client deltas with heterogeneous
    /// local step counts a_i (Wang et al. 2020).
    fn nova_aggregate(&mut self, active: &[usize], weights: &[f32]) -> Result<f64> {
        let tau_eff: f64 = active
            .iter()
            .zip(weights)
            .map(|(&ci, &w)| w as f64 * self.clients[ci].steps_in_round as f64)
            .sum();
        // global <- global + tau_eff * sum_i w_i (x_i - x_start)/a_i
        for t in 0..self.global.len() {
            let len = self.global[t].data.len();
            let mut delta = vec![0.0f64; len];
            for (&ci, &w) in active.iter().zip(weights) {
                let a_i = self.clients[ci].steps_in_round.max(1) as f64;
                let start = self.clients[ci]
                    .round_start
                    .as_ref()
                    .context("FedNova requires round_start")?;
                let x = &self.clients[ci].params[t].data;
                let s = &start[t].data;
                for j in 0..len {
                    delta[j] += w as f64 * (x[j] - s[j]) as f64 / a_i;
                }
            }
            let gdata = &mut self.global[t].data;
            for j in 0..len {
                gdata[j] += (tau_eff * delta[j]) as f32;
            }
        }
        for &ci in active {
            let global = std::mem::take(&mut self.global);
            self.clients[ci].pull(&global);
            self.global = global;
        }
        // full-model sync: account every group
        self.ledger.record_round();
        let n_groups = self.backend.manifest().groups.len();
        for g in 0..n_groups {
            self.ledger.record_sync(g, active.len());
        }
        Ok(0.0)
    }

    /// SCAFFOLD option-II control update (before aggregation):
    /// c_i+ = c_i - c + (x_start - x_i) / (a_i * lr);  c += sum dc_i / N.
    fn scaffold_update_controls(
        &mut self,
        active: &[usize],
        round_len: usize,
        lr: f32,
    ) -> Result<()> {
        let n = self.cfg.n_clients as f32;
        let server = self.server_control.as_mut().context("server control")?;
        for &ci in active {
            let a_i = self.clients[ci].steps_in_round.max(1).min(round_len) as f32;
            let scale = 1.0 / (a_i * lr);
            let client = &mut self.clients[ci];
            let control = client.control.as_mut().context("client control")?;
            for t in 0..control.len() {
                let x = &client.params[t].data;
                let g = &self.global[t].data; // x_start == global at round start
                let c_t = &mut control[t].data;
                let s_t = &mut server[t].data;
                for j in 0..c_t.len() {
                    let c_new = c_t[j] - s_t[j] + scale * (g[j] - x[j]);
                    let dc = c_new - c_t[j];
                    c_t[j] = c_new;
                    s_t[j] += dc / n;
                }
            }
        }
        Ok(())
    }

    /// Evaluate the global model on the held-out validation set.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let b = self.backend.manifest().eval_batch_size;
        let d = self.gen.input_dim;
        let n = self.val_y.len();
        let mut correct = 0.0f64;
        let mut loss = 0.0f64;
        for s in (0..n).step_by(b) {
            let xs = &self.val_x[s * d..(s + b) * d];
            let ys = &self.val_y[s..s + b];
            let (c, l) = self.backend.eval_step(&self.global, xs, ys)?;
            correct += c as f64;
            loss += l as f64;
        }
        Ok((correct / n as f64, loss / n as f64))
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt_backend(cfg: &RunConfig) -> Result<Box<dyn ComputeBackend>> {
    let runtime = crate::runtime::ModelRuntime::load(&cfg.model_dir)
        .with_context(|| format!("loading artifacts from {}", cfg.model_dir.display()))?;
    Ok(Box::new(runtime))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt_backend(_cfg: &RunConfig) -> Result<Box<dyn ComputeBackend>> {
    anyhow::bail!(
        "this build has no PJRT support: rebuild with `--features pjrt` (and a real \
         xla crate, see rust/DESIGN.md) or use --engine native"
    )
}

fn build_partition(cfg: &RunConfig, rng: &mut Rng) -> Partition {
    let classes = cfg.dataset.num_classes();
    match cfg.partition {
        PartitionKind::Iid => iid_partition(cfg.n_clients, classes, cfg.samples),
        PartitionKind::Dirichlet { alpha } => {
            dirichlet_partition(cfg.n_clients, classes, cfg.samples, alpha, rng)
        }
        PartitionKind::Writers => femnist_partition(
            cfg.n_clients,
            classes,
            cfg.dataset.num_writers().max(cfg.n_clients),
            cfg.samples,
            rng,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;

    #[test]
    fn partition_builder_kinds() {
        let mut rng = Rng::new(1);
        let cfg = RunConfig { n_clients: 4, samples: 100, ..Default::default() };
        let p = build_partition(&cfg, &mut rng);
        assert_eq!(p.clients.len(), 4);
        assert_eq!(p.total, 400);
        let cfg = RunConfig {
            partition: PartitionKind::Dirichlet { alpha: 0.1 },
            n_clients: 4,
            samples: 50,
            ..Default::default()
        };
        let p = build_partition(&cfg, &mut rng);
        assert_eq!(p.clients.len(), 4);
        let cfg = RunConfig {
            partition: PartitionKind::Writers,
            dataset: DatasetKind::Femnist,
            n_clients: 4,
            samples: 64,
            ..Default::default()
        };
        let p = build_partition(&cfg, &mut rng);
        assert!(p.clients.iter().all(|c| !c.writers.is_empty()));
    }

    #[test]
    fn native_coordinator_builds_without_artifacts() {
        let cfg = RunConfig { n_clients: 2, ..Default::default() };
        let coord = Coordinator::new(cfg).unwrap();
        assert_eq!(coord.manifest().model, "native-mlp");
        assert_eq!(coord.clients.len(), 2);
        assert_eq!(coord.global.len(), coord.manifest().num_tensors());
    }

    #[test]
    fn native_coordinator_resolves_zoo_models() {
        let cfg = RunConfig {
            model: "femnist_cnn".into(),
            dataset: DatasetKind::Femnist,
            n_clients: 2,
            ..Default::default()
        };
        let coord = Coordinator::new(cfg).unwrap();
        assert_eq!(coord.manifest().model, "native-femnist-cnn");
        // unknown names error instead of degrading to the MLP
        let cfg = RunConfig { model: "alexnet".into(), ..Default::default() };
        assert!(Coordinator::new(cfg).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_engine_requires_feature() {
        let cfg = RunConfig { engine: EngineKind::Pjrt, ..Default::default() };
        let err = Coordinator::new(cfg).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
