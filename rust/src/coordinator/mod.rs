//! The federated training coordinator: a thin driver over the federation
//! protocol (Algorithm 1 end-to-end).
//!
//! Since the protocol redesign, the coordinator no longer fuses protocol
//! logic, client state, compute dispatch and I/O into one struct.  It
//! composes:
//!
//!   - `protocol::CoordinatorCore` — the pure server state machine
//!     (schedule, ledger, sampler, global params); emits
//!     `RoundAssignment`s, consumes losses + `LayerUpdate`s, emits
//!     `SyncDecision`s.
//!   - a `protocol::Transport` — `InProcTransport` (one participant owning
//!     every client, direct calls; the default) or `ProcessTransport`
//!     (`cfg.workers > 0`: N `fedlama worker` subprocesses over stdio,
//!     clients sharded round-robin).
//!   - a `ComputeBackend` — used here only for evaluation and the
//!     manifest; local training runs inside participants.
//!
//! The training loop (per block of `gap = tau'` iterations):
//!
//!   assignment -> participants train their active shards (L2 compute,
//!   fanned across `cfg.threads` workers) -> layer updates for due groups
//!   -> core aggregates in active order, observes d_l, charges Eq. 9
//!   (L1) -> decisions broadcast -> Algorithm 2 at round boundaries (L3).
//!
//! Every transport is bit-identical to every other (and to the historical
//! monolithic coordinator) because all cross-client reductions happen in
//! the core, ordered by the active list — see `tests/determinism.rs` and
//! `tests/process_transport.rs`.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::aggregation::{AggBackend, Schedule};
use crate::clients::{ClientSampler, ClientState};
use crate::comm::CommLedger;
use crate::config::{Algorithm, EngineKind, RunConfig};
use crate::data::{Generator, Partition};
use crate::metrics::RunMetrics;
use crate::protocol::{
    BlockOutcome, CoordinatorCore, InProcTransport, Participant, ProcessTransport, Transport,
};
use crate::runtime::{zoo, ComputeBackend, HostTensor, Manifest};

pub struct Coordinator {
    pub cfg: RunConfig,
    backend: Arc<dyn ComputeBackend>,
    core: CoordinatorCore,
    /// The in-proc participant (owns every client) when `cfg.workers == 0`;
    /// multi-process runs keep client state inside worker processes.
    participant: Option<Participant>,
    val_x: Vec<f32>,
    val_y: Vec<i32>,
}

impl Coordinator {
    /// Build a coordinator with the backend `cfg.engine` selects.
    pub fn new(cfg: RunConfig) -> Result<Coordinator> {
        cfg.validate()?;
        let backend: Box<dyn ComputeBackend> = match cfg.engine {
            // The zoo registry resolves the named architecture (and errors
            // on unknown names — no silent MLP fallback).
            EngineKind::Native => Box::new(zoo::build(&cfg.model, cfg.dataset)?),
            EngineKind::Pjrt => load_pjrt_backend(&cfg)?,
        };
        Self::with_backend(cfg, backend)
    }

    /// Build a coordinator around an explicit compute backend.  With
    /// `cfg.resume` this restores the round-boundary checkpoint from
    /// `cfg.checkpoint_dir` before any participant is built, so the whole
    /// stack (core counters, sampler rng, participant client rngs) starts
    /// from the snapshot.
    pub fn with_backend(
        mut cfg: RunConfig,
        backend: Box<dyn ComputeBackend>,
    ) -> Result<Coordinator> {
        cfg.validate()?;
        let backend: Arc<dyn ComputeBackend> = Arc::from(backend);
        {
            let manifest = backend.manifest();
            anyhow::ensure!(
                manifest.input_shape == cfg.dataset.input_shape(),
                "model {} input shape {:?} != dataset {:?} shape {:?}",
                manifest.model,
                manifest.input_shape,
                cfg.dataset,
                cfg.dataset.input_shape()
            );
            anyhow::ensure!(
                manifest.num_classes == cfg.dataset.num_classes(),
                "model classes {} != dataset classes {}",
                manifest.num_classes,
                cfg.dataset.num_classes()
            );
        }
        let global = backend.init_params(cfg.seed as u32)?;
        let mut core =
            CoordinatorCore::new(&cfg, backend.manifest().groups.clone(), global.clone());
        if cfg.resume {
            let dir = cfg.checkpoint_dir.clone().context("--resume requires --checkpoint-dir")?;
            let body = crate::registry::checkpoint::read(&dir)
                .with_context(|| format!("--resume: reading checkpoint in {}", dir.display()))?;
            core.restore_checkpoint(&body)?;
            // participants (in-proc below, workers via the Configure frame,
            // TCP joiners via run_serve) fast-forward past exactly the
            // committed blocks
            cfg.resume_blocks = core.completed_blocks();
        }
        let participant = if cfg.workers == 0 {
            // share the core's init/partition instead of re-deriving them
            Some(Participant::with_state(
                &cfg,
                backend.clone(),
                0,
                (0..cfg.n_clients).collect(),
                global,
                core.partition.clone(),
            )?)
        } else {
            None
        };
        let gen = Generator::new(cfg.dataset, cfg.seed);
        let eval_b = backend.manifest().eval_batch_size;
        let n_val = (cfg.eval_examples / eval_b).max(1) * eval_b;
        let (val_x, val_y) = gen.validation_set(n_val);
        Ok(Coordinator { cfg, backend, core, participant, val_x, val_y })
    }

    /// Build around a PJRT `ModelRuntime` (compat wrapper).
    #[cfg(feature = "pjrt")]
    pub fn with_runtime(
        cfg: RunConfig,
        runtime: crate::runtime::ModelRuntime,
    ) -> Result<Coordinator> {
        Self::with_backend(cfg, Box::new(runtime))
    }

    /// The backend's manifest (parameter layout and aggregation groups).
    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// The compute backend executing this run.
    pub fn backend(&self) -> &dyn ComputeBackend {
        self.backend.as_ref()
    }

    /// The protocol core's live schedule (intervals, adjustments).
    pub fn schedule(&self) -> &Schedule {
        &self.core.schedule
    }

    /// The Eq. 9 communication ledger.
    pub fn ledger(&self) -> &CommLedger {
        &self.core.ledger
    }

    /// The participation sampler.
    pub fn sampler(&self) -> &ClientSampler {
        &self.core.sampler
    }

    /// The client data partition.
    pub fn partition(&self) -> &Partition {
        &self.core.partition
    }

    /// The authoritative global model.
    pub fn global(&self) -> &[HostTensor] {
        &self.core.global
    }

    /// The client fleet — in-proc runs only (multi-process runs keep
    /// client state inside the worker processes; this is then empty).
    pub fn clients(&self) -> &[ClientState] {
        self.participant.as_ref().map(|p| p.clients()).unwrap_or(&[])
    }

    /// Worker threads the local-training fan-out will actually use: 1 when
    /// the backend is thread-confined (PJRT), otherwise the configured
    /// count with 0 resolving to auto.
    pub fn effective_threads(&self) -> usize {
        if self.backend.as_parallel().is_none() {
            return 1;
        }
        if self.cfg.threads == 0 {
            crate::util::pool::default_threads()
        } else {
            self.cfg.threads
        }
    }

    /// Learning rate at a given round (linear warmup, as in the paper).
    pub fn lr_at(&self, round: usize) -> f32 {
        self.core.lr_at(round)
    }

    /// Run the full training loop; returns the metrics record.
    pub fn run(&mut self) -> Result<RunMetrics> {
        if self.cfg.workers == 0 {
            let t0 = Instant::now();
            let batch = self.backend.manifest().batch_size;
            let mut p = self.participant.take().context("coordinator already consumed")?;
            let mut transport = InProcTransport::new(&mut p);
            let r = drive(&self.cfg, &mut self.core, &mut transport, batch, &|global| {
                evaluate_global(self.backend.as_ref(), global, &self.val_x, &self.val_y)
            });
            let remote_secs = transport.remote_compute_secs();
            drop(transport);
            self.participant = Some(p);
            self.finish(r?, remote_secs, t0)
        } else {
            let exe = crate::protocol::worker_exe()?;
            let mut transport = ProcessTransport::spawn(&exe, &self.cfg, self.cfg.workers)?;
            // on error run_with_transport skips the graceful shutdown — a
            // worker may be wedged mid-frame — and the drop here kills the
            // children instead of waiting on them
            self.run_with_transport(&mut transport)
        }
    }

    /// Drive the training loop over an externally built transport (TCP
    /// participants via `protocol::tcp`, custom transports in tests).  On
    /// success the transport is shut down gracefully; on error it is left
    /// for the caller to drop (`ProcessTransport` kills its children in
    /// `Drop`, `TcpTransport` closes its sockets).
    pub fn run_with_transport(&mut self, transport: &mut dyn Transport) -> Result<RunMetrics> {
        let t0 = Instant::now();
        let batch = self.backend.manifest().batch_size;
        let r = drive(&self.cfg, &mut self.core, &mut *transport, batch, &|global| {
            evaluate_global(self.backend.as_ref(), global, &self.val_x, &self.val_y)
        });
        let remote_secs = transport.remote_compute_secs();
        let stats = r?;
        transport.shutdown()?;
        self.finish(stats, remote_secs, t0)
    }

    /// Final-metrics assembly shared by every transport path.
    fn finish(&mut self, stats: DriveStats, remote_secs: f64, t0: Instant) -> Result<RunMetrics> {
        let mut metrics = self.core.metrics();
        let (acc, loss) = self.evaluate()?;
        metrics.final_acc = acc;
        metrics.final_loss = loss;
        metrics.wall_secs = t0.elapsed().as_secs_f64();
        metrics.runtime_secs = self.backend.stats_total_secs() + remote_secs;
        metrics.train_samples = stats.train_samples;
        // denominator is the summed (eval-excluded) round wall time, so
        // the throughput number is invariant to --eval-every cadence
        let train_wall: f64 = stats.round_wall_secs.iter().sum();
        metrics.samples_per_sec =
            if train_wall > 0.0 { stats.train_samples as f64 / train_wall } else { 0.0 };
        metrics.round_wall_secs = stats.round_wall_secs;
        Ok(metrics)
    }

    /// Evaluate the global model on the held-out validation set.  Takes
    /// `&self`: evaluation is read-only over the core's global params and
    /// the backend's per-call scratch, so it never demands exclusive
    /// access to the coordinator.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        evaluate_global(self.backend.as_ref(), &self.core.global, &self.val_x, &self.val_y)
    }
}

/// Read-only evaluation of `global` on a validation set.
fn evaluate_global(
    backend: &dyn ComputeBackend,
    global: &[HostTensor],
    val_x: &[f32],
    val_y: &[i32],
) -> Result<(f64, f64)> {
    let b = backend.manifest().eval_batch_size;
    let d: usize = backend.manifest().input_shape.iter().product();
    let n = val_y.len();
    let mut correct = 0.0f64;
    let mut loss = 0.0f64;
    for s in (0..n).step_by(b) {
        let xs = &val_x[s * d..(s + b) * d];
        let ys = &val_y[s..s + b];
        let (c, l) = backend.eval_step(global, xs, ys)?;
        correct += c as f64;
        loss += l as f64;
    }
    Ok((correct / n as f64, loss / n as f64))
}

/// Throughput bookkeeping the driver hands back to `Coordinator::run`.
struct DriveStats {
    /// *Assigned* training examples: block steps (`gap`) x batch size,
    /// counted for clients whose block loss was finite.  Clients that
    /// trained zero steps report NaN and are excluded, but a
    /// `--hetero` client whose budget runs out *mid-block* still counts
    /// the full block — so this is an upper bound under heterogeneous
    /// budgets (exact step counts live in the participants and are not
    /// part of the block result messages).
    train_samples: u64,
    /// Wall seconds per completed round, evaluation excluded.
    round_wall_secs: Vec<f64>,
}

/// The protocol driver: pump assignments through the transport, feed
/// results to the core, dispatch its decisions, and let `eval` answer the
/// core's evaluation requests.  Purely mechanical — every decision lives
/// in `CoordinatorCore`, every FLOP of model compute in the participants.
/// SCAFFOLD and FedNova server reductions run in the core too, fed by the
/// `AlgoState` frames participants ship at round boundaries, so every
/// algorithm works on every transport.
fn drive(
    cfg: &RunConfig,
    core: &mut CoordinatorCore,
    transport: &mut dyn Transport,
    batch_size: usize,
    eval: &dyn Fn(&[HostTensor]) -> Result<(f64, f64)>,
) -> Result<DriveStats> {
    let tag = cfg.tag();
    let mut stats = DriveStats { train_samples: 0, round_wall_secs: Vec::new() };
    if cfg.resume_blocks > 0 {
        // resumed run: every participant was rebuilt from init params and
        // fast-forwarded its rng streams, but its global replica predates
        // the checkpoint — refresh it replica-only (no active clients)
        // before the first block, exactly like a rejoining peer catches up
        for d in core.catchup_decisions() {
            transport.broadcast_decision(&d, &[])?;
        }
        // SCAFFOLD resume: refresh the server-control replica and re-seed
        // per-client control variates from the registry spill (both are
        // None/empty for every other algorithm)
        if let Some(cu) = core.catchup_control() {
            transport.broadcast_control(&cu)?;
        }
        for s in core.catchup_algo()? {
            transport.broadcast_algo(&s)?;
        }
    }
    let mut rounds_done = 0usize;
    let mut round_t0 = Instant::now();
    while let Some(assignment) = core.begin_block() {
        // elastic membership: round boundaries are the only admission
        // points — a rejoiner claims a vacant shard, replays the catch-up
        // decision snapshot replica-only, and works from this round on
        if assignment.new_round && transport.has_pending_members() {
            let catchup = core.catchup_decisions();
            let control = core.catchup_control();
            let algo = core.catchup_algo()?;
            for shard in transport.admit_ready_peers(&catchup, control.as_ref(), &algo)? {
                core.note_rejoin(shard);
            }
        }
        let result = transport.run_block(&assignment)?;
        for &shard in &result.departed {
            core.note_departure(shard);
        }
        for &shard in &result.missed {
            core.note_missed_block(shard);
        }
        core.record_losses(&result.losses);
        let trained = result.losses.iter().filter(|l| l.is_finite()).count();
        stats.train_samples += (trained * assignment.gap * batch_size) as u64;

        let boundary = core.schedule.is_round_boundary(assignment.k);
        if cfg.algorithm == Algorithm::Nova && boundary {
            // transport-complete FedNova: survivors shipped their round
            // deltas as AlgoState frames; the coordinator's normalized
            // fold replaces group-wise averaging and the fresh global goes
            // out as one plain decision per group
            for d in core.nova_fold(assignment.k, &result.algo)? {
                transport.broadcast_decision(&d, &assignment.active)?;
            }
        } else {
            // Backend choice for the weighted average: on CPU the native
            // path runs at memory bandwidth, so Auto resolves to native;
            // `--backend xla` forces the fused Pallas kernel (the TPU
            // deployment path) through the injected hook.
            let decisions = if cfg.backend == AggBackend::Xla && cfg.compressor == "dense" {
                let backend = transport
                    .in_proc()
                    .context("backend=xla requires the in-proc transport")?
                    .backend();
                let mut fused = |stack: &[f32], w: &[f32], dim: usize| {
                    backend.fused_agg(stack, w, dim)?.with_context(|| {
                        format!(
                            "backend=xla but no fused agg kernel for dim={dim} m={}; re-run \
                             `make artifacts` with --agg-m including {}",
                            w.len(),
                            w.len()
                        )
                    })
                };
                core.apply_updates_quorum(
                    &assignment,
                    &result.updates,
                    &result.absent,
                    Some(&mut fused),
                )?
            } else {
                core.apply_updates_quorum(&assignment, &result.updates, &result.absent, None)?
            };
            for d in &decisions {
                transport.broadcast_decision(d, &assignment.active)?;
            }
            if cfg.algorithm == Algorithm::Scaffold && boundary {
                // survivors shipped their refreshed c_i+ as AlgoState
                // frames; fold them into the server control and broadcast
                // the fresh replica for the next round
                let cu = core.scaffold_fold(assignment.k, &result.algo)?;
                transport.broadcast_control(&cu)?;
            }
        }

        if let BlockOutcome::RoundComplete { round, total_rounds, train_loss, eval_due } =
            core.end_block(assignment.k)
        {
            // round wall time closes before evaluation so eval cadence
            // cannot skew the p50/p95 the CLI reports
            stats.round_wall_secs.push(round_t0.elapsed().as_secs_f64());
            let evaled = if eval_due { Some(eval(&core.global)?) } else { None };
            core.complete_round(assignment.k, train_loss, evaled);
            if let Some(dir) = &cfg.checkpoint_dir {
                let body = core.encode_checkpoint()?;
                crate::registry::checkpoint::write_atomic(dir, &body)
                    .with_context(|| format!("writing checkpoint to {}", dir.display()))?;
            }
            rounds_done += 1;
            if cfg.verbose {
                let acc = evaled
                    .map(|(a, _)| format!(" acc={:.2}%", 100.0 * a))
                    .unwrap_or_default();
                eprintln!(
                    "[{tag}] round {round}/{total_rounds} k={} loss={train_loss:.4}{acc} comm={}",
                    assignment.k,
                    core.ledger.total_cost()
                );
            }
            round_t0 = Instant::now();
            // testing knob for checkpoint/resume: stop after N rounds
            // completed *in this process*, as an interrupted run would
            if cfg.halt_after_rounds > 0 && rounds_done >= cfg.halt_after_rounds {
                break;
            }
        }
    }
    Ok(stats)
}

#[cfg(feature = "pjrt")]
fn load_pjrt_backend(cfg: &RunConfig) -> Result<Box<dyn ComputeBackend>> {
    let runtime = crate::runtime::ModelRuntime::load(&cfg.model_dir)
        .with_context(|| format!("loading artifacts from {}", cfg.model_dir.display()))?;
    Ok(Box::new(runtime))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt_backend(_cfg: &RunConfig) -> Result<Box<dyn ComputeBackend>> {
    anyhow::bail!(
        "this build has no PJRT support: rebuild with `--features pjrt` (and a real \
         xla crate, see rust/DESIGN.md) or use --engine native"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_for, DatasetKind};
    use crate::config::PartitionKind;

    #[test]
    fn partition_builder_kinds() {
        let cfg = RunConfig { n_clients: 4, samples: 100, ..Default::default() };
        let p = partition_for(&cfg);
        assert_eq!(p.clients.len(), 4);
        assert_eq!(p.total, 400);
        let cfg = RunConfig {
            partition: PartitionKind::Dirichlet { alpha: 0.1 },
            n_clients: 4,
            samples: 50,
            ..Default::default()
        };
        let p = partition_for(&cfg);
        assert_eq!(p.clients.len(), 4);
        let cfg = RunConfig {
            partition: PartitionKind::Writers,
            dataset: DatasetKind::Femnist,
            n_clients: 4,
            samples: 64,
            ..Default::default()
        };
        let p = partition_for(&cfg);
        assert!(p.clients.iter().all(|c| !c.writers.is_empty()));
    }

    #[test]
    fn native_coordinator_builds_without_artifacts() {
        let cfg = RunConfig { n_clients: 2, ..Default::default() };
        let coord = Coordinator::new(cfg).unwrap();
        assert_eq!(coord.manifest().model, "native-mlp");
        assert_eq!(coord.clients().len(), 2);
        assert_eq!(coord.global().len(), coord.manifest().num_tensors());
    }

    #[test]
    fn native_coordinator_resolves_zoo_models() {
        let cfg = RunConfig {
            model: "femnist_cnn".into(),
            dataset: DatasetKind::Femnist,
            n_clients: 2,
            ..Default::default()
        };
        let coord = Coordinator::new(cfg).unwrap();
        assert_eq!(coord.manifest().model, "native-femnist-cnn");
        // unknown names error instead of degrading to the MLP
        let cfg = RunConfig { model: "alexnet".into(), ..Default::default() };
        assert!(Coordinator::new(cfg).is_err());
    }

    #[test]
    fn evaluate_needs_only_a_shared_reference() {
        let cfg = RunConfig { n_clients: 2, eval_examples: 128, ..Default::default() };
        let coord = Coordinator::new(cfg).unwrap();
        // no &mut in sight: two concurrent-style calls on &self agree
        let a = coord.evaluate().unwrap();
        let b = coord.evaluate().unwrap();
        assert_eq!(a, b, "read-only evaluation must be reproducible");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_engine_requires_feature() {
        let cfg = RunConfig { engine: EngineKind::Pjrt, ..Default::default() };
        let err = Coordinator::new(cfg).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
