//! The transport seam between the coordinator core and its participants.
//!
//! A `Transport` delivers `RoundAssignment`s to every participant, gathers
//! their block results (losses + layer updates), and broadcasts
//! `SyncDecision`s back.  Two implementations:
//!
//!   - [`InProcTransport`] — a direct method-call wrapper around one
//!     `Participant` owning the whole fleet.  No serialization; this is
//!     the rewritten single-process path and reproduces the historical
//!     coordinator bit-for-bit.
//!   - [`super::process::ProcessTransport`] — N `fedlama worker`
//!     subprocesses over stdio pipes speaking the length-prefixed wire
//!     codec, each owning a client shard.
//!
//! Determinism contract: whatever the transport, `run_block` returns
//! losses in *active order* and the full update set for every due group;
//! the core then orders rows by the active list, so worker interleaving
//! can never leak into the numerics.

use anyhow::{Context, Result};

use super::messages::{AlgoState, ControlUpdate, LayerUpdate, RoundAssignment, SyncDecision};
use super::participant::Participant;

/// Round-robin shard map shared by every sharded transport (stdio
/// workers, TCP participants) and by `CommLedger::shard_of`'s inverse:
/// the global client ids shard `shard` of `n` owns.  This single
/// definition is load-bearing for the bit-identity guarantee — an
/// N-participant TCP run equals the N-worker stdio run only because both
/// draw the same map.
pub fn shard_clients(n_clients: usize, n: usize, shard: usize) -> Vec<usize> {
    (0..n_clients).filter(|c| c % n == shard).collect()
}

/// Merged result of one training block across all participants.
pub struct BlockResult {
    /// Per-client mean losses in `assignment.active` order (NaN for
    /// active clients whose shard was absent — the core skips NaN).
    pub losses: Vec<f64>,
    /// Every `LayerUpdate` for the block's due groups (any order; the
    /// core re-orders by the active list).
    pub updates: Vec<LayerUpdate>,
    /// Active clients whose shard sent nothing this block (quorum mode;
    /// empty on a full-roster commit).
    pub absent: Vec<usize>,
    /// Shards absent for this block's commit (vacant or departed).
    pub missed: Vec<usize>,
    /// Shards that departed *during* this block (subset of `missed`).
    pub departed: Vec<usize>,
    /// Per-client algorithm state (SCAFFOLD refreshed controls, FedNova
    /// round deltas) shipped at round boundaries; empty mid-round and for
    /// stateless optimizers.  Any order — the core re-orders by the
    /// active list before folding.
    pub algo: Vec<AlgoState>,
}

impl BlockResult {
    /// A full-roster result — every shard reported (the only case the
    /// in-proc and stdio transports produce).
    pub fn full(
        losses: Vec<f64>,
        updates: Vec<LayerUpdate>,
        algo: Vec<AlgoState>,
    ) -> BlockResult {
        BlockResult {
            losses,
            updates,
            absent: Vec::new(),
            missed: Vec::new(),
            departed: Vec::new(),
            algo,
        }
    }
}

/// Merge (client, loss) pairs from participants into active order,
/// erroring on missing or duplicate clients.
pub fn merge_losses(active: &[usize], pairs: &[(usize, f64)]) -> Result<Vec<f64>> {
    merge_losses_absent(active, pairs, &[])
}

/// Like [`merge_losses`] but tolerating `absent` clients (quorum mode):
/// their slot reports NaN, which `record_losses` skips like a
/// budget-exhausted client.  Clients outside `active` and duplicates are
/// still errors, and so is a *present* client with no loss.
pub fn merge_losses_absent(
    active: &[usize],
    pairs: &[(usize, f64)],
    absent: &[usize],
) -> Result<Vec<f64>> {
    let mut by_client: Vec<Option<f64>> = vec![None; active.len()];
    for &(ci, loss) in pairs {
        let slot = active
            .iter()
            .position(|&a| a == ci)
            .with_context(|| format!("loss reported for inactive client {ci}"))?;
        anyhow::ensure!(by_client[slot].is_none(), "duplicate loss for client {ci}");
        by_client[slot] = Some(loss);
    }
    by_client
        .into_iter()
        .enumerate()
        .map(|(i, l)| {
            if absent.contains(&active[i]) {
                anyhow::ensure!(
                    l.is_none(),
                    "absent client {} reported a loss anyway",
                    active[i]
                );
                return Ok(f64::NAN);
            }
            l.with_context(|| format!("no loss reported for client {}", active[i]))
        })
        .collect()
}

pub trait Transport {
    /// Number of participant endpoints behind this transport.
    fn workers(&self) -> usize;

    /// Deliver the assignment, run the block on every participant, and
    /// return the merged result.
    fn run_block(&mut self, a: &RoundAssignment) -> Result<BlockResult>;

    /// Broadcast an aggregation decision to every participant.
    /// `active` is the assignment's active set (the broadcast targets).
    fn broadcast_decision(&mut self, d: &SyncDecision, active: &[usize]) -> Result<()>;

    /// Broadcast the refreshed SCAFFOLD server control variate to every
    /// participant (round boundaries, after the coordinator fold).
    fn broadcast_control(&mut self, c: &ControlUpdate) -> Result<()>;

    /// Broadcast one client's algorithm catch-up state (the resume path:
    /// registry-spilled SCAFFOLD controls).  Each participant adopts it
    /// if it owns the client and ignores it otherwise.
    fn broadcast_algo(&mut self, s: &AlgoState) -> Result<()>;

    /// Compute seconds accumulated inside remote participants (0 when the
    /// participant shares the driver's backend, as in-proc does).
    fn remote_compute_secs(&self) -> f64 {
        0.0
    }

    /// Direct access to the single in-proc participant, when this
    /// transport has one.  The driver uses it for eval-model access; no
    /// algorithm requires it — SCAFFOLD/FedNova state rides the wire
    /// (`AlgoState` / `ControlUpdate` frames) on every transport.
    fn in_proc(&mut self) -> Option<&mut Participant> {
        None
    }

    /// Whether any connection is parked waiting for a vacant shard
    /// (elastic transports only; `&mut` so the transport can drain its
    /// accept queue while answering).
    fn has_pending_members(&mut self) -> bool {
        false
    }

    /// Admit parked Ready peers into the block loop — called by the
    /// driver at round boundaries only.  `catchup` is the core's current
    /// per-group decision snapshot, applied replica-only by the rejoiner
    /// before its first assignment; `control` and `algo` carry the
    /// SCAFFOLD catch-up state (server control broadcast + spilled
    /// per-client controls — the rejoiner adopts the ones in its shard).
    /// Returns the admitted shard ids.
    fn admit_ready_peers(
        &mut self,
        _catchup: &[SyncDecision],
        _control: Option<&ControlUpdate>,
        _algo: &[AlgoState],
    ) -> Result<Vec<usize>> {
        Ok(Vec::new())
    }

    /// Tear the session down (terminate workers, close pipes).
    fn shutdown(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Single-process transport: one participant, called directly.
pub struct InProcTransport<'a> {
    participant: &'a mut Participant,
}

impl<'a> InProcTransport<'a> {
    pub fn new(participant: &'a mut Participant) -> InProcTransport<'a> {
        InProcTransport { participant }
    }
}

impl Transport for InProcTransport<'_> {
    fn workers(&self) -> usize {
        1
    }

    fn run_block(&mut self, a: &RoundAssignment) -> Result<BlockResult> {
        let (pairs, updates, algo) = self.participant.handle_assignment(a)?;
        Ok(BlockResult::full(merge_losses(&a.active, &pairs)?, updates, algo))
    }

    fn broadcast_decision(&mut self, d: &SyncDecision, active: &[usize]) -> Result<()> {
        self.participant.apply_decision(d, active)
    }

    fn broadcast_control(&mut self, c: &ControlUpdate) -> Result<()> {
        self.participant.set_server_control(c)
    }

    fn broadcast_algo(&mut self, s: &AlgoState) -> Result<()> {
        self.participant.adopt_algo_state(s)
    }

    fn in_proc(&mut self) -> Option<&mut Participant> {
        Some(&mut *self.participant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_losses_orders_and_validates() {
        let active = [2usize, 5, 9];
        let pairs = [(9usize, 3.0), (2, 1.0), (5, 2.0)];
        assert_eq!(merge_losses(&active, &pairs).unwrap(), vec![1.0, 2.0, 3.0]);
        // NaN losses survive the merge (budget-exhausted clients)
        let pairs = [(2usize, f64::NAN), (5, 2.0), (9, 3.0)];
        assert!(merge_losses(&active, &pairs).unwrap()[0].is_nan());
        // missing / duplicate / inactive all rejected
        assert!(merge_losses(&active, &[(2, 1.0), (5, 2.0)]).is_err());
        assert!(merge_losses(&active, &[(2, 1.0), (2, 1.5), (5, 2.0), (9, 3.0)]).is_err());
        assert!(merge_losses(&active, &[(1, 1.0), (5, 2.0), (9, 3.0)]).is_err());
    }

    #[test]
    fn merge_losses_absent_fills_nan_slots() {
        let active = [2usize, 5, 9];
        // client 5's shard departed: its slot becomes NaN
        let merged = merge_losses_absent(&active, &[(2, 1.0), (9, 3.0)], &[5]).unwrap();
        assert_eq!(merged[0], 1.0);
        assert!(merged[1].is_nan());
        assert_eq!(merged[2], 3.0);
        // a loss from a supposedly absent client is a protocol violation
        let err = merge_losses_absent(&active, &[(2, 1.0), (5, 2.0), (9, 3.0)], &[5]);
        assert!(err.is_err());
        // present clients still must report
        assert!(merge_losses_absent(&active, &[(2, 1.0)], &[5]).is_err());
    }
}
