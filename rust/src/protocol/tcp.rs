//! TCP transport: multi-machine federation over real sockets.
//!
//! The same length-prefixed CRC-32 frames the stdio transport writes to
//! pipes, served on `std::net::TcpListener`/`TcpStream` — the first
//! configuration that can federate across machines.  Roles:
//!
//!   - **coordinator** (`fedlama serve --bind ADDR --expect N`):
//!     [`TcpServer::bind`] + [`TcpServer::accept_participants`] produce a
//!     [`TcpTransport`] once N participants completed the join handshake;
//!     `Coordinator::run_with_transport` then drives the ordinary block
//!     loop over it.
//!   - **participant** (`fedlama join --connect ADDR`): [`join`] dials the
//!     coordinator (with connect retries — it may not be up yet), runs the
//!     handshake, rebuilds its `Participant` from the `Configure` frame,
//!     and enters the same serve loop as the stdio worker.
//!
//! Join handshake (participant speaks first — the stdio flow reversed,
//! because over TCP the participant initiates the connection; the pure
//! state machine lives in [`super::core::JoinHandshake`]):
//!
//! ```text
//!   participant                               coordinator
//!     connect ------------------------------->  accept (shard = join order)
//!     Hello{version, 0, 0} ------------------>  version gate
//!     <-- Configure{shard_id, n, shard, cfg} -
//!     (rebuild backend/partition: slow is OK)   heartbeats ready peers
//!     Hello{version, shard_id, shard_len} --->  ready
//!     <-- Heartbeat ping / echo -------------   liveness smoke, then train
//! ```
//!
//! Shards are assigned round-robin over client ids (client c -> shard
//! c mod N) exactly like `--workers N`, so an N-participant TCP run is
//! bit-identical to the N-worker stdio run — including the per-participant
//! ledger tables.  Receive paths use [`super::wire::StreamDecoder`]: a
//! socket read that ends mid-frame is [`super::wire::FrameStatus::Truncated`],
//! so the bytes are kept and the read continues — never treated as a
//! protocol error.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;

use super::core::{JoinAction, JoinHandshake};
use super::messages::{Configure, Heartbeat, Hello, Message, RoundAssignment, SyncDecision};
use super::transport::{merge_losses, shard_clients, BlockResult, Transport};
use super::wire::{StreamDecoder, WIRE_VERSION};

/// Timeout knobs for the coordinator side.
#[derive(Debug, Clone)]
pub struct TcpOpts {
    /// Window for all `--expect` participants to complete the join
    /// handshake.
    pub join_timeout: Duration,
    /// Per-read timeout once training runs (covers a full local-training
    /// block on the slowest participant, so it is generous).
    pub io_timeout: Duration,
    /// Liveness-ping cadence toward ready peers while slower ones are
    /// still joining.
    pub heartbeat_every: Duration,
}

impl Default for TcpOpts {
    fn default() -> TcpOpts {
        TcpOpts {
            join_timeout: Duration::from_secs(120),
            io_timeout: Duration::from_secs(600),
            heartbeat_every: Duration::from_secs(2),
        }
    }
}

/// Options for the participant side ([`join`]).
#[derive(Debug, Clone)]
pub struct JoinOpts {
    /// Keep retrying the initial connect for this long (the coordinator
    /// may not be listening yet when the participant starts).
    pub connect_retry: Duration,
    /// Read timeout while waiting for the next coordinator frame (covers
    /// the coordinator waiting on the slowest *other* participant).
    pub io_timeout: Duration,
}

impl Default for JoinOpts {
    fn default() -> JoinOpts {
        JoinOpts { connect_retry: Duration::from_secs(30), io_timeout: Duration::from_secs(600) }
    }
}

/// One connected participant on the coordinator side.
struct Peer {
    shard: usize,
    /// Global client ids this shard owns (`transport::shard_clients` —
    /// the same map as `--workers`).
    shard_clients: Vec<usize>,
    stream: TcpStream,
    addr: SocketAddr,
    decoder: StreamDecoder,
    handshake: JoinHandshake,
    /// Outstanding liveness-ping nonce, if any.
    pending_ping: Option<u64>,
    pings_sent: u64,
    compute_secs: f64,
}

impl Peer {
    fn describe(&self) -> String {
        format!("participant shard {} ({})", self.shard, self.addr)
    }

    /// Blocking receive of one message (the socket must be in blocking
    /// mode with a read timeout).  A read that ends mid-frame keeps the
    /// bytes buffered and reads on — only corruption, timeout, or EOF
    /// fail.
    fn recv(&mut self) -> Result<Message> {
        loop {
            if let Some(m) =
                self.decoder.poll_message().with_context(|| format!("from {}", self.describe()))?
            {
                return Ok(m);
            }
            let mut buf = [0u8; 64 * 1024];
            match self.stream.read(&mut buf) {
                Ok(0) => bail!("{} closed the connection mid-session", self.describe()),
                Ok(n) => self.decoder.extend(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    bail!("timed out waiting for a frame from {}", self.describe())
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(e).with_context(|| format!("reading from {}", self.describe()))
                }
            }
        }
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        msg.write_to(&mut self.stream).with_context(|| format!("to {}", self.describe()))
    }
}

/// A bound listener, split from the accept phase so callers can report
/// the actual bound address (`--bind 127.0.0.1:0` picks a free port).
pub struct TcpServer {
    listener: TcpListener,
}

impl TcpServer {
    pub fn bind(addr: &str) -> Result<TcpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding coordinator on {addr}"))?;
        Ok(TcpServer { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Accept and handshake exactly `n` participants, then return the
    /// ready transport.  Shard ids go in join order; slow joins are
    /// tolerated up to `opts.join_timeout`, with liveness pings keeping
    /// already-ready peers verified while stragglers connect and build
    /// their backends.
    pub fn accept_participants(
        &self,
        cfg: &RunConfig,
        n: usize,
        opts: &TcpOpts,
    ) -> Result<TcpTransport> {
        anyhow::ensure!(n > 0, "the TCP transport needs at least one participant");
        cfg.validate_sharded("the tcp transport")?;
        anyhow::ensure!(
            cfg.workers == n,
            "serve config has workers={} but expects {n} participants; they must match so \
             the shard map and per-participant ledger equal the stdio --workers run",
            cfg.workers
        );
        self.listener.set_nonblocking(true).context("non-blocking listener")?;
        let deadline = Instant::now() + opts.join_timeout;
        let mut peers: Vec<Peer> = Vec::with_capacity(n);
        let mut last_beat = Instant::now();
        loop {
            let ready = peers.iter().filter(|p| p.handshake.is_ready()).count();
            let unconfirmed = peers.iter().any(|p| p.pending_ping.is_some());
            if ready == n && !unconfirmed {
                break;
            }
            if Instant::now() >= deadline {
                let pinging = peers.iter().filter(|p| p.pending_ping.is_some()).count();
                bail!(
                    "join window ({:?}) expired with {ready}/{n} participants ready \
                     ({} connected, {pinging} with an unanswered liveness ping)",
                    opts.join_timeout,
                    peers.len()
                );
            }
            // accept new connections (shard id = join order)
            match self.listener.accept() {
                Ok((stream, addr)) => {
                    if peers.len() == n {
                        // fleet is full: refuse politely by closing
                        let _ = stream.shutdown(Shutdown::Both);
                    } else {
                        let shard = peers.len();
                        let owned = shard_clients(cfg.n_clients, n, shard);
                        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                        stream.set_nonblocking(true).context("non-blocking peer socket")?;
                        peers.push(Peer {
                            shard,
                            handshake: JoinHandshake::new(shard, owned.len()),
                            shard_clients: owned,
                            stream,
                            addr,
                            decoder: StreamDecoder::new(),
                            pending_ping: None,
                            pings_sent: 0,
                            compute_secs: 0.0,
                        });
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) => return Err(e).context("accepting participant connection"),
            }
            // pump every peer's receive buffer and drive its handshake
            for peer in &mut peers {
                pump_join_peer(peer, cfg, n, deadline)?;
            }
            // ping ready peers while stragglers join: verifies both socket
            // directions stay live through an arbitrarily long join window
            if last_beat.elapsed() >= opts.heartbeat_every {
                last_beat = Instant::now();
                for peer in &mut peers {
                    if peer.handshake.is_ready() && peer.pending_ping.is_none() {
                        let nonce = 0xFED_1A0A ^ ((peer.shard as u64) << 32) ^ peer.pings_sent;
                        peer.pings_sent += 1;
                        peer.pending_ping = Some(nonce);
                        let frame = Message::Heartbeat(Heartbeat { nonce }).to_frame();
                        write_all_nb(peer, &frame, deadline, "liveness ping")?;
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // switch to blocking I/O with the training-time budget (zero =
        // unlimited, matching `join`; the write timeout keeps a wedged
        // participant that stops draining its socket from hanging the
        // coordinator inside a decision broadcast), then one final
        // synchronous ping/echo per peer (both directions verified
        // immediately before the first assignment)
        let io_timeout = if opts.io_timeout.is_zero() { None } else { Some(opts.io_timeout) };
        for peer in &mut peers {
            peer.stream.set_nonblocking(false).context("blocking peer socket")?;
            peer.stream.set_read_timeout(io_timeout).context("setting peer read timeout")?;
            peer.stream.set_write_timeout(io_timeout).context("setting peer write timeout")?;
            let nonce = 0xFED_7EA1 ^ peer.shard as u64;
            peer.send(&Message::Heartbeat(Heartbeat { nonce }))?;
            match peer.recv()? {
                Message::Heartbeat(h) if h.nonce == nonce => {}
                other => bail!("{}: bad heartbeat echo ({})", peer.describe(), other.kind_name()),
            }
        }
        Ok(TcpTransport { peers })
    }
}

/// Drain one peer's socket during the join phase (non-blocking) and feed
/// complete frames to its handshake state machine.
fn pump_join_peer(peer: &mut Peer, cfg: &RunConfig, n: usize, deadline: Instant) -> Result<()> {
    loop {
        let mut buf = [0u8; 64 * 1024];
        match peer.stream.read(&mut buf) {
            Ok(0) => bail!("{} disconnected during the join handshake", peer.describe()),
            Ok(nread) => peer.decoder.extend(&buf[..nread]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e).with_context(|| format!("reading from {}", peer.describe())),
        }
        // a partial frame stays buffered (Truncated, not an error): the
        // next pump continues where this read left off
        while let Some(msg) =
            peer.decoder.poll_message().with_context(|| format!("from {}", peer.describe()))?
        {
            match peer.handshake.on_message(&msg)? {
                JoinAction::SendConfigure => {
                    let conf = Message::Configure(Configure {
                        worker_id: peer.shard,
                        n_workers: n,
                        shard: peer.shard_clients.clone(),
                        cfg: cfg.clone(),
                    });
                    let frame = conf.to_frame();
                    write_all_nb(peer, &frame, deadline, "Configure")?;
                }
                JoinAction::Ready => {}
                JoinAction::Pong(nonce) => {
                    anyhow::ensure!(
                        peer.pending_ping == Some(nonce),
                        "{}: heartbeat echo nonce {nonce:#x} does not match the ping",
                        peer.describe()
                    );
                    peer.pending_ping = None;
                }
            }
        }
    }
    Ok(())
}

/// `write_all` on a non-blocking socket: retry `WouldBlock` with a small
/// sleep until `deadline`.
fn write_all_nb(peer: &mut Peer, bytes: &[u8], deadline: Instant, what: &str) -> Result<()> {
    let mut off = 0;
    while off < bytes.len() {
        match peer.stream.write(&bytes[off..]) {
            Ok(0) => bail!("{} closed the connection while receiving {what}", peer.describe()),
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "timed out sending {what} to {}",
                    peer.describe()
                );
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("sending {what} to {}", peer.describe()))
            }
        }
    }
    Ok(())
}

/// Coordinator-side TCP transport over `n` handshaken participants.
/// Message flow per block is identical to `ProcessTransport`; TCP is a
/// FIFO byte stream exactly like a pipe, so block k's decisions always
/// precede block k+1's assignment without extra synchronization.
pub struct TcpTransport {
    peers: Vec<Peer>,
}

impl TcpTransport {
    /// Convenience: bind + accept in one call (tests; `serve` binds first
    /// to print the address).
    pub fn serve(addr: &str, cfg: &RunConfig, n: usize, opts: &TcpOpts) -> Result<TcpTransport> {
        TcpServer::bind(addr)?.accept_participants(cfg, n, opts)
    }

    /// The peers' shard -> remote address map (diagnostics).
    pub fn peer_addrs(&self) -> Vec<(usize, SocketAddr)> {
        self.peers.iter().map(|p| (p.shard, p.addr)).collect()
    }
}

impl Transport for TcpTransport {
    fn workers(&self) -> usize {
        self.peers.len()
    }

    fn run_block(&mut self, a: &RoundAssignment) -> Result<BlockResult> {
        // serialize once, fan the same bytes to every participant
        let frame = Message::Assignment(a.clone()).to_frame();
        for peer in &mut self.peers {
            peer.stream
                .write_all(&frame)
                .with_context(|| format!("sending assignment to {}", peer.describe()))?;
        }
        let mut pairs = Vec::with_capacity(a.active.len());
        let mut updates = Vec::new();
        for peer in &mut self.peers {
            loop {
                match peer.recv().with_context(|| {
                    format!("mid-block (k={}) result from participant shard {}", a.k, peer.shard)
                })? {
                    Message::Update(u) => updates.push(u),
                    Message::Done(d) => {
                        anyhow::ensure!(
                            d.k == a.k,
                            "{} finished block k={}, expected k={}",
                            peer.describe(),
                            d.k,
                            a.k
                        );
                        pairs.extend(d.losses);
                        peer.compute_secs = d.compute_secs;
                        break;
                    }
                    other => {
                        bail!("{}: unexpected {} mid-block", peer.describe(), other.kind_name());
                    }
                }
            }
        }
        Ok(BlockResult { losses: merge_losses(&a.active, &pairs)?, updates })
    }

    fn broadcast_decision(&mut self, d: &SyncDecision, _active: &[usize]) -> Result<()> {
        let frame = Message::Decision(d.clone()).to_frame();
        for peer in &mut self.peers {
            peer.stream
                .write_all(&frame)
                .with_context(|| format!("sending SyncDecision to {}", peer.describe()))?;
        }
        Ok(())
    }

    fn remote_compute_secs(&self) -> f64 {
        self.peers.iter().map(|p| p.compute_secs).sum()
    }

    fn shutdown(&mut self) -> Result<()> {
        for peer in &mut self.peers {
            // best effort: the participant may already have exited on error
            let _ = peer.send(&Message::Shutdown);
        }
        for peer in &mut self.peers {
            // a clean participant closes its end after Shutdown; do not
            // fail a completed run over a slow close
            let _ = peer.stream.set_read_timeout(Some(Duration::from_secs(5)));
            let mut buf = [0u8; 256];
            let _ = peer.stream.read(&mut buf);
            let _ = peer.stream.shutdown(Shutdown::Both);
        }
        Ok(())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // error path: close sockets so remote participants fail fast
        // instead of blocking on a dead coordinator
        for peer in &mut self.peers {
            let _ = peer.stream.shutdown(Shutdown::Both);
        }
    }
}

// ---------------------------------------------------------------------------
// Participant side
// ---------------------------------------------------------------------------

/// Dial `addr` until it accepts or the retry window closes.
fn connect_with_retry(addr: &str, window: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + window;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("connecting to coordinator at {addr}"));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Join a coordinator as a TCP participant and serve one full training
/// session; returns the shard id this participant owned.  The
/// `Participant` (backend, client shard, partition) is rebuilt from the
/// coordinator's `Configure` frame exactly like a stdio worker.
pub fn join(addr: &str, opts: &JoinOpts) -> Result<usize> {
    let stream = connect_with_retry(addr, opts.connect_retry)?;
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    if !opts.io_timeout.is_zero() {
        stream.set_read_timeout(Some(opts.io_timeout)).context("setting read timeout")?;
        stream.set_write_timeout(Some(opts.io_timeout)).context("setting write timeout")?;
    }
    let mut rx = stream.try_clone().context("cloning socket for reads")?;
    let mut tx = stream;
    // 1. announce: version-only Hello (no shard assigned yet)
    Message::Hello(Hello { version: WIRE_VERSION, worker_id: 0, shard_len: 0 }).write_to(&mut tx)?;
    // 2. the coordinator assigns a shard + ships the run config
    let conf = match Message::read_from(&mut rx).context("reading Configure")? {
        Message::Configure(c) => c,
        other => bail!("expected Configure from the coordinator, got {}", other.kind_name()),
    };
    let mut p = super::worker::build_participant(conf)?;
    // 3. confirm readiness (backend built, shard adopted)
    Message::Hello(Hello {
        version: WIRE_VERSION,
        worker_id: p.worker_id,
        shard_len: p.shard().len(),
    })
    .write_to(&mut tx)?;
    // 4. the stdio worker's block loop, verbatim (echoes heartbeats, so
    //    the coordinator's slow-join pings keep this session verified)
    super::worker::serve_loop(&mut p, rx, tx)?;
    Ok(p.worker_id)
}
