//! TCP transport: multi-machine federation over real sockets, with
//! elastic membership.
//!
//! The same length-prefixed CRC-32 frames the stdio transport writes to
//! pipes, served on `std::net::TcpListener`/`TcpStream` — the first
//! configuration that can federate across machines.  Roles:
//!
//!   - **coordinator** (`fedlama serve --bind ADDR --expect N`):
//!     [`TcpServer::bind`] + [`TcpServer::accept_participants`] produce a
//!     [`TcpTransport`] once N participants completed the join handshake;
//!     `Coordinator::run_with_transport` then drives the ordinary block
//!     loop over it.
//!   - **participant** (`fedlama join --connect ADDR`): [`join`] dials the
//!     coordinator (with connect retries — it may not be up yet), runs the
//!     handshake, rebuilds its `Participant` from the `Configure` frame,
//!     and enters the same serve loop as the stdio worker.
//!
//! Join handshake (participant speaks first — the stdio flow reversed,
//! because over TCP the participant initiates the connection; the pure
//! state machine lives in [`super::core::PeerSession`]):
//!
//! ```text
//!   participant                               coordinator
//!     connect ------------------------------->  accept (shard = join order)
//!     Hello{version, 0, 0} ------------------>  version gate
//!     <-- Configure{shard_id, n, shard, cfg} -
//!     (rebuild backend/partition: slow is OK)   heartbeats ready peers
//!     Hello{version, shard_id, shard_len} --->  ready
//!     <-- Heartbeat ping / echo -------------   liveness smoke, then train
//! ```
//!
//! Shards are assigned round-robin over client ids (client c -> shard
//! c mod N) exactly like `--workers N`, so an N-participant TCP run is
//! bit-identical to the N-worker stdio run — including the per-participant
//! ledger tables.  Receive paths use [`super::messages::MessageStream`]
//! (a [`super::wire::StreamDecoder`] plus the per-layer frame
//! [`super::messages::Assembler`]): a socket read that ends mid-frame is
//! [`super::wire::FrameStatus::Truncated`], so the bytes are kept and the
//! read continues — never treated as a protocol error.  Bulk downlink
//! (`SyncDecision`) is fanned out frame-at-a-time: each per-layer frame is
//! encoded once into a reusable buffer and written to every live peer
//! before the next layer is staged, bounding peak staging by the largest
//! layer instead of the whole model.
//!
//! **Elastic membership.**  The roster is a fixed set of N *shards*, but
//! the connections behind them may come and go:
//!
//!   - The listener stays open for the whole run.  Connections beyond the
//!     current roster are parked (they block on their `Configure`) until a
//!     shard is vacant.
//!   - A peer that disconnects, times out, or sends [`Message::Abort`]
//!     mid-run is marked [`super::core::PeerPhase::Departed`] and its
//!     shard returns to the vacant pool; with `--quorum Q < N` the run
//!     continues as long as Q shards still report each block.
//!   - At the next round boundary the driver calls
//!     [`Transport::admit_ready_peers`]: parked connections claim vacant
//!     shards, walk the ordinary join handshake, receive a catch-up
//!     decision snapshot (replica-only — no active clients yet), and are
//!     promoted into the block loop.
//!
//! Admission happens only between rounds because mid-round client state
//! cannot be reconstructed from the wire protocol; the core renormalizes
//! aggregation weights over surviving clients, so commits stay
//! deterministic regardless of *when* within the join window each peer
//! connected.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::chaos::{chaos_stream_seed, ChaosRng, FaultPlan};
use crate::config::RunConfig;

use super::core::{JoinAction, PeerPhase, PeerSession};
use super::messages::{
    control_frame_count, decision_frame_count, encode_control_frame, encode_decision_frame, Abort,
    AlgoState, BlockDone, Configure, ControlUpdate, Heartbeat, Hello, Message, MessageStream,
    RoundAssignment, SyncDecision,
};
use super::transport::{merge_losses_absent, shard_clients, BlockResult, Transport};
use super::wire::{HEADER_LEN, WIRE_VERSION};

/// Timeout knobs for the coordinator side.
#[derive(Debug, Clone)]
pub struct TcpOpts {
    /// Window for all `--expect` participants to complete the join
    /// handshake (also the per-boundary window for rejoin admission).
    pub join_timeout: Duration,
    /// Per-block timeout once training runs (covers a full local-training
    /// block on the slowest participant, so it is generous).  Zero means
    /// unlimited.
    pub io_timeout: Duration,
    /// Liveness-ping cadence toward ready peers while slower ones are
    /// still joining.
    pub heartbeat_every: Duration,
}

impl Default for TcpOpts {
    fn default() -> TcpOpts {
        TcpOpts {
            join_timeout: Duration::from_secs(120),
            io_timeout: Duration::from_secs(600),
            heartbeat_every: Duration::from_secs(2),
        }
    }
}

/// Options for the participant side ([`join`]).
#[derive(Debug, Clone)]
pub struct JoinOpts {
    /// Keep retrying the initial connect for this long (the coordinator
    /// may not be listening yet when the participant starts).
    pub connect_retry: Duration,
    /// Read timeout while waiting for the next coordinator frame (covers
    /// the coordinator waiting on the slowest *other* participant).
    pub io_timeout: Duration,
    /// Leave cleanly after serving this many assignments instead of
    /// waiting for `Shutdown` — the chaos-test lever for a participant
    /// that departs at a deterministic block boundary.
    pub depart_after_blocks: Option<usize>,
}

impl Default for JoinOpts {
    fn default() -> JoinOpts {
        JoinOpts {
            connect_retry: Duration::from_secs(30),
            io_timeout: Duration::from_secs(600),
            depart_after_blocks: None,
        }
    }
}

/// One connected participant on the coordinator side.
struct Peer {
    shard: usize,
    /// Global client ids this shard owns (`transport::shard_clients` —
    /// the same map as `--workers`).
    shard_clients: Vec<usize>,
    stream: TcpStream,
    addr: SocketAddr,
    /// Frame decoder + per-layer frame assembler: survives partial reads
    /// *and* partially received streamed messages across pumps.
    decoder: MessageStream,
    session: PeerSession,
    /// Outstanding liveness-ping nonce, if any.
    pending_ping: Option<u64>,
    pings_sent: u64,
}

impl Peer {
    fn new(shard: usize, shard_clients: Vec<usize>, stream: TcpStream, addr: SocketAddr) -> Peer {
        let shard_len = shard_clients.len();
        Peer {
            shard,
            shard_clients,
            stream,
            addr,
            decoder: MessageStream::new(),
            session: PeerSession::new(shard, shard_len),
            pending_ping: None,
            pings_sent: 0,
        }
    }

    fn describe(&self) -> String {
        format!("participant shard {} ({})", self.shard, self.addr)
    }

    /// Receive one message on the (non-blocking) socket, polling until
    /// `deadline`.  A read that ends mid-frame keeps the bytes buffered
    /// and reads on — only corruption, timeout, or EOF fail.
    fn recv_deadline(&mut self, deadline: Instant) -> Result<Message> {
        loop {
            if let Some(m) =
                self.decoder.poll().with_context(|| format!("from {}", self.describe()))?
            {
                return Ok(m);
            }
            let mut buf = [0u8; 64 * 1024];
            match self.stream.read(&mut buf) {
                Ok(0) => bail!("{} closed the connection mid-session", self.describe()),
                Ok(n) => self.decoder.extend(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "timed out waiting for a frame from {}",
                        self.describe()
                    );
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(e).with_context(|| format!("reading from {}", self.describe()))
                }
            }
        }
    }

    /// Best-effort read until the peer closes its end or `window` passes
    /// (shutdown drain — never fails).
    fn drain_until_close(&mut self, window: Duration) {
        let deadline = Instant::now() + window;
        let mut buf = [0u8; 256];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => return,
                Ok(_) => {}
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    if Instant::now() >= deadline {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
    }
}

/// A bound listener, split from the accept phase so callers can report
/// the actual bound address (`--bind 127.0.0.1:0` picks a free port).
pub struct TcpServer {
    listener: TcpListener,
}

impl TcpServer {
    pub fn bind(addr: &str) -> Result<TcpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding coordinator on {addr}"))?;
        Ok(TcpServer { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Accept and handshake `n` participants, then return the ready
    /// transport.  Shard ids go in join order; slow joins are tolerated up
    /// to `opts.join_timeout`, with liveness pings keeping already-ready
    /// peers verified while stragglers connect and build their backends.
    ///
    /// A peer that disconnects mid-handshake is evicted and its shard
    /// returns to the vacant pool — later connections (including extras
    /// parked beyond the roster) can claim it within the window.  A peer
    /// that sends [`Message::Abort`] (its backend build failed) fails the
    /// serve with that reason.
    pub fn accept_participants(
        &self,
        cfg: &RunConfig,
        n: usize,
        opts: &TcpOpts,
    ) -> Result<TcpTransport> {
        anyhow::ensure!(n > 0, "the TCP transport needs at least one participant");
        cfg.validate_sharded("the tcp transport")?;
        anyhow::ensure!(
            cfg.workers == n,
            "serve config has workers={} but expects {n} participants; they must match so \
             the shard map and per-participant ledger equal the stdio --workers run",
            cfg.workers
        );
        self.listener.set_nonblocking(true).context("non-blocking listener")?;
        let deadline = Instant::now() + opts.join_timeout;
        let mut slots: Vec<Option<Peer>> = (0..n).map(|_| None).collect();
        let mut waiting: VecDeque<(TcpStream, SocketAddr)> = VecDeque::new();
        let mut last_beat = Instant::now();
        loop {
            // seat parked connections in vacant shards (join order, and —
            // after an eviction — reclaim order)
            attach_waiting(&mut slots, &mut waiting, cfg, n);
            let ready = slots
                .iter()
                .flatten()
                .filter(|p| p.session.phase() == PeerPhase::Ready)
                .count();
            let unconfirmed = slots.iter().flatten().any(|p| p.pending_ping.is_some());
            if ready == n && !unconfirmed {
                break;
            }
            if Instant::now() >= deadline {
                let connected = slots.iter().flatten().count() + waiting.len();
                let pinging =
                    slots.iter().flatten().filter(|p| p.pending_ping.is_some()).count();
                bail!(
                    "join window ({:?}) expired with {ready}/{n} participants ready \
                     ({connected} connected, {pinging} with an unanswered liveness ping)",
                    opts.join_timeout,
                );
            }
            // accept new connections into the parking queue
            match self.listener.accept() {
                Ok((stream, addr)) => {
                    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                    stream.set_nonblocking(true).context("non-blocking peer socket")?;
                    waiting.push_back((stream, addr));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) => return Err(e).context("accepting participant connection"),
            }
            // pump every seated peer's receive buffer and drive its join
            for s in 0..n {
                if slots[s].is_none() {
                    continue;
                }
                match pump_join_peer(slots[s].as_mut().unwrap(), cfg, n, deadline) {
                    Ok(JoinPump::Alive) => {}
                    Ok(JoinPump::Disconnected) => {
                        // the satellite-2 fix: evict, vacate the shard,
                        // keep accepting until the window closes
                        let peer = slots[s].take().unwrap();
                        let _ = peer.stream.shutdown(Shutdown::Both);
                        eprintln!(
                            "[serve] {} disconnected during the join handshake; \
                             shard {s} returns to the vacant pool",
                            peer.describe()
                        );
                    }
                    Ok(JoinPump::Aborted(reason)) => {
                        let peer = slots[s].take().unwrap();
                        bail!("{} aborted during join: {reason}", peer.describe());
                    }
                    Err(e) => return Err(e),
                }
            }
            // ping ready peers while stragglers join: verifies both socket
            // directions stay live through an arbitrarily long join window
            if last_beat.elapsed() >= opts.heartbeat_every {
                last_beat = Instant::now();
                for peer in slots.iter_mut().flatten() {
                    if peer.session.phase() == PeerPhase::Ready && peer.pending_ping.is_none() {
                        let nonce = 0xFED_1A0A ^ ((peer.shard as u64) << 32) ^ peer.pings_sent;
                        peer.pings_sent += 1;
                        peer.pending_ping = Some(nonce);
                        let frame = Message::Heartbeat(Heartbeat { nonce }).to_frame()?;
                        write_all_nb(peer, &frame, deadline, "liveness ping")?;
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // one final synchronous ping/echo per peer (both directions
        // verified immediately before the first assignment), then promote
        // everyone into the block loop
        let sync_deadline = deadline_after(opts.io_timeout);
        for peer in slots.iter_mut().flatten() {
            let nonce = 0xFED_7EA1 ^ peer.shard as u64;
            let frame = Message::Heartbeat(Heartbeat { nonce }).to_frame()?;
            write_all_nb(peer, &frame, sync_deadline, "final sync ping")?;
            match peer.recv_deadline(sync_deadline)? {
                Message::Heartbeat(h) if h.nonce == nonce => {}
                other => bail!("{}: bad heartbeat echo ({})", peer.describe(), other.kind_name()),
            }
            peer.session.promote()?;
        }
        Ok(TcpTransport {
            listener: self
                .listener
                .try_clone()
                .context("retaining the listener for mid-run joins")?,
            chaos: FaultPlan::parse(&cfg.chaos)?,
            cfg: cfg.clone(),
            n,
            opts: opts.clone(),
            slots,
            waiting,
            reasons: vec![None; n],
            fresh_departures: Vec::new(),
            compute_secs: vec![0.0; n],
        })
    }
}

/// Seat parked connections in vacant shards.
fn attach_waiting(
    slots: &mut [Option<Peer>],
    waiting: &mut VecDeque<(TcpStream, SocketAddr)>,
    cfg: &RunConfig,
    n: usize,
) {
    for s in 0..n {
        if slots[s].is_some() {
            continue;
        }
        let Some((stream, addr)) = waiting.pop_front() else { break };
        slots[s] = Some(Peer::new(s, shard_clients(cfg.n_clients, n, s), stream, addr));
    }
}

/// What one non-blocking pump of a joining peer's socket produced.
enum JoinPump {
    /// Socket drained (or would block); handshake may have advanced.
    Alive,
    /// The peer closed its end (EOF).
    Disconnected,
    /// The peer sent `Abort{reason}` — its participant build failed.
    Aborted(String),
}

/// Drain one joining peer's socket (non-blocking) and feed complete
/// frames to its session state machine.  Protocol violations and codec
/// corruption are hard errors; disconnects and aborts are returned for
/// the caller to translate (evict vs fail).
fn pump_join_peer(
    peer: &mut Peer,
    cfg: &RunConfig,
    n: usize,
    deadline: Instant,
) -> Result<JoinPump> {
    loop {
        // a partial frame stays buffered (Truncated, not an error): the
        // next pump continues where this read left off
        while let Some(msg) =
            peer.decoder.poll().with_context(|| format!("from {}", peer.describe()))?
        {
            if let Message::Abort(a) = &msg {
                return Ok(JoinPump::Aborted(a.reason.clone()));
            }
            match peer.session.on_message(&msg)? {
                JoinAction::SendConfigure => {
                    let conf = Message::Configure(Configure {
                        worker_id: peer.shard,
                        n_workers: n,
                        shard: peer.shard_clients.clone(),
                        cfg: cfg.clone(),
                    });
                    let frame = conf.to_frame()?;
                    write_all_nb(peer, &frame, deadline, "Configure")?;
                }
                JoinAction::Ready => {}
                JoinAction::Pong(nonce) => {
                    anyhow::ensure!(
                        peer.pending_ping == Some(nonce),
                        "{}: heartbeat echo nonce {nonce:#x} does not match the ping",
                        peer.describe()
                    );
                    peer.pending_ping = None;
                }
            }
        }
        let mut buf = [0u8; 64 * 1024];
        match peer.stream.read(&mut buf) {
            Ok(0) => return Ok(JoinPump::Disconnected),
            Ok(nread) => peer.decoder.extend(&buf[..nread]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(JoinPump::Alive),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e).with_context(|| format!("reading from {}", peer.describe())),
        }
    }
}

/// Drain one working peer's socket (non-blocking) during a block; returns
/// the peer's `BlockDone` once it arrives.  Stray heartbeat echoes are
/// ignored; EOF, an `Abort`, or any other frame is an error the caller
/// turns into a departure.
fn pump_block_peer(
    peer: &mut Peer,
    a: &RoundAssignment,
    updates: &mut Vec<super::messages::LayerUpdate>,
    algo: &mut Vec<AlgoState>,
) -> Result<Option<BlockDone>> {
    loop {
        while let Some(msg) =
            peer.decoder.poll().with_context(|| format!("from {}", peer.describe()))?
        {
            match msg {
                Message::Update(u) => updates.push(u),
                Message::Algo(s) => algo.push(s),
                Message::Done(d) => {
                    anyhow::ensure!(
                        d.k == a.k,
                        "{} finished block k={}, expected k={}",
                        peer.describe(),
                        d.k,
                        a.k
                    );
                    return Ok(Some(d));
                }
                Message::Heartbeat(_) => {}
                Message::Abort(ab) => bail!("{} aborted: {}", peer.describe(), ab.reason),
                other => {
                    bail!("{}: unexpected {} mid-block", peer.describe(), other.kind_name())
                }
            }
        }
        let mut buf = [0u8; 64 * 1024];
        match peer.stream.read(&mut buf) {
            Ok(0) => bail!("{} closed the connection mid-session", peer.describe()),
            Ok(nread) => peer.decoder.extend(&buf[..nread]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(None),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e).with_context(|| format!("reading from {}", peer.describe())),
        }
    }
}

/// `write_all` on a non-blocking socket: retry `WouldBlock` with a small
/// sleep until `deadline`.
fn write_all_nb(peer: &mut Peer, bytes: &[u8], deadline: Instant, what: &str) -> Result<()> {
    let mut off = 0;
    while off < bytes.len() {
        match peer.stream.write(&bytes[off..]) {
            Ok(0) => bail!("{} closed the connection while receiving {what}", peer.describe()),
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "timed out sending {what} to {}",
                    peer.describe()
                );
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("sending {what} to {}", peer.describe()))
            }
        }
    }
    Ok(())
}

/// `--chaos stall` wire fault: deliver `bytes` in tiny delayed chunks so
/// the peer's decoder sees the frame header and body split across many
/// partial reads.  Exercises the `FrameStatus::Truncated` reassembly path
/// without changing a single byte — numerics are untouched.  Only the
/// (small) assignment frames are trickled; model-sized decision fan-out
/// keeps the normal write path so a stalled run finishes in bounded time.
fn write_trickled_nb(peer: &mut Peer, bytes: &[u8], deadline: Instant, what: &str) -> Result<()> {
    // deliberately unaligned with the 8-byte frame header
    const CHUNK: usize = 7;
    for chunk in bytes.chunks(CHUNK) {
        write_all_nb(peer, chunk, deadline, what)?;
        std::thread::sleep(Duration::from_millis(1));
    }
    Ok(())
}

/// Absolute deadline `window` from now; zero means effectively unlimited.
fn deadline_after(window: Duration) -> Instant {
    if window.is_zero() {
        Instant::now() + Duration::from_secs(100 * 365 * 24 * 3600)
    } else {
        Instant::now() + window
    }
}

/// Coordinator-side TCP transport over a fixed roster of `n` shards with
/// elastic connections behind them.  Message flow per block is identical
/// to `ProcessTransport`; TCP is a FIFO byte stream exactly like a pipe,
/// so block k's decisions always precede block k+1's assignment without
/// extra synchronization.
pub struct TcpTransport {
    /// The serve listener, kept open for the whole run so departed shards
    /// can be re-claimed by fresh connections.
    listener: TcpListener,
    cfg: RunConfig,
    n: usize,
    opts: TcpOpts,
    /// shard id -> its live connection (None = vacant).
    slots: Vec<Option<Peer>>,
    /// Accepted connections not yet seated in a shard.
    waiting: VecDeque<(TcpStream, SocketAddr)>,
    /// Last departure reason per shard (for quorum-failure reports).
    reasons: Vec<Option<String>>,
    /// Shards that departed since the last committed block.
    fresh_departures: Vec<usize>,
    /// Last reported compute seconds per shard (survives departures).
    compute_secs: Vec<f64>,
    /// Parsed `--chaos` plan: this transport injects the *wire* faults
    /// (stall, corrupt-frame) into its own write path; payload attacks
    /// happen client-side and just ride through.
    chaos: FaultPlan,
}

impl TcpTransport {
    /// Convenience: bind + accept in one call (tests; `serve` binds first
    /// to print the address).
    pub fn serve(addr: &str, cfg: &RunConfig, n: usize, opts: &TcpOpts) -> Result<TcpTransport> {
        TcpServer::bind(addr)?.accept_participants(cfg, n, opts)
    }

    /// The live peers' shard -> remote address map (diagnostics).
    pub fn peer_addrs(&self) -> Vec<(usize, SocketAddr)> {
        self.slots.iter().flatten().map(|p| (p.shard, p.addr)).collect()
    }

    /// Drain the listener's accept queue into the parking lot.
    fn accept_waiting(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, addr)) => {
                    if stream.set_nodelay(true).is_err() || stream.set_nonblocking(true).is_err() {
                        let _ = stream.shutdown(Shutdown::Both);
                        continue;
                    }
                    self.waiting.push_back((stream, addr));
                }
                Err(_) => break,
            }
        }
    }

    /// Mark shard `s` departed: close its connection, vacate the slot,
    /// remember why (quorum-failure reports name it), and queue the
    /// departure for the next committed block's result.
    fn depart_slot(&mut self, s: usize, reason: String) {
        if let Some(mut peer) = self.slots[s].take() {
            peer.session.depart();
            let _ = peer.stream.shutdown(Shutdown::Both);
            eprintln!("[serve] {reason}; shard {s} is now vacant");
            self.reasons[s] = Some(reason);
            self.fresh_departures.push(s);
        }
    }

    /// Drop a rejoin candidate that failed its handshake (quiet — it was
    /// never part of the roster, so nothing departed).
    fn evict_candidate(&mut self, s: usize, why: &str) {
        if let Some(peer) = self.slots[s].take() {
            eprintln!(
                "[serve] rejoin candidate for shard {s} ({}) {why}; the shard stays vacant",
                peer.addr
            );
            let _ = peer.stream.shutdown(Shutdown::Both);
        }
    }
}

impl Transport for TcpTransport {
    fn workers(&self) -> usize {
        self.n
    }

    fn run_block(&mut self, a: &RoundAssignment) -> Result<BlockResult> {
        // serialize once, fan the same bytes to every live participant
        let frame = Message::Assignment(a.clone()).to_frame()?;
        let deadline = deadline_after(self.opts.io_timeout);
        for s in 0..self.n {
            if self.slots[s].is_some() {
                let res = if self.chaos.corrupts_frame(s, a.round) {
                    // flip one rng-chosen bit in the frame body: the peer's
                    // CRC check rejects the frame, its serve loop errors
                    // out, and the shard departs on EOF — the next block's
                    // quorum gate decides whether the run survives
                    let mut bad = frame.clone();
                    let mut rng = ChaosRng::new(chaos_stream_seed(
                        self.cfg.seed,
                        a.k,
                        s,
                        usize::MAX,
                    ));
                    let span = bad.len() - HEADER_LEN;
                    let byte = HEADER_LEN + rng.next_u64() as usize % span;
                    bad[byte] ^= 1 << (rng.next_u64() % 8);
                    eprintln!(
                        "[serve] chaos: corrupting one bit of shard {s}'s assignment \
                         frame at round {} (byte {byte})",
                        a.round
                    );
                    write_all_nb(self.slots[s].as_mut().unwrap(), &bad, deadline, "assignment")
                } else if self.chaos.stalls(s, a.round) {
                    write_trickled_nb(
                        self.slots[s].as_mut().unwrap(),
                        &frame,
                        deadline,
                        "assignment",
                    )
                } else {
                    write_all_nb(self.slots[s].as_mut().unwrap(), &frame, deadline, "assignment")
                };
                if let Err(e) = res {
                    self.depart_slot(s, format!("{e:#}"));
                }
            }
        }
        // gather: poll every live shard until it reports Done, departs,
        // or the block deadline expires
        let mut done = vec![false; self.n];
        let mut per_shard_updates: Vec<Vec<super::messages::LayerUpdate>> =
            (0..self.n).map(|_| Vec::new()).collect();
        let mut per_shard_algo: Vec<Vec<AlgoState>> =
            (0..self.n).map(|_| Vec::new()).collect();
        let mut pairs: Vec<(usize, f64)> = Vec::with_capacity(a.active.len());
        loop {
            for s in 0..self.n {
                if done[s] || self.slots[s].is_none() {
                    continue;
                }
                match pump_block_peer(
                    self.slots[s].as_mut().unwrap(),
                    a,
                    &mut per_shard_updates[s],
                    &mut per_shard_algo[s],
                ) {
                    Ok(Some(d)) => {
                        done[s] = true;
                        pairs.extend(d.losses);
                        self.compute_secs[s] = d.compute_secs;
                    }
                    Ok(None) => {}
                    Err(e) => self.depart_slot(s, format!("{e:#}")),
                }
            }
            if (0..self.n).all(|s| done[s] || self.slots[s].is_none()) {
                break;
            }
            if Instant::now() >= deadline {
                for s in 0..self.n {
                    if !done[s] {
                        if let Some(p) = &self.slots[s] {
                            let reason = format!(
                                "timed out waiting for block k={} from {}",
                                a.k,
                                p.describe()
                            );
                            self.depart_slot(s, reason);
                        }
                    }
                }
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // quorum gate: commit iff enough shards reported.  quorum == 0
        // means the full roster — the strict pre-elastic behavior.
        let q = if self.cfg.quorum == 0 { self.n } else { self.cfg.quorum };
        let reporters = done.iter().filter(|&&d| d).count();
        if reporters < q {
            let detail: Vec<String> = (0..self.n)
                .filter(|&s| !done[s])
                .map(|s| {
                    self.reasons[s]
                        .clone()
                        .unwrap_or_else(|| format!("shard {s} has no connection"))
                })
                .collect();
            bail!(
                "block k={} has {reporters}/{} shards reporting, below quorum {q}: {}",
                a.k,
                self.n,
                detail.join("; ")
            );
        }
        // fold updates in shard order (not arrival order) so the commit
        // is byte-identical however the survivors' replies interleaved;
        // a shard that died mid-block may have sent a partial update set —
        // only shards that reached Done contribute
        let updates: Vec<super::messages::LayerUpdate> = per_shard_updates
            .into_iter()
            .enumerate()
            .filter(|(s, _)| done[*s])
            .flat_map(|(_, u)| u)
            .collect();
        let algo: Vec<AlgoState> = per_shard_algo
            .into_iter()
            .enumerate()
            .filter(|(s, _)| done[*s])
            .flat_map(|(_, v)| v)
            .collect();
        let absent: Vec<usize> =
            a.active.iter().copied().filter(|&c| !done[c % self.n]).collect();
        let missed: Vec<usize> = (0..self.n).filter(|&s| !done[s]).collect();
        let departed = std::mem::take(&mut self.fresh_departures);
        Ok(BlockResult {
            losses: merge_losses_absent(&a.active, &pairs, &absent)?,
            updates,
            absent,
            missed,
            departed,
            algo,
        })
    }

    fn broadcast_decision(&mut self, d: &SyncDecision, _active: &[usize]) -> Result<()> {
        // frame-at-a-time fan-out: encode each per-layer frame once into a
        // reusable buffer and write it to every live peer before staging
        // the next layer — peak staging is one layer, not the whole model.
        // Every peer still sees the frames in sequence order (the sockets
        // are FIFO), so the byte stream per peer is unchanged.
        let deadline = deadline_after(self.opts.io_timeout);
        let mut frame = Vec::new();
        for idx in 0..decision_frame_count(d) {
            encode_decision_frame(d, idx, &mut frame)?;
            for s in 0..self.n {
                if self.slots[s].is_some() {
                    if let Err(e) = write_all_nb(
                        self.slots[s].as_mut().unwrap(),
                        &frame,
                        deadline,
                        "SyncDecision",
                    ) {
                        // a peer lost here is a departure, not a run error:
                        // the next block's quorum gate decides whether the
                        // run can continue without it
                        self.depart_slot(s, format!("{e:#}"));
                    }
                }
            }
        }
        Ok(())
    }

    fn broadcast_control(&mut self, c: &ControlUpdate) -> Result<()> {
        // same frame-at-a-time fan-out as decisions: one tensor staged at
        // a time, lost peers become departures for the next quorum gate
        let deadline = deadline_after(self.opts.io_timeout);
        let mut frame = Vec::new();
        for idx in 0..control_frame_count(c) {
            encode_control_frame(c, idx, &mut frame)?;
            for s in 0..self.n {
                if self.slots[s].is_some() {
                    if let Err(e) = write_all_nb(
                        self.slots[s].as_mut().unwrap(),
                        &frame,
                        deadline,
                        "ControlUpdate",
                    ) {
                        self.depart_slot(s, format!("{e:#}"));
                    }
                }
            }
        }
        Ok(())
    }

    fn broadcast_algo(&mut self, s: &AlgoState) -> Result<()> {
        // resume catch-up (rare): monolithic frame, fanned to every live
        // peer; a lost peer becomes a departure like any other broadcast
        let deadline = deadline_after(self.opts.io_timeout);
        let frame = Message::Algo(s.clone()).to_frame()?;
        for sh in 0..self.n {
            if self.slots[sh].is_some() {
                if let Err(e) = write_all_nb(
                    self.slots[sh].as_mut().unwrap(),
                    &frame,
                    deadline,
                    "AlgoState",
                ) {
                    self.depart_slot(sh, format!("{e:#}"));
                }
            }
        }
        Ok(())
    }

    fn remote_compute_secs(&self) -> f64 {
        self.compute_secs.iter().sum()
    }

    fn has_pending_members(&mut self) -> bool {
        self.accept_waiting();
        !self.waiting.is_empty() && self.slots.iter().any(|s| s.is_none())
    }

    fn admit_ready_peers(
        &mut self,
        catchup: &[SyncDecision],
        control: Option<&ControlUpdate>,
        algo: &[AlgoState],
    ) -> Result<Vec<usize>> {
        self.accept_waiting();
        // seat parked connections in vacant shards
        let mut attached: Vec<usize> = Vec::new();
        for s in 0..self.n {
            if self.slots[s].is_none() {
                let Some((stream, addr)) = self.waiting.pop_front() else { break };
                let owned = shard_clients(self.cfg.n_clients, self.n, s);
                self.slots[s] = Some(Peer::new(s, owned, stream, addr));
                attached.push(s);
            }
        }
        if attached.is_empty() {
            return Ok(Vec::new());
        }
        // walk the candidates through the ordinary join handshake
        let deadline = Instant::now() + self.opts.join_timeout;
        loop {
            for &s in &attached {
                if self.slots[s].as_ref().map(|p| p.session.phase()) != Some(PeerPhase::Joining) {
                    continue;
                }
                let outcome = {
                    let TcpTransport { slots, cfg, n, .. } = &mut *self;
                    pump_join_peer(slots[s].as_mut().unwrap(), cfg, *n, deadline)
                };
                match outcome {
                    Ok(JoinPump::Alive) => {}
                    Ok(JoinPump::Disconnected) => {
                        self.evict_candidate(s, "disconnected during the join handshake")
                    }
                    Ok(JoinPump::Aborted(r)) => {
                        self.evict_candidate(s, &format!("aborted during join: {r}"))
                    }
                    Err(e) => self.evict_candidate(s, &format!("{e:#}")),
                }
            }
            let joining = attached.iter().any(|&s| {
                self.slots[s].as_ref().map(|p| p.session.phase()) == Some(PeerPhase::Joining)
            });
            if !joining {
                break;
            }
            if Instant::now() >= deadline {
                for &s in &attached {
                    if self.slots[s].as_ref().map(|p| p.session.phase())
                        == Some(PeerPhase::Joining)
                    {
                        self.evict_candidate(
                            s,
                            "did not finish the join handshake before the admission window closed",
                        );
                    }
                }
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // ship each Ready candidate the catch-up decision snapshot
        // (applied replica-only — it has no active clients yet), then
        // promote it into the block loop.  Frame-at-a-time through one
        // reusable buffer, like broadcast_decision: rejoin is rare, so
        // re-encoding per candidate is cheap, and peak staging stays
        // bounded by one layer even for a deep catch-up history.
        let io_deadline = deadline_after(self.opts.io_timeout);
        let mut admitted = Vec::new();
        let mut frame = Vec::new();
        for &s in &attached {
            if self.slots[s].as_ref().map(|p| p.session.phase()) != Some(PeerPhase::Ready) {
                continue;
            }
            let res: Result<()> = {
                let peer = self.slots[s].as_mut().unwrap();
                catchup
                    .iter()
                    .try_for_each(|d| {
                        (0..decision_frame_count(d)).try_for_each(|idx| {
                            encode_decision_frame(d, idx, &mut frame)?;
                            write_all_nb(peer, &frame, io_deadline, "catch-up SyncDecision")
                        })
                    })
                    // SCAFFOLD catch-up: server control replica, then the
                    // spilled per-client controls (the peer adopts only the
                    // ones in its shard and skips the rest)
                    .and_then(|()| {
                        control.map_or(Ok(()), |c| {
                            (0..control_frame_count(c)).try_for_each(|idx| {
                                encode_control_frame(c, idx, &mut frame)?;
                                write_all_nb(peer, &frame, io_deadline, "catch-up ControlUpdate")
                            })
                        })
                    })
                    .and_then(|()| {
                        algo.iter().try_for_each(|st| {
                            let f = Message::Algo(st.clone()).to_frame()?;
                            write_all_nb(peer, &f, io_deadline, "catch-up AlgoState")
                        })
                    })
                    .and_then(|()| peer.session.promote())
            };
            match res {
                Ok(()) => {
                    eprintln!(
                        "[serve] {} rejoined the run as shard {s}",
                        self.slots[s].as_ref().unwrap().addr
                    );
                    self.reasons[s] = None;
                    admitted.push(s);
                }
                Err(e) => self.evict_candidate(s, &format!("{e:#}")),
            }
        }
        Ok(admitted)
    }

    fn shutdown(&mut self) -> Result<()> {
        let frame = Message::Shutdown.to_frame()?;
        let deadline = Instant::now() + Duration::from_secs(5);
        for peer in self.slots.iter_mut().flatten() {
            // best effort: the participant may already have exited on error
            let _ = write_all_nb(peer, &frame, deadline, "Shutdown");
        }
        for peer in self.slots.iter_mut().flatten() {
            // a clean participant closes its end after Shutdown; do not
            // fail a completed run over a slow close
            peer.drain_until_close(Duration::from_secs(5));
            let _ = peer.stream.shutdown(Shutdown::Both);
        }
        Ok(())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // error path: close sockets so remote participants fail fast
        // instead of blocking on a dead coordinator
        for peer in self.slots.iter_mut().flatten() {
            let _ = peer.stream.shutdown(Shutdown::Both);
        }
        for (stream, _) in self.waiting.drain(..) {
            let _ = stream.shutdown(Shutdown::Both);
        }
    }
}

// ---------------------------------------------------------------------------
// Participant side
// ---------------------------------------------------------------------------

/// Dial `addr` until it accepts or the retry window closes.
fn connect_with_retry(addr: &str, window: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + window;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("connecting to coordinator at {addr}"));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Join a coordinator as a TCP participant and serve one full training
/// session; returns the shard id this participant owned.  The
/// `Participant` (backend, client shard, partition) is rebuilt from the
/// coordinator's `Configure` frame exactly like a stdio worker.  If that
/// rebuild fails, an `Abort` frame carries the reason back to the
/// coordinator before this function returns the error — the serve side
/// reports it instead of timing out in silence.
pub fn join(addr: &str, opts: &JoinOpts) -> Result<usize> {
    let stream = connect_with_retry(addr, opts.connect_retry)?;
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    if !opts.io_timeout.is_zero() {
        stream.set_read_timeout(Some(opts.io_timeout)).context("setting read timeout")?;
        stream.set_write_timeout(Some(opts.io_timeout)).context("setting write timeout")?;
    }
    let mut rx = stream.try_clone().context("cloning socket for reads")?;
    let mut tx = stream;
    // 1. announce: version-only Hello (no shard assigned yet)
    Message::Hello(Hello { version: WIRE_VERSION, worker_id: 0, shard_len: 0 }).write_to(&mut tx)?;
    // 2. the coordinator assigns a shard + ships the run config
    let conf = match Message::read_from(&mut rx).context("reading Configure")? {
        Message::Configure(c) => c,
        other => bail!("expected Configure from the coordinator, got {}", other.kind_name()),
    };
    let worker_id = conf.worker_id;
    let mut p = match super::worker::build_participant(conf) {
        Ok(p) => p,
        Err(e) => {
            let abort = Message::Abort(Abort { worker_id, reason: format!("{e:#}") });
            if let Ok(frame) = abort.to_frame() {
                let _ = tx.write_all(&frame);
                let _ = tx.flush();
            }
            return Err(e);
        }
    };
    // 3. confirm readiness (backend built, shard adopted)
    Message::Hello(Hello {
        version: WIRE_VERSION,
        worker_id: p.worker_id,
        shard_len: p.shard().len(),
    })
    .write_to(&mut tx)?;
    // 4. the stdio worker's block loop, verbatim (echoes heartbeats, so
    //    the coordinator's slow-join pings keep this session verified)
    super::worker::serve_loop_with_limit(&mut p, rx, tx, opts.depart_after_blocks)?;
    Ok(p.worker_id)
}
