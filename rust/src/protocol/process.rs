//! Multi-process transport: `fedlama worker` subprocesses over stdio.
//!
//! The coordinator spawns N copies of its own executable with the `worker`
//! subcommand, shards the client fleet round-robin across them, and drives
//! the protocol over each child's stdin/stdout with the length-prefixed
//! wire codec.  stderr passes through for diagnostics.
//!
//! Session lifecycle per worker:
//!
//! ```text
//!   spawn -> Configure{worker_id, shard, cfg} -> Hello{version, shard_len}
//!         -> Heartbeat ping/echo (liveness + codec smoke)
//!         -> per block: Assignment -> (Update* Algo* Done) -> Decision* Control?
//!         -> Shutdown -> wait(exit 0)
//! ```
//!
//! Pipes are FIFO, so a worker always applies block k's decisions before
//! it sees block k+1's assignment — no extra barrier needed.  Frames are
//! written eagerly and flushed before every read.

use std::io::{BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;

use super::messages::{
    control_frame_count, decision_frame_count, encode_control_frame, encode_decision_frame,
    AlgoState, Assembler, Configure, ControlUpdate, Heartbeat, Message, RoundAssignment,
    SyncDecision,
};
use super::transport::{merge_losses, shard_clients, BlockResult, Transport};
use super::wire::WIRE_VERSION;

/// Resolve the executable to spawn workers from: `FEDLAMA_WORKER_EXE`
/// when set (tests point it at the built binary), else this process's
/// own image.
///
/// The current-exe fallback assumes the running image understands the
/// `worker` subcommand (true for the `fedlama` CLI).  Any other host
/// binary that enables `workers > 0` must set `FEDLAMA_WORKER_EXE` to a
/// fedlama binary: a spawned image that doesn't speak the protocol fails
/// the `Hello` handshake (bad magic on its first stdout bytes, or EOF
/// when it exits) — only a long-running, stdout-silent image would make
/// the handshake block.
pub fn worker_exe() -> Result<PathBuf> {
    if let Some(p) = std::env::var_os("FEDLAMA_WORKER_EXE") {
        return Ok(PathBuf::from(p));
    }
    std::env::current_exe().context("resolving current executable for worker spawn")
}

struct Worker {
    id: usize,
    child: Child,
    tx: BufWriter<ChildStdin>,
    rx: BufReader<ChildStdout>,
    /// Reassembles the worker's streamed per-layer update frames; held
    /// across `recv` calls so a partially received streamed message
    /// survives interleaved heartbeats.
    asm: Assembler,
    compute_secs: f64,
}

impl Worker {
    fn send(&mut self, msg: &Message) -> Result<()> {
        msg.write_to(&mut self.tx).with_context(|| format!("to worker {}", self.id))
    }
    fn flush(&mut self) -> Result<()> {
        self.tx.flush().with_context(|| format!("flushing pipe to worker {}", self.id))
    }
    fn recv(&mut self) -> Result<Message> {
        Message::read_streamed(&mut self.rx, &mut self.asm)
            .with_context(|| format!("from worker {}", self.id))
    }
}

pub struct ProcessTransport {
    workers: Vec<Worker>,
}

impl ProcessTransport {
    /// Spawn `n` workers from `exe`, shard `cfg.n_clients` clients
    /// round-robin, and complete the join handshake with each.
    pub fn spawn(exe: &Path, cfg: &RunConfig, n: usize) -> Result<ProcessTransport> {
        anyhow::ensure!(n > 0, "ProcessTransport needs at least one worker");
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let shard = shard_clients(cfg.n_clients, n, w);
            let mut child = Command::new(exe)
                .arg("worker")
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .with_context(|| format!("spawning worker {w} from {}", exe.display()))?;
            let tx = BufWriter::new(child.stdin.take().context("worker stdin")?);
            let rx = BufReader::new(child.stdout.take().context("worker stdout")?);
            let mut worker =
                Worker { id: w, child, tx, rx, asm: Assembler::new(), compute_secs: 0.0 };
            let shard_len = shard.len();
            worker.send(&Message::Configure(Configure {
                worker_id: w,
                n_workers: n,
                shard,
                cfg: cfg.clone(),
            }))?;
            worker.flush()?;
            match worker.recv()? {
                Message::Hello(h) => {
                    anyhow::ensure!(
                        h.version == WIRE_VERSION,
                        "worker {w} speaks protocol v{}, coordinator v{WIRE_VERSION}",
                        h.version
                    );
                    anyhow::ensure!(h.worker_id == w, "worker id mismatch: {}", h.worker_id);
                    anyhow::ensure!(
                        h.shard_len == shard_len,
                        "worker {w} claims {} clients, assigned {shard_len}",
                        h.shard_len
                    );
                }
                other => bail!("worker {w}: expected Hello, got {}", other.kind_name()),
            }
            // liveness ping: exercises both pipe directions before training
            let nonce = 0xFED_1A0A ^ w as u64;
            worker.send(&Message::Heartbeat(Heartbeat { nonce }))?;
            worker.flush()?;
            match worker.recv()? {
                Message::Heartbeat(h) if h.nonce == nonce => {}
                other => bail!("worker {w}: bad heartbeat echo ({})", other.kind_name()),
            }
            workers.push(worker);
        }
        Ok(ProcessTransport { workers })
    }
}

impl Transport for ProcessTransport {
    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn run_block(&mut self, a: &RoundAssignment) -> Result<BlockResult> {
        let msg = Message::Assignment(a.clone());
        for w in &mut self.workers {
            w.send(&msg)?;
            w.flush()?;
        }
        let mut pairs = Vec::with_capacity(a.active.len());
        let mut updates = Vec::new();
        let mut algo = Vec::new();
        for w in &mut self.workers {
            loop {
                match w.recv()? {
                    Message::Update(u) => updates.push(u),
                    Message::Algo(s) => algo.push(s),
                    Message::Done(d) => {
                        anyhow::ensure!(
                            d.k == a.k,
                            "worker {} finished block k={}, expected k={}",
                            w.id,
                            d.k,
                            a.k
                        );
                        pairs.extend(d.losses);
                        w.compute_secs = d.compute_secs;
                        break;
                    }
                    other => bail!("worker {}: unexpected {} mid-block", w.id, other.kind_name()),
                }
            }
        }
        Ok(BlockResult::full(merge_losses(&a.active, &pairs)?, updates, algo))
    }

    fn broadcast_decision(&mut self, d: &SyncDecision, _active: &[usize]) -> Result<()> {
        // frame-at-a-time fan-out: each per-layer frame is encoded once
        // and written to every worker before the next layer is staged, so
        // peak staging is one layer, not the whole decision.  Pipes are
        // FIFO per worker, so each worker still sees the frames in
        // sequence order.
        let mut frame = Vec::new();
        for idx in 0..decision_frame_count(d) {
            encode_decision_frame(d, idx, &mut frame)?;
            for w in &mut self.workers {
                w.tx
                    .write_all(&frame)
                    .with_context(|| format!("sending SyncDecision to worker {}", w.id))?;
            }
        }
        for w in &mut self.workers {
            w.flush()?;
        }
        Ok(())
    }

    fn broadcast_control(&mut self, c: &ControlUpdate) -> Result<()> {
        // same frame-at-a-time fan-out as decisions: one tensor staged at
        // a time, FIFO pipes keep per-worker frame order
        let mut frame = Vec::new();
        for idx in 0..control_frame_count(c) {
            encode_control_frame(c, idx, &mut frame)?;
            for w in &mut self.workers {
                w.tx
                    .write_all(&frame)
                    .with_context(|| format!("sending ControlUpdate to worker {}", w.id))?;
            }
        }
        for w in &mut self.workers {
            w.flush()?;
        }
        Ok(())
    }

    fn broadcast_algo(&mut self, s: &AlgoState) -> Result<()> {
        // resume catch-up (rare): encode the monolithic frame once, fan
        // the same bytes to every worker — each adopts the client if it
        // owns it and skips otherwise
        let frame = Message::Algo(s.clone()).to_frame()?;
        for w in &mut self.workers {
            w.tx
                .write_all(&frame)
                .with_context(|| format!("sending AlgoState to worker {}", w.id))?;
        }
        for w in &mut self.workers {
            w.flush()?;
        }
        Ok(())
    }

    fn remote_compute_secs(&self) -> f64 {
        self.workers.iter().map(|w| w.compute_secs).sum()
    }

    fn shutdown(&mut self) -> Result<()> {
        for w in &mut self.workers {
            // best effort: the worker may already have exited on error
            let _ = w.send(&Message::Shutdown);
            let _ = w.flush();
        }
        for w in &mut self.workers {
            let status = w.child.wait().with_context(|| format!("waiting for worker {}", w.id))?;
            anyhow::ensure!(status.success(), "worker {} exited with {status}", w.id);
        }
        Ok(())
    }
}

impl Drop for ProcessTransport {
    fn drop(&mut self) {
        // if shutdown() was not reached (error path), don't leave orphans
        for w in &mut self.workers {
            if matches!(w.child.try_wait(), Ok(None)) {
                let _ = w.child.kill();
                let _ = w.child.wait();
            }
        }
    }
}
