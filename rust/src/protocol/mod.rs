//! The federation protocol: Algorithm 1/2 as a message-passing API.
//!
//! The paper's training loop is, at heart, a protocol: clients push layer
//! updates on per-layer intervals, the server replies with aggregated
//! layers and adjusted intervals.  This subsystem makes that protocol
//! explicit and serializable so the federation can span processes (and,
//! eventually, machines) without touching the numerics:
//!
//!   - [`messages`] — the typed message set (`RoundAssignment`,
//!     `LayerUpdate` with dense / q-bit / top-k payloads, `SyncDecision`,
//!     join/heartbeat/shutdown) and their wire schemas, including the
//!     streamed per-layer framing (`Begin` + one frame per tensor,
//!     reassembled by [`messages::Assembler`] / [`messages::MessageStream`])
//!     that the bulk messages travel as since wire v2.
//!   - [`wire`] — the versioned, length-prefixed, CRC-checked codec
//!     (hand-rolled little-endian, no serde), with a scatter-gather
//!     zero-copy encode path (`Gather` / `write_frame_gather`) and an
//!     incremental `Crc32`.
//!   - [`core`] — [`CoordinatorCore`], the pure server state machine
//!     (schedule, ledger, sampler, global params; zero model compute,
//!     zero I/O).
//!   - [`participant`] — [`Participant`], the compute-owning client-shard
//!     role (backend, client states, local global replica).
//!   - [`transport`] — the [`Transport`] seam plus the in-proc
//!     implementation (the rewritten single-process path).
//!   - [`process`] — [`ProcessTransport`]: N `fedlama worker`
//!     subprocesses over stdio pipes.
//!   - [`tcp`] — [`TcpTransport`]: N `fedlama join` participants over TCP
//!     sockets (the multi-machine path) behind a `fedlama serve`
//!     coordinator, plus the participant-side [`tcp::join`] session.
//!   - [`worker`] — the worker subcommand's serve loop.
//!
//! Determinism is the design constraint throughout: client RNG streams
//! are keyed by global client id, compression streams by (seed, k, group,
//! client), shards rebuild their data partition from the seed, and the
//! core orders every cross-client reduction by the active list — so
//! in-proc, 2-worker, and N-worker runs are bit-identical (asserted by
//! `tests/process_transport.rs`).

pub mod core;
pub mod messages;
pub mod participant;
pub mod process;
pub mod tcp;
pub mod transport;
pub mod wire;
pub mod worker;

pub use self::core::{
    BlockOutcome, CoordinatorCore, JoinAction, JoinHandshake, JoinPhase, PeerPhase, PeerSession,
};
pub use messages::{
    Abort, AlgoState, Assembler, BlockDone, Configure, ControlUpdate, Heartbeat, Hello,
    LayerUpdate, Message, MessageStream, Payload, RoundAssignment, SyncDecision,
};
pub use participant::Participant;
pub use process::{worker_exe, ProcessTransport};
pub use tcp::{JoinOpts, TcpOpts, TcpServer, TcpTransport};
pub use transport::{shard_clients, BlockResult, InProcTransport, Transport};
pub use wire::WIRE_VERSION;
