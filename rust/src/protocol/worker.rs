//! The `fedlama worker` subprocess: a participant speaking the wire
//! protocol over stdin/stdout.
//!
//! The worker is almost stateless between messages: everything heavy
//! (backend, partition, client shard) is rebuilt deterministically from
//! the `Configure` frame, and the only cross-message state is the current
//! assignment's active set (decisions broadcast after a block apply to
//! that set).  Anything unexpected — codec error, protocol violation,
//! compute failure — surfaces as a non-zero exit that the coordinator's
//! `shutdown()` turns into a run error.

use std::io::{Read, Write};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::EngineKind;
use crate::runtime::{zoo, ComputeBackend};

use super::messages::{Assembler, BlockDone, Configure, Hello, Message};
use super::participant::Participant;
use super::wire::WIRE_VERSION;

/// Build a participant from a `Configure` frame: validate the shipped
/// config and construct the compute backend.  Shared by the stdio worker
/// and the TCP `join` participant.
pub fn build_participant(conf: Configure) -> Result<Participant> {
    let cfg = conf.cfg;
    cfg.validate().context("worker received invalid config")?;
    anyhow::ensure!(
        cfg.engine == EngineKind::Native,
        "worker processes support the native engine only"
    );
    let backend: Arc<dyn ComputeBackend> = Arc::new(
        zoo::build(&cfg.model, cfg.dataset).context("building worker compute backend")?,
    );
    Participant::new(&cfg, backend, conf.worker_id, conf.shard)
}

/// The participant's block loop over arbitrary streams: Assignment ->
/// Update* + Done, Decision, Heartbeat echo, until a `Shutdown` frame
/// arrives.  Transport-agnostic — the stdio worker hands it pipe halves,
/// the TCP `join` participant hands it socket halves.
pub fn serve_loop<R: Read, W: Write>(p: &mut Participant, rx: R, tx: W) -> Result<()> {
    serve_loop_with_limit(p, rx, tx, None)
}

/// [`serve_loop`] with an optional departure knob: after serving
/// `depart_after` assignments the loop returns `Ok` without waiting for
/// `Shutdown`, closing the connection cleanly — the chaos-test lever for
/// a participant that leaves mid-run at a deterministic block boundary.
pub fn serve_loop_with_limit<R: Read, W: Write>(
    p: &mut Participant,
    mut rx: R,
    mut tx: W,
    depart_after: Option<usize>,
) -> Result<()> {
    let mut last_active: Vec<usize> = Vec::new();
    let mut served = 0usize;
    // held across reads: a streamed Decision's per-layer frames may be
    // interleaved with heartbeats, and the partial must survive
    let mut asm = Assembler::new();
    loop {
        match Message::read_streamed(&mut rx, &mut asm)? {
            Message::Assignment(a) => {
                let (losses, updates, algo) = p.handle_assignment(&a)?;
                for u in updates {
                    // streamed per-layer frames: encode borrows the tensor
                    // payloads (zero copy) and peak staging stays one layer
                    Message::Update(u).write_streamed(&mut tx)?;
                }
                for s in algo {
                    // round-boundary optimizer state (SCAFFOLD controls,
                    // FedNova deltas), streamed tensor-at-a-time like
                    // updates
                    Message::Algo(s).write_streamed(&mut tx)?;
                }
                Message::Done(BlockDone {
                    worker_id: p.worker_id,
                    k: a.k,
                    losses,
                    compute_secs: p.compute_secs(),
                })
                .write_to(&mut tx)?;
                tx.flush().context("flushing block result")?;
                last_active = a.active;
                served += 1;
                if depart_after.is_some_and(|n| served >= n) {
                    return Ok(());
                }
            }
            Message::Decision(d) => p.apply_decision(&d, &last_active)?,
            // refreshed SCAFFOLD server control (round-boundary broadcast)
            Message::Control(c) => p.set_server_control(&c)?,
            // rejoin/resume catch-up: adopt a client's spilled control
            Message::Algo(s) => p.adopt_algo_state(&s)?,
            Message::Heartbeat(h) => {
                Message::Heartbeat(h).write_to(&mut tx)?;
                tx.flush().context("flushing heartbeat echo")?;
            }
            Message::Shutdown => return Ok(()),
            other => bail!("unexpected {} in worker loop", other.kind_name()),
        }
    }
}

/// Serve one coordinator session over the given streams; returns when a
/// `Shutdown` frame arrives.
pub fn run<R: Read, W: Write>(mut rx: R, mut tx: W) -> Result<()> {
    let conf = match Message::read_from(&mut rx).context("reading Configure")? {
        Message::Configure(c) => c,
        other => bail!("expected Configure, got {}", other.kind_name()),
    };
    let mut p = build_participant(conf)?;
    Message::Hello(Hello {
        version: WIRE_VERSION,
        worker_id: p.worker_id,
        shard_len: p.shard().len(),
    })
    .write_to(&mut tx)?;
    tx.flush().context("flushing Hello")?;
    serve_loop(&mut p, rx, tx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::protocol::messages::{Configure, Heartbeat};

    /// Drive a worker loop fully in-memory: Configure -> Hello, heartbeat
    /// echo, one assignment -> updates + done, decision, shutdown.
    #[test]
    fn worker_loop_speaks_the_protocol_in_memory() {
        let cfg = RunConfig {
            n_clients: 3,
            samples: 32,
            iterations: 12,
            policy: crate::aggregation::Policy::fedavg(6),
            warmup_rounds: 0,
            ..RunConfig::default()
        };
        cfg.validate().unwrap();
        let mut inbox: Vec<u8> = Vec::new();
        let push =
            |inbox: &mut Vec<u8>, m: &Message| inbox.extend_from_slice(&m.to_frame().unwrap());
        push(
            &mut inbox,
            &Message::Configure(Configure {
                worker_id: 0,
                n_workers: 1,
                shard: vec![0, 1, 2],
                cfg: cfg.clone(),
            }),
        );
        push(&mut inbox, &Message::Heartbeat(Heartbeat { nonce: 77 }));
        let assignment = super::super::messages::RoundAssignment {
            k: 6,
            round: 0,
            gap: 6,
            lr: 0.1,
            new_round: true,
            active: vec![0, 1, 2],
            due_groups: vec![0],
        };
        push(&mut inbox, &Message::Assignment(assignment));
        push(&mut inbox, &Message::Shutdown);

        let mut out: Vec<u8> = Vec::new();
        run(std::io::Cursor::new(inbox), &mut out).unwrap();

        // replies: Hello, Heartbeat echo, 3 Updates (group 0 x clients,
        // streamed as per-layer frames), Done
        let mut cur = std::io::Cursor::new(out);
        let mut asm = Assembler::new();
        let mut next = || Message::read_streamed(&mut cur, &mut asm).unwrap();
        let Message::Hello(h) = next() else { panic!("hello") };
        assert_eq!((h.version, h.worker_id, h.shard_len), (WIRE_VERSION, 0, 3));
        let Message::Heartbeat(hb) = next() else { panic!("heartbeat") };
        assert_eq!(hb.nonce, 77);
        let mut updates = 0;
        loop {
            match next() {
                Message::Update(u) => {
                    assert_eq!(u.k, 6);
                    assert_eq!(u.group, 0);
                    updates += 1;
                }
                Message::Done(d) => {
                    assert_eq!(d.k, 6);
                    assert_eq!(d.losses.len(), 3);
                    assert!(d.losses.iter().all(|(_, l)| l.is_finite()));
                    break;
                }
                other => panic!("unexpected {}", other.kind_name()),
            }
        }
        assert_eq!(updates, 3);
    }

    #[test]
    fn worker_rejects_garbage_config() {
        let bad = RunConfig { iterations: 0, ..RunConfig::default() };
        let mut inbox = Vec::new();
        inbox.extend_from_slice(
            &Message::Configure(Configure {
                worker_id: 0,
                n_workers: 1,
                shard: vec![0],
                cfg: bad,
            })
            .to_frame()
            .unwrap(),
        );
        let mut out = Vec::new();
        assert!(run(std::io::Cursor::new(inbox), &mut out).is_err());
    }
}
