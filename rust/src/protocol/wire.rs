//! Versioned, length-prefixed wire codec for the federation protocol.
//!
//! Hand-rolled little-endian framing in the spirit of the repo's other
//! binary formats — no serde, no derive macros, every byte accounted for:
//!
//! ```text
//!   frame := magic(2) version(1) kind(1) len(4, LE) body(len) crc32(4, LE)
//! ```
//!
//! `len` counts body bytes only; the CRC-32 (IEEE) covers the body, so a
//! flipped bit anywhere in the payload is rejected, and a truncated stream
//! fails the length/`read_exact` checks.  The version byte gates protocol
//! evolution: frames carry the writer's version and the decoder accepts
//! the whole supported range `MIN_WIRE_VERSION..=WIRE_VERSION` (the frame
//! *layout* has never changed — bumps add kinds), while the `Hello`
//! handshake still pins peers to exact equality so a coordinator and a
//! worker from different builds refuse to talk rather than mis-decode.
//!
//! Primitives (`Enc`/`Dec`) are deliberately dumb: fixed-width LE integers,
//! IEEE-754 bit-pattern floats (NaN losses survive the trip), and
//! u32-length-prefixed sequences.  Everything higher-level (message
//! schemas) lives in `protocol::messages`.
//!
//! Two encode paths share the layout: [`frame`]/[`write_frame`] copy an
//! `Enc` body into one staging buffer (fine for small control messages),
//! and [`write_frame_gather`] emits a [`Gather`] — a scatter-gather body
//! that *borrows* bulk slices (tensor storage) and owns only the small
//! interleaved fields — via `write_vectored`, with the CRC computed
//! incrementally ([`Crc32`]) as the parts are walked.  Both produce
//! byte-identical frames; gather just never materializes the body.

use std::io::{IoSlice, Read, Write};
use std::sync::OnceLock;

use anyhow::{bail, ensure, Context, Result};

/// Protocol wire version; bump on any frame or schema change.
///
/// v1 -> v2: streamed per-layer framing (`UpdateBegin`/`UpdateTensor`,
/// `DecisionBegin`/`DecisionTensor` kinds).  The frame layout is
/// unchanged; v1 frames (including the monolithic `Update`/`Decision`
/// kinds, which remain decodable) are still accepted.
///
/// v2 -> v3: algorithm state rides the wire (`AlgoState`/`ControlUpdate`
/// kinds plus their streamed framing), decisions carry per-client mixing
/// weights, and the config codec gained policy tags 2/3 and partition
/// tags 3/4.  Existing *bodies* changed (Decision, Configure), so v3
/// does not accept older frames — see [`MIN_WIRE_VERSION`].
pub const WIRE_VERSION: u8 = 3;

/// Oldest frame version this build still decodes.  The v3 bump changed
/// the bodies of existing kinds (Decision grew a mix-weight section,
/// Configure a wider policy/partition tag space), so mixed-version runs
/// must fail at the handshake rather than mis-decode mid-run.
pub const MIN_WIRE_VERSION: u8 = 3;

/// Frame magic: distinguishes protocol traffic from stray stdout bytes.
pub const MAGIC: [u8; 2] = [0xF7, 0x1A];

/// Upper bound on a single frame body; rejects absurd lengths from
/// corrupted headers before any allocation happens.
pub const MAX_FRAME: usize = 1 << 30;

/// Total frame bytes around a body: magic(2) + version(1) + kind(1) +
/// len(4) before it, crc32(4) after.
pub const HEADER_LEN: usize = 8;

/// Is `v` a frame version this build decodes?
fn version_ok(v: u8) -> bool {
    (MIN_WIRE_VERSION..=WIRE_VERSION).contains(&v)
}

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// Incremental CRC-32 (IEEE 802.3, reflected): feed slices in wire order,
/// [`Crc32::finish`] yields the same value [`crc32`] computes over their
/// concatenation.  This is what lets the gather encoder checksum borrowed
/// tensor slices as they are written instead of staging the body first.
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let table = crc_table();
        let mut c = self.state;
        for &b in data {
            c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(self) -> u32 {
        !self.state
    }
}

/// CRC-32 (IEEE 802.3, reflected) over `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

// ---------------------------------------------------------------------------
// Encoder
// ---------------------------------------------------------------------------

/// Append-only body encoder.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc::default()
    }
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// Sequence length prefix: a length that does not fit the u32 prefix
    /// would silently truncate and poison the stream, so it is an encode
    /// error instead (mirrors the decode-side `seq_len` bound).
    fn seq_len(&mut self, n: usize) -> Result<()> {
        ensure!(n <= u32::MAX as usize, "sequence length {n} exceeds the u32 wire prefix");
        self.u32(n as u32);
        Ok(())
    }

    pub fn str(&mut self, s: &str) -> Result<()> {
        self.seq_len(s.len())?;
        self.buf.extend_from_slice(s.as_bytes());
        Ok(())
    }
    pub fn bytes(&mut self, b: &[u8]) -> Result<()> {
        self.seq_len(b.len())?;
        self.buf.extend_from_slice(b);
        Ok(())
    }
    pub fn f32s(&mut self, v: &[f32]) -> Result<()> {
        self.seq_len(v.len())?;
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        Ok(())
    }
    pub fn u16s(&mut self, v: &[u16]) -> Result<()> {
        self.seq_len(v.len())?;
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        Ok(())
    }
    pub fn u32s(&mut self, v: &[u32]) -> Result<()> {
        self.seq_len(v.len())?;
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        Ok(())
    }
    pub fn usizes(&mut self, v: &[usize]) -> Result<()> {
        self.seq_len(v.len())?;
        for &x in v {
            self.u64(x as u64);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Decoder
// ---------------------------------------------------------------------------

/// Bounds-checked body decoder; every `take_*` errors on overrun instead of
/// panicking, so corrupt frames surface as `Err`, never UB or aborts.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "frame underrun: need {n} bytes, have {}", self.remaining());
        let whole: &'a [u8] = self.buf;
        let s = &whole[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("bad bool byte {v}"),
        }
    }
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        ensure!(v <= usize::MAX as u64, "usize overflow {v}");
        Ok(v as usize)
    }
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Sequence length prefix, sanity-bounded by the bytes actually left so
    /// a corrupt length cannot trigger a huge allocation.
    fn seq_len(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(elem_bytes) <= self.remaining(),
            "sequence length {n} exceeds frame ({} bytes left)",
            self.remaining()
        );
        Ok(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.seq_len(1)?;
        Ok(std::str::from_utf8(self.take(n)?).context("bad utf-8 string")?.to_string())
    }
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.seq_len(1)?;
        Ok(self.take(n)?.to_vec())
    }
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    pub fn u16s(&mut self) -> Result<Vec<u16>> {
        let n = self.seq_len(2)?;
        let raw = self.take(n * 2)?;
        Ok(raw.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect())
    }
    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.seq_len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    pub fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.seq_len(8)?;
        (0..n).map(|_| self.usize()).collect()
    }

    pub fn finish(self) -> Result<()> {
        ensure!(self.remaining() == 0, "{} trailing bytes after message body", self.remaining());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Wrap an encoded body into a full frame.  Bodies over [`MAX_FRAME`]
/// are rejected at encode time — the decode side would refuse them
/// anyway, so emitting one could only poison the stream.
pub fn frame(kind: u8, body: &[u8]) -> Result<Vec<u8>> {
    ensure!(body.len() <= MAX_FRAME, "frame body {} bytes exceeds cap {MAX_FRAME}", body.len());
    let mut out = Vec::with_capacity(HEADER_LEN + body.len() + 4);
    out.extend_from_slice(&MAGIC);
    out.push(WIRE_VERSION);
    out.push(kind);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body);
    out.extend_from_slice(&crc32(body).to_le_bytes());
    Ok(out)
}

// ---------------------------------------------------------------------------
// Scatter-gather encoding
// ---------------------------------------------------------------------------

enum GatherPart<'a> {
    /// Small interleaved fields (tags, lengths, counts), staged locally.
    Owned(Vec<u8>),
    /// Bulk payload bytes borrowed straight from caller storage.
    Borrowed(&'a [u8]),
}

impl GatherPart<'_> {
    fn bytes(&self) -> &[u8] {
        match self {
            GatherPart::Owned(v) => v,
            GatherPart::Borrowed(s) => s,
        }
    }
}

/// Scatter-gather body builder: the zero-copy sibling of [`Enc`].
///
/// Small fields append to an owned staging tail; the `*s` sequence
/// methods write their u32 length prefix to the tail and then *borrow*
/// the element storage (on little-endian targets the in-memory bytes ARE
/// the wire bytes, so no copy happens — big-endian targets fall back to
/// an owned byteswapped copy).  The part list preserves wire order, so a
/// gather body is byte-identical to the `Enc` encoding of the same
/// fields; [`write_frame_gather`] emits it without ever materializing
/// the body, and [`Gather::staging_bytes`] reports how few bytes were
/// actually staged (the transport bench's peak-staging metric).
#[derive(Default)]
pub struct Gather<'a> {
    parts: Vec<GatherPart<'a>>,
    total: usize,
    owned: usize,
}

impl<'a> Gather<'a> {
    pub fn new() -> Gather<'a> {
        Gather::default()
    }

    /// Total body bytes across all parts.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Bytes held in owned staging (everything except borrowed payload
    /// slices) — the memory the encode path actually allocates.
    pub fn staging_bytes(&self) -> usize {
        self.owned
    }

    fn push_owned(&mut self, bytes: &[u8]) {
        self.total += bytes.len();
        self.owned += bytes.len();
        if let Some(GatherPart::Owned(tail)) = self.parts.last_mut() {
            tail.extend_from_slice(bytes);
        } else {
            self.parts.push(GatherPart::Owned(bytes.to_vec()));
        }
    }

    fn push_borrowed(&mut self, bytes: &'a [u8]) {
        if bytes.is_empty() {
            return;
        }
        self.total += bytes.len();
        self.parts.push(GatherPart::Borrowed(bytes));
    }

    pub fn u8(&mut self, v: u8) {
        self.push_owned(&[v]);
    }
    pub fn bool(&mut self, v: bool) {
        self.push_owned(&[v as u8]);
    }
    pub fn u32(&mut self, v: u32) {
        self.push_owned(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.push_owned(&v.to_le_bytes());
    }
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    pub fn f32(&mut self, v: f32) {
        self.push_owned(&v.to_le_bytes());
    }

    /// Sequence length prefix; same u32 bound as [`Enc::seq_len`].
    fn seq_len(&mut self, n: usize) -> Result<()> {
        ensure!(n <= u32::MAX as usize, "sequence length {n} exceeds the u32 wire prefix");
        self.u32(n as u32);
        Ok(())
    }

    pub fn bytes(&mut self, b: &'a [u8]) -> Result<()> {
        self.seq_len(b.len())?;
        self.push_borrowed(b);
        Ok(())
    }

    pub fn f32s(&mut self, v: &'a [f32]) -> Result<()> {
        self.seq_len(v.len())?;
        #[cfg(target_endian = "little")]
        {
            // SAFETY: f32 has no padding and alignment 4 >= 1; reinterpreting
            // the slice as bytes is always valid, and on LE the in-memory
            // layout equals the `to_le_bytes` wire encoding.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) };
            self.push_borrowed(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.push_owned(&x.to_le_bytes());
        }
        Ok(())
    }

    pub fn u16s(&mut self, v: &'a [u16]) -> Result<()> {
        self.seq_len(v.len())?;
        #[cfg(target_endian = "little")]
        {
            // SAFETY: as in `f32s` — no padding, byte alignment is weaker,
            // and LE in-memory layout equals the wire encoding.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 2) };
            self.push_borrowed(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.push_owned(&x.to_le_bytes());
        }
        Ok(())
    }

    pub fn u32s(&mut self, v: &'a [u32]) -> Result<()> {
        self.seq_len(v.len())?;
        #[cfg(target_endian = "little")]
        {
            // SAFETY: as in `f32s`.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) };
            self.push_borrowed(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.push_owned(&x.to_le_bytes());
        }
        Ok(())
    }
}

/// Write every byte of `slices`, in order, through `write_vectored`.
///
/// Handles short writes by re-slicing: `(idx, off)` track the first
/// not-yet-flushed slice and the bytes of it already written, and the
/// IoSlice list is rebuilt from there each iteration (manual advance —
/// `IoSlice::advance_slices` is newer than our MSRV).  A `Write` impl
/// that ignores vectoring (the default forwards to `write` with the
/// first slice) still terminates: every pass writes at least one byte
/// or errors.
fn write_vectored_all<W: Write>(w: &mut W, slices: &[&[u8]]) -> std::io::Result<()> {
    let mut idx = 0;
    let mut off = 0;
    let mut iov: Vec<IoSlice<'_>> = Vec::with_capacity(slices.len());
    while idx < slices.len() {
        if off == slices[idx].len() {
            // skip empty slices (and fully flushed heads)
            idx += 1;
            off = 0;
            continue;
        }
        iov.clear();
        iov.push(IoSlice::new(&slices[idx][off..]));
        for s in &slices[idx + 1..] {
            if !s.is_empty() {
                iov.push(IoSlice::new(s));
            }
        }
        let mut n = match w.write_vectored(&iov) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "stream accepted 0 bytes mid-frame",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while n > 0 {
            let rem = slices[idx].len() - off;
            if n >= rem {
                n -= rem;
                idx += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

/// Emit one frame whose body is a [`Gather`], without materializing the
/// body: header and CRC are computed up front (the CRC incrementally,
/// part by part), then header + borrowed/owned parts + CRC go out in one
/// `write_vectored` pass.  Byte-identical to
/// `write_frame(w, kind, &flattened_body)`.
pub fn write_frame_gather<W: Write>(w: &mut W, kind: u8, g: &Gather<'_>) -> Result<()> {
    ensure!(g.len() <= MAX_FRAME, "frame body {} bytes exceeds cap {MAX_FRAME}", g.len());
    let mut header = [0u8; HEADER_LEN];
    header[0..2].copy_from_slice(&MAGIC);
    header[2] = WIRE_VERSION;
    header[3] = kind;
    header[4..8].copy_from_slice(&(g.len() as u32).to_le_bytes());
    let mut crc = Crc32::new();
    for p in &g.parts {
        crc.update(p.bytes());
    }
    let crc_bytes = crc.finish().to_le_bytes();
    let mut slices: Vec<&[u8]> = Vec::with_capacity(g.parts.len() + 2);
    slices.push(&header);
    for p in &g.parts {
        slices.push(p.bytes());
    }
    slices.push(&crc_bytes);
    write_vectored_all(w, &slices).context("writing protocol frame")
}

/// Outcome of decoding the head of a byte buffer.
///
/// Truncation is a *variant*, not an error: a socket read can legitimately
/// deliver half a frame, and the caller must keep the bytes and read more.
/// Only genuine corruption (bad magic/version, oversized length, CRC
/// mismatch) is an `Err` from [`try_deframe`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameStatus<'a> {
    /// One complete, CRC-verified frame at the head of the buffer.
    Ready { kind: u8, body: &'a [u8], consumed: usize },
    /// The buffer ends before the frame does: `need` total bytes must be
    /// available before decoding can be retried (a lower bound when even
    /// the header is incomplete).
    Truncated { need: usize },
}

/// Parse the head of `buf` without treating truncation as corruption:
/// returns `Ok(Truncated { need })` when the header or the header-claimed
/// body extends past the buffer, `Ok(Ready { .. })` on a complete verified
/// frame, and `Err` only for corruption (bad magic/version, length over
/// the cap, CRC mismatch).  Socket transports call this on a growing
/// receive buffer so a partial frame keeps reading instead of dropping
/// the connection.
pub fn try_deframe(buf: &[u8]) -> Result<FrameStatus<'_>> {
    if buf.len() < HEADER_LEN {
        return Ok(FrameStatus::Truncated { need: HEADER_LEN });
    }
    ensure!(buf[0..2] == MAGIC, "bad frame magic {:02x}{:02x}", buf[0], buf[1]);
    ensure!(
        version_ok(buf[2]),
        "protocol version mismatch: peer speaks v{}, this build accepts v{MIN_WIRE_VERSION}..=v{WIRE_VERSION}",
        buf[2]
    );
    let kind = buf[3];
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    ensure!(len <= MAX_FRAME, "frame length {len} exceeds cap");
    let total = HEADER_LEN + len + 4;
    if buf.len() < total {
        return Ok(FrameStatus::Truncated { need: total });
    }
    let body = &buf[HEADER_LEN..HEADER_LEN + len];
    let want = u32::from_le_bytes(buf[HEADER_LEN + len..total].try_into().unwrap());
    let got = crc32(body);
    ensure!(want == got, "frame checksum mismatch: {want:08x} != {got:08x}");
    Ok(FrameStatus::Ready { kind, body, consumed: total })
}

/// Total byte extent of the frame at the head of `buf`, when the header
/// is well-formed (magic/version readable, length within cap) and the
/// buffer holds the whole frame.  The CRC is deliberately NOT checked:
/// this is how [`StreamDecoder`] skips past a CRC-corrupt frame while
/// staying aligned on the next frame boundary — one parser for the
/// layout, shared with [`try_deframe`]'s constants.
fn complete_frame_extent(buf: &[u8]) -> Option<usize> {
    if buf.len() < HEADER_LEN || buf[0..2] != MAGIC || !version_ok(buf[2]) {
        return None;
    }
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    // cap check first: on 32-bit targets a hostile length near u32::MAX
    // would overflow the extent sum below
    if len > MAX_FRAME {
        return None;
    }
    let total = HEADER_LEN + len + 4;
    (buf.len() >= total).then_some(total)
}

/// Parse one frame from the head of `buf`; returns (kind, body, consumed).
/// Errors on truncation, bad magic/version, oversized length, or CRC
/// mismatch — a corrupt frame is never partially accepted.  Callers that
/// must distinguish an incomplete frame from a corrupt one (socket receive
/// buffers) use [`try_deframe`] instead.
pub fn deframe(buf: &[u8]) -> Result<(u8, &[u8], usize)> {
    match try_deframe(buf)? {
        FrameStatus::Ready { kind, body, consumed } => Ok((kind, body, consumed)),
        FrameStatus::Truncated { need } => {
            bail!("truncated frame: need {need} bytes, have {}", buf.len())
        }
    }
}

/// Incremental frame decoder over a growing receive buffer.
///
/// Socket transports feed raw `read()` chunks via [`StreamDecoder::extend`]
/// and pop complete frames via [`StreamDecoder::poll`]:
///
///   - `Ok(Some((kind, body)))` — one complete, CRC-verified frame;
///   - `Ok(None)` — the buffered bytes end mid-frame
///     ([`FrameStatus::Truncated`]): keep the bytes, read more;
///   - `Err` — corruption.  A CRC-mismatched frame whose *length* was
///     readable is skipped in full before the error returns, so one
///     corrupt frame is rejected without poisoning the stream — the next
///     `poll` resumes at the following frame boundary.  Lost framing (bad
///     magic/version/length) cannot be resynchronized; the connection
///     must drop.
#[derive(Default)]
pub struct StreamDecoder {
    buf: Vec<u8>,
    start: usize,
}

impl StreamDecoder {
    pub fn new() -> StreamDecoder {
        StreamDecoder::default()
    }

    /// Append freshly read bytes to the receive buffer.
    pub fn extend(&mut self, bytes: &[u8]) {
        // drop consumed prefix before growing; keeps the buffer bounded by
        // one frame plus one read chunk in the steady state
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a decoded frame.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Try to pop one complete frame from the buffer.
    pub fn poll(&mut self) -> Result<Option<(u8, Vec<u8>)>> {
        let head = &self.buf[self.start..];
        match try_deframe(head) {
            Ok(FrameStatus::Ready { kind, body, consumed }) => {
                let out = body.to_vec();
                self.start += consumed;
                Ok(Some((kind, out)))
            }
            Ok(FrameStatus::Truncated { .. }) => Ok(None),
            Err(e) => {
                // CRC mismatch: the header (and thus the frame extent) was
                // valid, so skip exactly this frame and leave the stream
                // aligned on the next one.  Header-level corruption leaves
                // `start` where it is — framing is lost and the caller
                // must drop the connection.
                if let Some(total) = complete_frame_extent(head) {
                    self.start += total;
                }
                Err(e)
            }
        }
    }

    /// Try to pop one complete [`super::messages::Message`].
    pub fn poll_message(&mut self) -> Result<Option<super::messages::Message>> {
        match self.poll()? {
            Some((kind, body)) => Ok(Some(super::messages::Message::from_body(kind, &body)?)),
            None => Ok(None),
        }
    }
}

/// Write one frame to a stream (does not flush; callers batch + flush).
pub fn write_frame<W: Write>(w: &mut W, kind: u8, body: &[u8]) -> Result<()> {
    w.write_all(&frame(kind, body)?).context("writing protocol frame")
}

/// Read one full frame from a stream; returns (kind, body).
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).context("reading protocol frame header")?;
    ensure!(header[0..2] == MAGIC, "bad frame magic {:02x}{:02x}", header[0], header[1]);
    ensure!(
        version_ok(header[2]),
        "protocol version mismatch: peer speaks v{}, this build accepts v{MIN_WIRE_VERSION}..=v{WIRE_VERSION}",
        header[2]
    );
    let kind = header[3];
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    ensure!(len <= MAX_FRAME, "frame length {len} exceeds cap");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading protocol frame body")?;
    let mut crc = [0u8; 4];
    r.read_exact(&mut crc).context("reading protocol frame checksum")?;
    let want = u32::from_le_bytes(crc);
    let got = crc32(&body);
    ensure!(want == got, "frame checksum mismatch: {want:08x} != {got:08x}");
    Ok((kind, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // the classic check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn incremental_crc_matches_one_shot_at_every_split() {
        let data = b"123456789 incremental crc over arbitrary splits";
        let want = crc32(data);
        for cut in 0..=data.len() {
            let mut c = Crc32::new();
            c.update(&data[..cut]);
            c.update(&data[cut..]);
            assert_eq!(c.finish(), want, "split at {cut}");
        }
        // byte-at-a-time too
        let mut c = Crc32::new();
        for b in data {
            c.update(std::slice::from_ref(b));
        }
        assert_eq!(c.finish(), want);
    }

    #[test]
    fn oldest_supported_version_still_accepted() {
        // a frame stamped with the oldest supported version byte (not
        // covered by the CRC) must decode on every path
        let mut f = frame(4, b"legacy peer").unwrap();
        f[2] = MIN_WIRE_VERSION;
        let (kind, body, _) = deframe(&f).unwrap();
        assert_eq!((kind, body), (4u8, b"legacy peer".as_slice()));
        let mut cur = std::io::Cursor::new(f.clone());
        assert_eq!(read_frame(&mut cur).unwrap(), (4, b"legacy peer".to_vec()));
        let mut dec = StreamDecoder::new();
        dec.extend(&f);
        assert_eq!(dec.poll().unwrap(), Some((4u8, b"legacy peer".to_vec())));
        // below the supported range is still a reject
        let mut old = frame(4, b"x").unwrap();
        old[2] = MIN_WIRE_VERSION - 1;
        assert!(deframe(&old).is_err());
    }

    /// A hostile `Write` impl: accepts at most `max` bytes per call and
    /// (via the default `write_vectored`) only ever sees the first
    /// non-empty slice — the worst case for the gather writer's manual
    /// slice advance.
    struct TrickleWriter {
        out: Vec<u8>,
        max: usize,
    }

    impl Write for TrickleWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.max);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn sample_gather(vals: &[f32], idx: &[u32]) -> (Gather<'_>, Vec<u8>) {
        let mut g = Gather::new();
        g.u8(7);
        g.bool(true);
        g.u32(0xDEAD_BEEF);
        g.usize(42);
        g.f32(-0.0);
        g.f32s(vals).unwrap();
        g.u32s(idx).unwrap();
        g.u16s(&[]).unwrap();
        g.bytes(b"tail").unwrap();
        let mut e = Enc::new();
        e.u8(7);
        e.bool(true);
        e.u32(0xDEAD_BEEF);
        e.usize(42);
        e.f32(-0.0);
        e.f32s(vals).unwrap();
        e.u32s(idx).unwrap();
        e.u16s(&[]).unwrap();
        e.bytes(b"tail").unwrap();
        (g, e.buf)
    }

    #[test]
    fn gather_frame_is_byte_identical_to_enc_frame() {
        let vals = [1.5f32, -2.25, f32::NAN, 0.0, -0.0];
        let idx = [0u32, 9, u32::MAX];
        let (g, body) = sample_gather(&vals, &idx);
        assert_eq!(g.len(), body.len());
        let want = frame(11, &body).unwrap();
        let mut sink = Vec::new();
        write_frame_gather(&mut sink, 11, &g).unwrap();
        assert_eq!(sink, want, "gather and Enc paths must produce identical frames");
        // the bulk slices were borrowed, not staged: owned bytes are just
        // the small fields + length prefixes (and the tiny `bytes` tail)
        assert!(
            g.staging_bytes() < body.len(),
            "staging {} must be below body {}",
            g.staging_bytes(),
            body.len()
        );
        assert!(g.staging_bytes() >= 1 + 1 + 4 + 8 + 4 + 4 * 4);
    }

    #[test]
    fn gather_frame_survives_trickled_short_writes() {
        let vals: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 7.0).collect();
        let idx = [3u32, 1, 4, 1, 5];
        let (g, body) = sample_gather(&vals, &idx);
        let want = frame(5, &body).unwrap();
        for max in [1usize, 2, 3, 7, 64] {
            let mut w = TrickleWriter { out: Vec::new(), max };
            write_frame_gather(&mut w, 5, &g).unwrap();
            assert_eq!(w.out, want, "short-write max {max}");
        }
    }

    #[test]
    fn primitives_round_trip() {
        let mut e = Enc::new();
        e.u8(7);
        e.bool(true);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX);
        e.usize(42);
        e.f32(-0.0);
        e.f64(f64::NAN);
        e.str("fedlama").unwrap();
        e.f32s(&[1.5, -2.5]).unwrap();
        e.u16s(&[9, 65535]).unwrap();
        e.u32s(&[3]).unwrap();
        e.usizes(&[1, 2, 3]).unwrap();
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.usize().unwrap(), 42);
        assert_eq!(d.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(d.f64().unwrap().is_nan());
        assert_eq!(d.str().unwrap(), "fedlama");
        assert_eq!(d.f32s().unwrap(), vec![1.5, -2.5]);
        assert_eq!(d.u16s().unwrap(), vec![9, 65535]);
        assert_eq!(d.u32s().unwrap(), vec![3]);
        assert_eq!(d.usizes().unwrap(), vec![1, 2, 3]);
        d.finish().unwrap();
    }

    #[test]
    fn decoder_rejects_overrun_and_trailing() {
        let mut d = Dec::new(&[1, 2]);
        assert!(d.u32().is_err());
        let mut e = Enc::new();
        e.u32(5); // claims 5 elements but provides none
        assert!(Dec::new(&e.buf).f32s().is_err());
        let d = Dec::new(&[0]);
        assert!(d.finish().is_err());
    }

    #[test]
    fn frame_round_trip_and_rejection() {
        let body = b"hello protocol".to_vec();
        let f = frame(4, &body).unwrap();
        let (kind, got, used) = deframe(&f).unwrap();
        assert_eq!((kind, got, used), (4u8, body.as_slice(), f.len()));

        // truncation at every prefix length fails
        for cut in 0..f.len() {
            assert!(deframe(&f[..cut]).is_err(), "accepted truncated frame at {cut}");
        }
        // any single flipped byte fails (magic, version, kind->crc, body, crc)
        for i in 0..f.len() {
            let mut bad = f.clone();
            bad[i] ^= 0x01;
            let r = deframe(&bad);
            if i == 3 {
                // kind byte is not covered by the crc; deframe accepts it and
                // the message layer rejects the unknown kind instead.
                assert!(r.is_ok());
            } else {
                assert!(r.is_err(), "accepted corrupt frame at byte {i}");
            }
        }
    }

    #[test]
    fn stream_io_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 2, b"abc").unwrap();
        write_frame(&mut buf, 9, b"").unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), (2, b"abc".to_vec()));
        assert_eq!(read_frame(&mut cur).unwrap(), (9, Vec::new()));
        assert!(read_frame(&mut cur).is_err(), "eof must error");
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut f = frame(1, b"x").unwrap();
        f[2] = WIRE_VERSION + 1;
        let err = format!("{:#}", deframe(&f).unwrap_err());
        assert!(err.contains("version mismatch"), "{err}");
    }

    #[test]
    fn try_deframe_distinguishes_truncation_from_corruption() {
        let f = frame(4, b"hello protocol").unwrap();
        // every strict prefix is Truncated, never an Err — and `need` is
        // a usable lower bound on the bytes required
        for cut in 0..f.len() {
            match try_deframe(&f[..cut]).unwrap() {
                FrameStatus::Truncated { need } => {
                    assert!(need > cut, "need {need} at cut {cut}");
                    assert!(need <= f.len());
                }
                FrameStatus::Ready { .. } => panic!("prefix of {cut} bytes decoded"),
            }
        }
        match try_deframe(&f).unwrap() {
            FrameStatus::Ready { kind, body, consumed } => {
                assert_eq!((kind, body, consumed), (4u8, b"hello protocol".as_slice(), f.len()));
            }
            other => panic!("{other:?}"),
        }
        // corruption is still an Err, not Truncated
        let mut bad = f.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01; // CRC byte
        assert!(try_deframe(&bad).is_err());
        let mut bad = f;
        bad[0] ^= 0x01; // magic
        assert!(try_deframe(&bad).is_err());
    }

    #[test]
    fn stream_decoder_reassembles_partial_frames() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&frame(2, b"first").unwrap());
        bytes.extend_from_slice(&frame(3, b"second frame body").unwrap());
        let mut dec = StreamDecoder::new();
        let mut got = Vec::new();
        // drip-feed one byte at a time: poll never errors, yields exactly
        // the two frames in order
        for &b in &bytes {
            dec.extend(&[b]);
            while let Some(f) = dec.poll().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(
            got,
            vec![(2u8, b"first".to_vec()), (3u8, b"second frame body".to_vec())]
        );
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn stream_decoder_skips_corrupt_crc_without_poisoning() {
        let mut corrupt = frame(2, b"damaged-in-flight").unwrap();
        let blen = corrupt.len();
        corrupt[blen - 6] ^= 0x40; // flip a body bit -> CRC mismatch
        let good = frame(5, b"still fine").unwrap();
        let mut dec = StreamDecoder::new();
        dec.extend(&corrupt);
        dec.extend(&good);
        let err = format!("{:#}", dec.poll().unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        // the corrupt frame was consumed in full; the stream is intact
        assert_eq!(dec.poll().unwrap(), Some((5u8, b"still fine".to_vec())));
        assert_eq!(dec.poll().unwrap(), None);
    }

    #[test]
    fn frame_rejects_body_over_cap_at_encode_time() {
        // the decode side refuses frames over MAX_FRAME; emitting one would
        // only poison the stream, so encode must refuse too
        let body = vec![0u8; MAX_FRAME + 1];
        let err = format!("{:#}", frame(1, &body).unwrap_err());
        assert!(err.contains("exceeds cap"), "{err}");
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, 1, &body).is_err());
        assert!(sink.is_empty(), "nothing may hit the stream on encode failure");
    }

    #[test]
    fn stream_decoder_skips_two_back_to_back_corrupt_frames() {
        // regression: each corrupt frame must advance the cursor by its own
        // full extent, so consecutive damaged frames cannot desynchronize
        // the stream or shadow the valid frame behind them
        let mut bad1 = frame(2, b"first damaged frame").unwrap();
        let n1 = bad1.len();
        bad1[n1 - 6] ^= 0x20;
        let mut bad2 = frame(3, b"second damaged, different length").unwrap();
        let n2 = bad2.len();
        bad2[n2 - 5] ^= 0x04;
        let good = frame(5, b"survivor").unwrap();
        let mut dec = StreamDecoder::new();
        dec.extend(&bad1);
        dec.extend(&bad2);
        dec.extend(&good);
        for _ in 0..2 {
            let err = format!("{:#}", dec.poll().unwrap_err());
            assert!(err.contains("checksum mismatch"), "{err}");
        }
        assert_eq!(dec.poll().unwrap(), Some((5u8, b"survivor".to_vec())));
        assert_eq!(dec.poll().unwrap(), None);
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn stream_decoder_header_corruption_is_fatal() {
        let mut f = frame(2, b"x").unwrap();
        f[0] ^= 0xFF; // magic gone -> framing lost, no resync possible
        let mut dec = StreamDecoder::new();
        dec.extend(&f);
        assert!(dec.poll().is_err());
        // still an error on retry: the decoder did not silently skip bytes
        assert!(dec.poll().is_err());
    }
}
